"""Static analyses over the source IR: liveness, call graph, type inference.

These drive the paper's five lowering optimizations:
  (i)   per-variable caller-saves stacks     -> save sets from liveness,
  (ii)  block-local temporaries              -> syntactic def-before-use,
  (iii) stack only when live across a call   -> save sets / recursion info,
  (iv)  top-of-stack caching                 -> structural in the VM,
  (v)   pop-push elimination                 -> peephole in lowering.py.
"""
from __future__ import annotations

from typing import Iterable

import jax

from . import ir


# --------------------------------------------------------------------------
# Reads/writes of source ops
# --------------------------------------------------------------------------


def op_reads(op: ir.Op) -> tuple[str, ...]:
    return op.ins


def op_writes(op: ir.Op) -> tuple[str, ...]:
    return op.outs


def term_reads(term: ir.Terminator) -> tuple[str, ...]:
    if isinstance(term, ir.Branch):
        return (term.var,)
    return ()


# --------------------------------------------------------------------------
# Liveness (per function, backward dataflow over the source CFG)
# --------------------------------------------------------------------------


class Liveness:
    """Per-block live-in/live-out, plus live-after sets for each op index.

    ``live_after(block, op_index)`` is the set of variables whose current
    value may still be read on some path after op ``op_index`` of ``block``
    has executed (excluding that op's own writes-before-reads semantics).
    """

    def __init__(self, func: ir.Function):
        self.func = func
        n = len(func.blocks)
        self.live_in: list[set[str]] = [set() for _ in range(n)]
        self.live_out: list[set[str]] = [set() for _ in range(n)]
        self._solve()

    def _block_use_def(self, blk: ir.Block) -> tuple[set[str], set[str]]:
        use: set[str] = set()
        defined: set[str] = set()
        for op in blk.ops:
            for r in op_reads(op):
                if r not in defined:
                    use.add(r)
            defined.update(op_writes(op))
        for r in term_reads(blk.term):
            if r not in defined:
                use.add(r)
        return use, defined

    def _solve(self) -> None:
        func = self.func
        n = len(func.blocks)
        use_def = [self._block_use_def(b) for b in func.blocks]
        # Function outputs are live at every Return.
        out_live = set(func.outputs)
        changed = True
        while changed:
            changed = False
            for i in range(n - 1, -1, -1):
                term = func.blocks[i].term
                if isinstance(term, ir.Return):
                    new_out = set(out_live)
                else:
                    new_out = set()
                    for s in ir.successors(func.blocks, i):
                        new_out |= self.live_in[s]
                use, defined = use_def[i]
                new_in = use | (new_out - defined)
                if new_out != self.live_out[i] or new_in != self.live_in[i]:
                    self.live_out[i] = new_out
                    self.live_in[i] = new_in
                    changed = True

    def live_after(self, block_idx: int, op_idx: int) -> set[str]:
        """Variables live immediately after op ``op_idx`` in ``block_idx``."""
        blk = self.func.blocks[block_idx]
        live = set(self.live_out[block_idx])
        for r in term_reads(blk.term):
            live.add(r)
        for j in range(len(blk.ops) - 1, op_idx, -1):
            op = blk.ops[j]
            live -= set(op_writes(op))
            live |= set(op_reads(op))
        return live


# --------------------------------------------------------------------------
# Lowered-CFG structure (drives the superblock fusion pass in fusion.py)
# --------------------------------------------------------------------------


def lowered_targets(term: "ir.LTerminator") -> tuple[int, ...]:
    """Every block index a lowered terminator can transfer control to
    *statically*.  ``LPushJump`` contributes both its callee entry and its
    return address (the latter is entered dynamically via ``LReturn``);
    ``LReturn`` itself contributes nothing — its target is on the pc stack.
    """
    if isinstance(term, ir.LJump):
        return (term.target,)
    if isinstance(term, ir.LBranch):
        return (term.true, term.false)
    if isinstance(term, ir.LPushJump):
        return (term.target, term.ret)
    return ()


def pinned_blocks(lowered: "ir.LoweredProgram") -> frozenset[int]:
    """Blocks whose *index* is load-bearing and must survive fusion intact:
    the program entry, every function entry (``LPushJump`` targets), and
    every return site (``LPushJump.ret`` addresses, entered dynamically by
    ``LReturn`` popping the pc stack).  Fusion may copy their ops into a
    predecessor but must never remove or renumber-away these blocks while
    they are reachable.
    """
    pinned = {lowered.entry} | set(lowered.func_entries.values())
    for blk in lowered.blocks:
        if isinstance(blk.term, ir.LPushJump):
            pinned.add(blk.term.target)
            pinned.add(blk.term.ret)
    return frozenset(pinned)


# --------------------------------------------------------------------------
# Call graph / recursion structure
# --------------------------------------------------------------------------


class CallGraph:
    def __init__(self, program: ir.Program):
        self.edges: dict[str, set[str]] = {f: set() for f in program.functions}
        for fname, func in program.functions.items():
            for blk in func.blocks:
                for op in blk.ops:
                    if isinstance(op, ir.Call):
                        self.edges[fname].add(op.callee)
        self._reach: dict[str, set[str]] = {}
        for f in self.edges:
            self._reach[f] = self._reachable(f)

    def _reachable(self, f: str) -> set[str]:
        seen: set[str] = set()
        stack = list(self.edges[f])
        while stack:
            g = stack.pop()
            if g in seen:
                continue
            seen.add(g)
            stack.extend(self.edges[g])
        return seen

    def can_reenter(self, caller: str, callee: str) -> bool:
        """Can a call from ``caller`` to ``callee`` lead back into ``caller``?

        If so, the caller must save (push) its live variables around the call.
        """
        return caller == callee or caller in self._reach[callee]

    def is_recursive(self, callee: str) -> bool:
        """Can ``callee`` transitively have two live frames at once?

        If so, arguments must be pushed onto the parameter stacks (burying the
        outer frame's values) rather than overwriting the tops.
        """
        return callee in self._reach[callee]


# --------------------------------------------------------------------------
# Type inference
# --------------------------------------------------------------------------


def _spec_of(x) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def _specs_eq(a: jax.ShapeDtypeStruct, b: jax.ShapeDtypeStruct) -> bool:
    return a.shape == b.shape and a.dtype == b.dtype


def infer_types(program: ir.Program) -> None:
    """Forward abstract interpretation filling ``Function.var_specs``.

    Function parameter and output specs are declared; locals are inferred by
    running each ``Prim.fn`` through ``jax.eval_shape``.  Merge points must
    agree exactly (we do not insert casts — the frontends emit explicit
    casts where needed).
    """
    for func in program.functions.values():
        specs: dict[str, jax.ShapeDtypeStruct] = dict(func.param_specs)
        pending = True
        guard = 0
        while pending:
            pending = False
            guard += 1
            if guard > len(func.blocks) * 4 + 16:
                missing = _missing_vars(func, specs)
                raise TypeError(
                    f"{func.name}: type inference did not converge; "
                    f"unresolved variables: {sorted(missing)}"
                )
            for blk in func.blocks:
                for op in blk.ops:
                    if isinstance(op, ir.Prim):
                        if not all(i in specs for i in op.ins):
                            if not all(o in specs for o in op.outs):
                                pending = True
                            continue
                        in_specs = [specs[i] for i in op.ins]
                        if op.batched:
                            # batched prims consume/produce a leading batch
                            # axis; type-check at batch size 1 and strip it.
                            in_specs = [
                                jax.ShapeDtypeStruct((1,) + tuple(s.shape),
                                                     s.dtype)
                                for s in in_specs
                            ]
                        try:
                            out = jax.eval_shape(op.fn, *in_specs)
                        except Exception as e:  # pragma: no cover - error path
                            raise TypeError(
                                f"{func.name}: cannot type primitive "
                                f"{op.name!r}({op.ins}): {e}"
                            ) from e
                        outs = out if isinstance(out, tuple) else (out,)
                        if op.batched:
                            for o in outs:
                                if not o.shape or o.shape[0] != 1:
                                    raise TypeError(
                                        f"{func.name}: batched primitive "
                                        f"{op.name!r} output lost its batch "
                                        f"axis: {o.shape}"
                                    )
                            outs = tuple(
                                jax.ShapeDtypeStruct(o.shape[1:], o.dtype)
                                for o in outs
                            )
                        if len(outs) != len(op.outs):
                            raise TypeError(
                                f"{func.name}: primitive {op.name!r} returned "
                                f"{len(outs)} values for {len(op.outs)} outputs"
                            )
                        for name, o in zip(op.outs, outs):
                            _bind(specs, name, _spec_of(o), func.name)
                    elif isinstance(op, ir.Call):
                        callee = program.functions[op.callee]
                        for name, oname in zip(op.outs, callee.outputs):
                            _bind(
                                specs,
                                name,
                                callee.output_specs[oname],
                                func.name,
                            )
        # Declared output specs must match inferred ones.
        for oname in func.outputs:
            declared = func.output_specs[oname]
            if oname in specs and not _specs_eq(specs[oname], declared):
                raise TypeError(
                    f"{func.name}: output {oname!r} declared "
                    f"{declared} but inferred {specs[oname]}"
                )
            specs[oname] = declared
        func.var_specs = specs


def _bind(specs, name, spec, fname) -> None:
    if name in specs and not _specs_eq(specs[name], spec):
        raise TypeError(
            f"{fname}: variable {name!r} assigned conflicting types "
            f"{specs[name]} vs {spec} (merge points must agree)"
        )
    specs[name] = spec


def _missing_vars(func: ir.Function, specs) -> set[str]:
    missing: set[str] = set()
    for blk in func.blocks:
        for op in blk.ops:
            missing |= {o for o in op.outs if o not in specs}
    return missing


def all_vars(func: ir.Function) -> set[str]:
    vs: set[str] = set(func.params) | set(func.outputs)
    for blk in func.blocks:
        for op in blk.ops:
            vs.update(op.ins)
            vs.update(op.outs)
        vs.update(term_reads(blk.term))
    return vs
