"""Static analyses over the source and lowered IRs.

Source-IR analyses drive the paper's five lowering optimizations:
  (i)   per-variable caller-saves stacks     -> save sets from liveness,
  (ii)  block-local temporaries              -> syntactic def-before-use,
  (iii) stack only when live across a call   -> save sets / recursion info,
  (iv)  top-of-stack caching                 -> structural in the VM,
  (v)   pop-push elimination                 -> peephole in lowering.py.

Lowered-IR analyses drive the pass pipeline (passes.py) and the verifier
(verifier.py): :class:`LoweredLiveness` (dead-code elimination),
:func:`stack_effects` (per-function stack-balance dataflow) and
:func:`stack_depth_bound` (interprocedural worst-case stack depth, the
static replacement for the magic ``max_depth=32``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax

from . import ir


# --------------------------------------------------------------------------
# Reads/writes of source ops
# --------------------------------------------------------------------------


def op_reads(op: ir.Op) -> tuple[str, ...]:
    return op.ins


def op_writes(op: ir.Op) -> tuple[str, ...]:
    return op.outs


def term_reads(term: ir.Terminator) -> tuple[str, ...]:
    if isinstance(term, ir.Branch):
        return (term.var,)
    return ()


# --------------------------------------------------------------------------
# Liveness (per function, backward dataflow over the source CFG)
# --------------------------------------------------------------------------


class Liveness:
    """Per-block live-in/live-out, plus live-after sets for each op index.

    ``live_after(block, op_index)`` is the set of variables whose current
    value may still be read on some path after op ``op_index`` of ``block``
    has executed (excluding that op's own writes-before-reads semantics).
    """

    def __init__(self, func: ir.Function):
        self.func = func
        n = len(func.blocks)
        self.live_in: list[set[str]] = [set() for _ in range(n)]
        self.live_out: list[set[str]] = [set() for _ in range(n)]
        self._solve()

    def _block_use_def(self, blk: ir.Block) -> tuple[set[str], set[str]]:
        use: set[str] = set()
        defined: set[str] = set()
        for op in blk.ops:
            for r in op_reads(op):
                if r not in defined:
                    use.add(r)
            defined.update(op_writes(op))
        for r in term_reads(blk.term):
            if r not in defined:
                use.add(r)
        return use, defined

    def _solve(self) -> None:
        func = self.func
        n = len(func.blocks)
        use_def = [self._block_use_def(b) for b in func.blocks]
        # Function outputs are live at every Return.
        out_live = set(func.outputs)
        changed = True
        while changed:
            changed = False
            for i in range(n - 1, -1, -1):
                term = func.blocks[i].term
                if isinstance(term, ir.Return):
                    new_out = set(out_live)
                else:
                    new_out = set()
                    for s in ir.successors(func.blocks, i):
                        new_out |= self.live_in[s]
                use, defined = use_def[i]
                new_in = use | (new_out - defined)
                if new_out != self.live_out[i] or new_in != self.live_in[i]:
                    self.live_out[i] = new_out
                    self.live_in[i] = new_in
                    changed = True
        # Per-op live-after sets, cached at solve time.  One backward scan
        # per block here makes every live_after() query O(1) instead of
        # rescanning the block suffix — this is a hot path now that the
        # pass pipeline re-runs analyses after every transform.
        self._after: list[list[frozenset[str]]] = []
        for i, blk in enumerate(func.blocks):
            live = set(self.live_out[i])
            live.update(term_reads(blk.term))
            after: list[frozenset[str]] = [frozenset()] * len(blk.ops)
            for j in range(len(blk.ops) - 1, -1, -1):
                after[j] = frozenset(live)
                op = blk.ops[j]
                live -= set(op_writes(op))
                live |= set(op_reads(op))
            self._after.append(after)

    def live_after(self, block_idx: int, op_idx: int) -> set[str]:
        """Variables live immediately after op ``op_idx`` in ``block_idx``."""
        return set(self._after[block_idx][op_idx])


# --------------------------------------------------------------------------
# Lowered-CFG structure (drives the superblock fusion pass in fusion.py)
# --------------------------------------------------------------------------


def lowered_targets(term: "ir.LTerminator") -> tuple[int, ...]:
    """Every block index a lowered terminator can transfer control to
    *statically*.  ``LPushJump`` contributes both its callee entry and its
    return address (the latter is entered dynamically via ``LReturn``);
    ``LReturn`` itself contributes nothing — its target is on the pc stack.
    """
    if isinstance(term, ir.LJump):
        return (term.target,)
    if isinstance(term, ir.LBranch):
        return (term.true, term.false)
    if isinstance(term, ir.LPushJump):
        return (term.target, term.ret)
    return ()


def pinned_blocks(lowered: "ir.LoweredProgram") -> frozenset[int]:
    """Blocks whose *index* is load-bearing and must survive fusion intact:
    the program entry, every function entry (``LPushJump`` targets), and
    every return site (``LPushJump.ret`` addresses, entered dynamically by
    ``LReturn`` popping the pc stack).  Fusion may copy their ops into a
    predecessor but must never remove or renumber-away these blocks while
    they are reachable.
    """
    pinned = {lowered.entry} | set(lowered.func_entries.values())
    for blk in lowered.blocks:
        if isinstance(blk.term, ir.LPushJump):
            pinned.add(blk.term.target)
            pinned.add(blk.term.ret)
    return frozenset(pinned)


# --------------------------------------------------------------------------
# Lowered-CFG liveness (drives dead-code elimination in passes.py)
# --------------------------------------------------------------------------


class LoweredLiveness:
    """Backward liveness of variable *tops* over the lowered CFG.

    Deliberately conservative about dynamic control flow: an ``LReturn``
    may resume at *any* return site (every ``LPushJump.ret``) or at
    program exit (where ``main_outputs`` stay live), so its live-out is
    the union over all of them.  ``LPush`` reads both its source and the
    variable it buries — the buried value is restored by a later ``LPop``
    and may be read afterwards — so a value that reaches a push is never
    considered dead.
    """

    def __init__(self, lowered: ir.LoweredProgram):
        self.lowered = lowered
        n = len(lowered.blocks)
        self.live_in: list[set[str]] = [set() for _ in range(n)]
        self.live_out: list[set[str]] = [set() for _ in range(n)]
        self._ret_sites = tuple(sorted({
            blk.term.ret
            for blk in lowered.blocks
            if isinstance(blk.term, ir.LPushJump)
        }))
        self._solve()

    @staticmethod
    def op_reads(op: ir.LOp) -> tuple[str, ...]:
        if isinstance(op, ir.LPush):
            return (op.src, op.var)
        return ir.prim_reads(op)

    def successors(self, i: int) -> tuple[int, ...]:
        t = self.lowered.blocks[i].term
        if isinstance(t, ir.LJump):
            return (t.target,)
        if isinstance(t, ir.LBranch):
            return (t.true, t.false)
        if isinstance(t, ir.LPushJump):
            return (t.target,)
        return self._ret_sites  # LReturn: any ret site (exit is separate)

    def _block_use_def(self, blk: ir.LBlock) -> tuple[set[str], set[str]]:
        use: set[str] = set()
        defined: set[str] = set()
        for op in blk.ops:
            for r in self.op_reads(op):
                if r not in defined:
                    use.add(r)
            defined.update(ir.prim_writes(op))
        if isinstance(blk.term, ir.LBranch) and blk.term.var not in defined:
            use.add(blk.term.var)
        return use, defined

    def _solve(self) -> None:
        blocks = self.lowered.blocks
        exit_live = set(self.lowered.main_outputs)
        if self.lowered.state_layout is not None:
            # A packed main output leaves the VM through its packed array
            # (the boundary reads ``tops[packed][:, slot]``), so it is the
            # *packed* variable that must stay live at exit.
            for o in tuple(exit_live):
                packed_slot = self.lowered.state_layout.slot_of(o)
                if packed_slot is not None:
                    exit_live.discard(o)
                    exit_live.add(packed_slot[0])
        use_def = [self._block_use_def(b) for b in blocks]
        changed = True
        while changed:
            changed = False
            for i in range(len(blocks) - 1, -1, -1):
                new_out: set[str] = set()
                if isinstance(blocks[i].term, ir.LReturn):
                    new_out |= exit_live
                for s in self.successors(i):
                    new_out |= self.live_in[s]
                use, defined = use_def[i]
                new_in = use | (new_out - defined)
                if new_out != self.live_out[i] or new_in != self.live_in[i]:
                    self.live_out[i] = new_out
                    self.live_in[i] = new_in
                    changed = True


# --------------------------------------------------------------------------
# Interprocedural stack effects + static stack-depth bound
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class FunctionStackEffects:
    """Stack-balance summary of one function's lowered body.

    ``entry_deltas[b]`` is the per-variable stack delta (pushes minus
    pops, relative to the function's own entry) on entry to block ``b``;
    zero entries are dropped.  ``local_peaks[v]`` is the largest standing
    delta ``v`` reaches anywhere in the body.  ``calls`` records each
    ``LPushJump`` site as ``(block, callee, standing deltas)`` — the
    deltas held *while the callee runs*.
    """

    name: str
    entry_deltas: dict[int, dict[str, int]]
    local_peaks: dict[str, int]
    calls: tuple[tuple[int, str, dict[str, int]], ...]


def stack_effects(
    lowered: ir.LoweredProgram,
) -> dict[str, FunctionStackEffects]:
    """Per-function stack-balance dataflow over the lowered CFG.

    This is the JVM-bytecode-style verification of the paper's calling
    convention: within one frame, every variable's stack delta must be
    non-negative everywhere, merge points must agree, and every
    ``LReturn`` must be reached with all deltas at zero (the caller's
    return site pops exactly what the call site pushed).  A call is
    summarized as a net-zero edge from the ``LPushJump`` block to its
    return site.

    Raises ``ValueError`` naming the function, block and variable on any
    violation; the verifier re-raises it as a ``VerificationError``.
    """
    entry_of = {e: f for f, e in lowered.func_entries.items()}
    out: dict[str, FunctionStackEffects] = {}
    for fname, entry in lowered.func_entries.items():
        entry_deltas: dict[int, dict[str, int]] = {}
        local_peaks: dict[str, int] = {}
        calls: list[tuple[int, str, dict[str, int]]] = []
        work: list[tuple[int, dict[str, int]]] = [(entry, {})]
        while work:
            b, delta = work.pop()
            if b in entry_deltas:
                if entry_deltas[b] != delta:
                    raise ValueError(
                        f"{fname}: block {b} "
                        f"({lowered.blocks[b].label or 'unlabeled'}) is "
                        f"reached with disagreeing stack deltas "
                        f"{entry_deltas[b]} vs {delta}"
                    )
                continue
            entry_deltas[b] = delta
            cur = dict(delta)
            blk = lowered.blocks[b]
            for op in blk.ops:
                if isinstance(op, ir.LPush):
                    cur[op.var] = cur.get(op.var, 0) + 1
                    local_peaks[op.var] = max(
                        local_peaks.get(op.var, 0), cur[op.var]
                    )
                elif isinstance(op, ir.LPop):
                    cur[op.var] = cur.get(op.var, 0) - 1
                    if cur[op.var] < 0:
                        raise ValueError(
                            f"{fname}: block {b} ({blk.label}): pop of "
                            f"{op.var!r} below the frame's stack floor "
                            "(unbalanced push/pop)"
                        )
            cur = {v: d for v, d in cur.items() if d}
            t = blk.term
            if isinstance(t, ir.LJump):
                work.append((t.target, cur))
            elif isinstance(t, ir.LBranch):
                work.append((t.true, cur))
                work.append((t.false, cur))
            elif isinstance(t, ir.LPushJump):
                callee = entry_of.get(t.target)
                if callee is None:
                    raise ValueError(
                        f"{fname}: block {b} ({blk.label}): pushjump "
                        f"target {t.target} is not a function entry"
                    )
                calls.append((b, callee, cur))
                work.append((t.ret, cur))
            elif isinstance(t, ir.LReturn):
                if cur:
                    raise ValueError(
                        f"{fname}: block {b} ({blk.label}): returns with "
                        f"non-zero stack delta for {sorted(cur)} "
                        "(unbalanced push/pop)"
                    )
            else:
                raise ValueError(
                    f"{fname}: block {b} ({blk.label}): invalid lowered "
                    f"terminator {t!r}"
                )
        out[fname] = FunctionStackEffects(
            fname, entry_deltas, local_peaks, tuple(calls)
        )
    return out


@dataclass(frozen=True)
class StackDepthReport:
    """Worst-case stack usage of a lowered program, statically bounded.

    For non-recursive call structures, ``required_max_depth`` is the
    smallest ``VMConfig.max_depth`` that can never overflow: the pc stack
    needs ``pc_depth + 1`` slots (the pc pointer starts at 1, above the
    exit sentinel) and each variable stack needs ``var_depths[v]`` slots.
    A recursive program has no static bound: ``required_max_depth`` and
    ``pc_depth`` are ``None`` and ``recursive_cycle`` names the cycle of
    functions whose call depth is input-dependent.
    """

    pc_depth: Optional[int]
    var_depths: dict[str, int]
    required_max_depth: Optional[int]
    recursive_cycle: Optional[tuple[str, ...]]


def stack_depth_bound(lowered: ir.LoweredProgram) -> StackDepthReport:
    """Interprocedural worst-case pc/variable stack depth from ``main``.

    Walks the lowered call graph (``LPushJump`` sites from
    :func:`stack_effects`) accumulating, per variable, the standing
    pushes held across each call plus the callee subtree's own peak.
    Only functions reachable from the program entry contribute — a
    registered-but-never-called recursive helper cannot overflow at run
    time and does not forfeit the static bound.
    """
    effects = stack_effects(lowered)
    entry_of = {e: f for f, e in lowered.func_entries.items()}
    main = entry_of[lowered.entry]
    memo: dict[str, tuple[int, dict[str, int]]] = {}
    path: list[str] = []
    cycle: Optional[tuple[str, ...]] = None

    def visit(f: str) -> tuple[int, dict[str, int]]:
        nonlocal cycle
        if f in memo:
            return memo[f]
        if f in path:
            if cycle is None:
                cycle = tuple(path[path.index(f):])
            return (0, {})
        path.append(f)
        eff = effects[f]
        pc = 0
        peaks = dict(eff.local_peaks)
        for _b, callee, standing in eff.calls:
            cpc, cpeaks = visit(callee)
            pc = max(pc, 1 + cpc)
            for v, p in cpeaks.items():
                peaks[v] = max(peaks.get(v, 0), standing.get(v, 0) + p)
        path.pop()
        memo[f] = (pc, peaks)
        return memo[f]

    pc, peaks = visit(main)
    if cycle is not None:
        return StackDepthReport(
            pc_depth=None, var_depths={}, required_max_depth=None,
            recursive_cycle=cycle,
        )
    required = max([pc + 1, 1] + list(peaks.values()))
    return StackDepthReport(
        pc_depth=pc, var_depths=peaks, required_max_depth=required,
        recursive_cycle=None,
    )


# --------------------------------------------------------------------------
# Call graph / recursion structure
# --------------------------------------------------------------------------


class CallGraph:
    def __init__(self, program: ir.Program):
        self.edges: dict[str, set[str]] = {f: set() for f in program.functions}
        for fname, func in program.functions.items():
            for blk in func.blocks:
                for op in blk.ops:
                    if isinstance(op, ir.Call):
                        self.edges[fname].add(op.callee)
        self._reach: dict[str, set[str]] = {}
        for f in self.edges:
            self._reach[f] = self._reachable(f)

    def _reachable(self, f: str) -> set[str]:
        seen: set[str] = set()
        stack = list(self.edges[f])
        while stack:
            g = stack.pop()
            if g in seen:
                continue
            seen.add(g)
            stack.extend(self.edges[g])
        return seen

    def can_reenter(self, caller: str, callee: str) -> bool:
        """Can a call from ``caller`` to ``callee`` lead back into ``caller``?

        If so, the caller must save (push) its live variables around the call.
        """
        return caller == callee or caller in self._reach[callee]

    def is_recursive(self, callee: str) -> bool:
        """Can ``callee`` transitively have two live frames at once?

        If so, arguments must be pushed onto the parameter stacks (burying the
        outer frame's values) rather than overwriting the tops.
        """
        return callee in self._reach[callee]


# --------------------------------------------------------------------------
# Type inference
# --------------------------------------------------------------------------


def _spec_of(x) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def _specs_eq(a: jax.ShapeDtypeStruct, b: jax.ShapeDtypeStruct) -> bool:
    return a.shape == b.shape and a.dtype == b.dtype


def infer_types(program: ir.Program) -> None:
    """Forward abstract interpretation filling ``Function.var_specs``.

    Function parameter and output specs are declared; locals are inferred by
    running each ``Prim.fn`` through ``jax.eval_shape``.  Merge points must
    agree exactly (we do not insert casts — the frontends emit explicit
    casts where needed).
    """
    for func in program.functions.values():
        specs: dict[str, jax.ShapeDtypeStruct] = dict(func.param_specs)
        pending = True
        guard = 0
        while pending:
            pending = False
            guard += 1
            if guard > len(func.blocks) * 4 + 16:
                missing = _missing_vars(func, specs)
                raise TypeError(
                    f"{func.name}: type inference did not converge; "
                    f"unresolved variables: {sorted(missing)}"
                )
            for blk in func.blocks:
                for op in blk.ops:
                    if isinstance(op, ir.Prim):
                        if not all(i in specs for i in op.ins):
                            if not all(o in specs for o in op.outs):
                                pending = True
                            continue
                        in_specs = [specs[i] for i in op.ins]
                        if op.batched:
                            # batched prims consume/produce a leading batch
                            # axis; type-check at batch size 1 and strip it.
                            in_specs = [
                                jax.ShapeDtypeStruct((1,) + tuple(s.shape),
                                                     s.dtype)
                                for s in in_specs
                            ]
                        try:
                            out = jax.eval_shape(op.fn, *in_specs)
                        except Exception as e:  # pragma: no cover - error path
                            raise TypeError(
                                f"{func.name}: cannot type primitive "
                                f"{op.name!r}({op.ins}): {e}"
                            ) from e
                        outs = out if isinstance(out, tuple) else (out,)
                        if op.batched:
                            for o in outs:
                                if not o.shape or o.shape[0] != 1:
                                    raise TypeError(
                                        f"{func.name}: batched primitive "
                                        f"{op.name!r} output lost its batch "
                                        f"axis: {o.shape}"
                                    )
                            outs = tuple(
                                jax.ShapeDtypeStruct(o.shape[1:], o.dtype)
                                for o in outs
                            )
                        if len(outs) != len(op.outs):
                            raise TypeError(
                                f"{func.name}: primitive {op.name!r} returned "
                                f"{len(outs)} values for {len(op.outs)} outputs"
                            )
                        for name, o in zip(op.outs, outs):
                            _bind(specs, name, _spec_of(o), func.name)
                    elif isinstance(op, ir.Call):
                        callee = program.functions[op.callee]
                        for name, oname in zip(op.outs, callee.outputs):
                            _bind(
                                specs,
                                name,
                                callee.output_specs[oname],
                                func.name,
                            )
        # Declared output specs must match inferred ones.
        for oname in func.outputs:
            declared = func.output_specs[oname]
            if oname in specs and not _specs_eq(specs[oname], declared):
                raise TypeError(
                    f"{func.name}: output {oname!r} declared "
                    f"{declared} but inferred {specs[oname]}"
                )
            specs[oname] = declared
        func.var_specs = specs


def _bind(specs, name, spec, fname) -> None:
    if name in specs and not _specs_eq(specs[name], spec):
        raise TypeError(
            f"{fname}: variable {name!r} assigned conflicting types "
            f"{specs[name]} vs {spec} (merge points must agree)"
        )
    specs[name] = spec


def _missing_vars(func: ir.Function, specs) -> set[str]:
    missing: set[str] = set()
    for blk in func.blocks:
        for op in blk.ops:
            missing |= {o for o in op.outs if o not in specs}
    return missing


def all_vars(func: ir.Function) -> set[str]:
    vs: set[str] = set(func.params) | set(func.outputs)
    for blk in func.blocks:
        for op in blk.ops:
            vs.update(op.ins)
            vs.update(op.outs)
        vs.update(term_reads(blk.term))
    return vs
