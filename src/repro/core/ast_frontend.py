"""Restricted-Python AST frontend (the paper's AutoGraph-style transform).

The paper implements autobatching "as a general program transformation on
Python source".  This module reproduces that interface for a restricted but
expressive Python subset:

* statements: ``=``, ``+=`` etc., ``if``/``elif``/``else``, ``while``,
  ``return``, ``pass``;
* expressions: arbitrary pure JAX expressions (operators, ``jnp.*`` calls,
  indexing, tuples in returns), PLUS calls to other *registered*
  autobatchable functions (including recursive self-calls), which are
  hoisted into IR ``Call`` ops in ANF style;
* multiple ``return`` statements are fine; every return must yield the same
  number of values.

Usage::

    ns = Namespace()

    @ns.define(param_specs={'n': I32}, output_specs=[I32])
    def fib(n):
        if n < 2:
            return n
        return fib(n - 1) + fib(n - 2)

    program = ns.program(main='fib')
"""
from __future__ import annotations

import ast
import inspect
import itertools
import textwrap
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from . import frontend, ir


class ASTFrontendError(NotImplementedError):
    pass


def _ret_names(n: int) -> tuple[str, ...]:
    return ("out",) if n == 1 else tuple(f"out{i}" for i in range(n))


class Namespace:
    """A registry of autobatchable functions that may call each other.

    This is the *unified* frontend namespace: it holds both restricted-Python
    functions (AST-transformed on demand) and pre-built IR functions coming
    from :class:`repro.core.frontend.FunctionBuilder`.  Either kind may call
    the other by name — ``trace()`` assembles them into one
    :class:`ir.Program`.
    """

    def __init__(self):
        self._specs: dict[str, tuple[dict, list]] = {}
        self._pyfns: dict[str, Callable] = {}
        self._built: dict[str, ir.Function] = {}

    def define(self, param_specs: dict, output_specs: Sequence) -> Callable:
        """Decorator registering a restricted-Python function."""

        def deco(fn: Callable) -> Callable:
            name = fn.__name__
            self._specs[name] = (dict(param_specs), list(output_specs))
            self._pyfns[name] = fn
            # Redefinition shadows: drop any IR built from a previous body.
            self._built.pop(name, None)
            return fn

        return deco

    def add(self, func) -> ir.Function:
        """Register a builder-defined function (or a raw ``ir.Function``).

        Accepts a :class:`repro.core.frontend.FunctionBuilder` (built here)
        or an already-built :class:`ir.Function`.  Registered builder
        functions are callable from restricted-Python functions and vice
        versa.
        """
        if isinstance(func, frontend.FunctionBuilder):
            func = func.build()
        if not isinstance(func, ir.Function):
            raise TypeError(f"expected FunctionBuilder or ir.Function, got {func!r}")
        self._built[func.name] = func
        return func

    def names(self) -> set[str]:
        return set(self._pyfns) | set(self._built)

    def __contains__(self, name: str) -> bool:
        return name in self._pyfns or name in self._built

    def trace(self, main: str, prune: bool = True) -> ir.Program:
        """Assemble the program rooted at ``main``.

        AST functions are transformed on demand; with ``prune=True`` only
        functions reachable from ``main`` through ``Call`` ops are included
        (a shared namespace may hold unrelated function families).
        """
        if main not in self:
            raise ValueError(f"main function {main!r} is not registered")
        functions: dict[str, ir.Function] = {}
        worklist = [main]
        while worklist:
            name = worklist.pop()
            if name in functions:
                continue
            functions[name] = self._function(name)
            for blk in functions[name].blocks:
                for op in blk.ops:
                    if isinstance(op, ir.Call) and op.callee not in functions:
                        worklist.append(op.callee)
        if not prune:
            for name in self.names():
                functions.setdefault(name, self._function(name))
        prog = ir.Program(functions=functions, main=main)
        prog.validate()
        return prog

    def program(self, main: str) -> ir.Program:
        """Back-compat alias: build *every* registered function."""
        return self.trace(main, prune=False)

    # ------------------------------------------------------------------

    def _function(self, name: str) -> ir.Function:
        if name not in self._built:
            self._built[name] = self._transform(name)
        return self._built[name]

    def _transform(self, name: str) -> ir.Function:
        fn = self._pyfns[name]
        param_specs, output_specs = self._specs[name]
        src = textwrap.dedent(inspect.getsource(fn))
        tree = ast.parse(src)
        fdef = tree.body[0]
        if not isinstance(fdef, (ast.FunctionDef, ast.AsyncFunctionDef)):
            raise ASTFrontendError(f"{name}: expected a function definition")
        params = [a.arg for a in fdef.args.args]
        if set(params) != set(param_specs):
            raise ASTFrontendError(
                f"{name}: param_specs keys {sorted(param_specs)} do not match "
                f"parameters {params}"
            )
        outputs = _ret_names(len(output_specs))
        fb = frontend.FunctionBuilder(
            name,
            params,
            outputs,
            param_specs,
            dict(zip(outputs, output_specs)),
        )
        closure_ns = dict(fn.__globals__)
        if fn.__closure__:
            for cname, cell in zip(fn.__code__.co_freevars, fn.__closure__):
                closure_ns[cname] = cell.cell_contents
        conv = _Converter(self, fb, params, closure_ns, outputs)
        conv.convert_body(fdef.body)
        fb.return_()  # seal fall-through paths
        return fb.build()


class _Converter:
    def __init__(self, ns: Namespace, fb: frontend.FunctionBuilder, params,
                 closure_ns, outputs):
        self.ns = ns
        self.fb = fb
        self.closure_ns = closure_ns
        self.outputs = outputs
        # Variables that live in the IR (everything assigned or a parameter).
        self.program_vars: set[str] = set(params)
        self._tmp = itertools.count()

    # ------------------------------- statements

    def convert_body(self, body: list[ast.stmt]) -> None:
        for stmt in body:
            self.convert_stmt(stmt)

    def convert_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            if len(stmt.targets) != 1:
                raise ASTFrontendError("chained assignment not supported")
            self._assign(stmt.targets[0], stmt.value)
        elif isinstance(stmt, ast.AugAssign):
            if not isinstance(stmt.target, ast.Name):
                raise ASTFrontendError("augmented assign target must be a name")
            binop = ast.BinOp(
                left=ast.Name(id=stmt.target.id, ctx=ast.Load()),
                op=stmt.op,
                right=stmt.value,
            )
            self._assign(ast.Name(id=stmt.target.id, ctx=ast.Store()), binop)
        elif isinstance(stmt, ast.If):
            cond = self._as_var(stmt.test, hint="cond")
            with self.fb.if_(cond):
                self.convert_body(stmt.body)
            if stmt.orelse:
                with self.fb.orelse():
                    self.convert_body(stmt.orelse)
        elif isinstance(stmt, ast.While):
            if self._contains_registered_call(stmt.test):
                raise ASTFrontendError(
                    "calls to autobatchable functions are not allowed in "
                    "while conditions; hoist them into the loop body"
                )
            free = sorted(self._free_program_vars(stmt.test))
            cond_fn = self._compile_expr(stmt.test, free, hint="while_cond")
            with self.fb.while_(cond_fn, free):
                self.convert_body(stmt.body)
        elif isinstance(stmt, ast.Return):
            self._convert_return(stmt)
        elif isinstance(stmt, ast.Pass):
            pass
        elif isinstance(stmt, ast.Expr) and isinstance(
            stmt.value, ast.Constant
        ):
            pass  # docstring
        else:
            raise ASTFrontendError(
                f"unsupported statement: {ast.dump(stmt)[:80]}"
            )

    def _assign(self, target: ast.expr, value: ast.expr) -> None:
        if isinstance(target, ast.Name):
            self._assign_names([target.id], value)
        elif isinstance(target, ast.Tuple) and all(
            isinstance(e, ast.Name) for e in target.elts
        ):
            self._assign_names([e.id for e in target.elts], value)
        else:
            raise ASTFrontendError("assignment target must be name(s)")

    def _assign_names(self, names: list[str], value: ast.expr) -> None:
        # Direct call to a registered function?
        if self._is_registered_call(value):
            args = [self._as_var(a) for a in value.args]
            self.fb.call(
                value.func.id, args,
                out=names[0] if len(names) == 1 else names,
                n_out=len(names),
            )
            self.program_vars.update(names)
            return
        if len(names) > 1:
            # Tuple-unpack of a non-call expression: evaluate then project.
            value = self._hoist_calls(value)
            free = sorted(self._free_program_vars(value))
            fn = self._compile_expr(value, free, hint="tuple")
            self.fb.prim(
                fn, free, out=names, n_out=len(names), name="tuple_assign"
            )
            self.program_vars.update(names)
            return
        value = self._hoist_calls(value)
        free = sorted(self._free_program_vars(value))
        fn = self._compile_expr(value, free, hint=names[0])
        self.fb.prim(fn, free, out=names[0], name=f"={names[0]}")
        self.program_vars.add(names[0])

    def _convert_return(self, stmt: ast.Return) -> None:
        if stmt.value is None:
            raise ASTFrontendError("bare return not supported; return values")
        values = (
            list(stmt.value.elts)
            if isinstance(stmt.value, ast.Tuple)
            else [stmt.value]
        )
        if len(values) != len(self.outputs):
            raise ASTFrontendError(
                f"return arity {len(values)} != declared {len(self.outputs)}"
            )
        for out, v in zip(self.outputs, values):
            self._assign_names([out], v)
        self.fb.return_()

    # ------------------------------- expressions

    def _is_registered_call(self, e: ast.expr) -> bool:
        return (
            isinstance(e, ast.Call)
            and isinstance(e.func, ast.Name)
            and e.func.id in self.ns
        )

    def _contains_registered_call(self, e: ast.expr) -> bool:
        return any(
            self._is_registered_call(n) for n in ast.walk(e)
        )

    def _hoist_calls(self, e: ast.expr) -> ast.expr:
        """ANF-convert: replace registered calls inside ``e`` with temps."""
        conv = self

        class Hoister(ast.NodeTransformer):
            def visit_Call(self, node: ast.Call):
                node = self.generic_visit(node)  # inner calls first
                if conv._is_registered_call(node):
                    args = [conv._as_var(a) for a in node.args]
                    tmp = f"_call{next(conv._tmp)}"
                    conv.fb.call(node.func.id, args, out=tmp)
                    conv.program_vars.add(tmp)
                    return ast.Name(id=tmp, ctx=ast.Load())
                return node

        return ast.fix_missing_locations(Hoister().visit(e))

    def _as_var(self, e: ast.expr, hint: str = "t") -> str:
        """Ensure ``e``'s value is available as an IR variable name."""
        e = self._hoist_calls(e)
        if isinstance(e, ast.Name) and e.id in self.program_vars:
            return e.id
        free = sorted(self._free_program_vars(e))
        fn = self._compile_expr(e, free, hint=hint)
        name = f"_{hint}{next(self._tmp)}"
        self.fb.prim(fn, free, out=name, name=hint)
        self.program_vars.add(name)
        return name

    def _free_program_vars(self, e: ast.expr) -> set[str]:
        free: set[str] = set()
        for node in ast.walk(e):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                if node.id in self.program_vars:
                    free.add(node.id)
        return free

    def _compile_expr(
        self, e: ast.expr, free: list[str], hint: str = "expr"
    ) -> Callable:
        lam = ast.Expression(
            body=ast.Lambda(
                args=ast.arguments(
                    posonlyargs=[],
                    args=[ast.arg(arg=v) for v in free],
                    vararg=None,
                    kwonlyargs=[],
                    kw_defaults=[],
                    kwarg=None,
                    defaults=[],
                ),
                body=e,
            )
        )
        ast.fix_missing_locations(lam)
        code = compile(lam, filename=f"<autobatch:{hint}>", mode="eval")
        fn = eval(code, self.closure_ns)  # noqa: S307 - trusted source
        fn.__name__ = hint
        return fn
