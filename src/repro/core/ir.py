"""Control-flow-graph IR for autobatching (paper Fig. 2) and its lowered,
stack-explicit form (paper Fig. 4).

Source IR (``Program``/``Function``/``Block``): per-function CFGs whose ops
are ``Prim`` (pure per-member computations) and ``Call`` (possibly-recursive
calls to other autobatched functions), and whose terminators are ``Jump``,
``Branch`` and ``Return``.

Lowered IR (``LoweredProgram``): all function CFGs merged into one block
list; ``Call`` is replaced by explicit per-variable stack manipulation
(``LPush``/``LPop``) plus ``LPushJump``/``LReturn`` for the program counter,
exactly as in the paper's Figure 4.  Variable names are qualified as
``"<function>/<var>"`` so namespaces never collide across functions.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax

# --------------------------------------------------------------------------
# Source IR (paper Fig. 2)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Prim:
    """``outs = fn(*ins)`` — a pure, per-batch-member computation.

    ``fn`` consumes/produces *unbatched* values; the runtimes batch it with
    ``jax.vmap`` unless ``batched=True``, in which case ``fn`` is expected to
    handle a leading batch dimension itself (useful when a hand-batched
    implementation is cheaper, e.g. matmul-heavy primitives).
    """

    outs: tuple[str, ...]
    fn: Callable[..., Any]
    ins: tuple[str, ...]
    name: str = "prim"
    batched: bool = False
    # Tag used by instrumentation (e.g. counting gradient evaluations).
    tag: Optional[str] = None


@dataclass(frozen=True)
class Call:
    """``outs = callee(*ins)`` — call to another autobatched function."""

    outs: tuple[str, ...]
    callee: str
    ins: tuple[str, ...]


@dataclass(frozen=True)
class Jump:
    target: int


@dataclass(frozen=True)
class Branch:
    """Two-way branch on a per-member boolean variable."""

    var: str
    true: int
    false: int


@dataclass(frozen=True)
class Return:
    pass


Terminator = Jump | Branch | Return
Op = Prim | Call


@dataclass
class Block:
    ops: list[Op] = field(default_factory=list)
    term: Optional[Terminator] = None
    label: str = ""


@dataclass(frozen=True)
class ArgBinding:
    """How one positional pytree argument binds to IR parameters.

    ``params`` are the IR parameter names consumed by the argument's leaves,
    in pytree flatten order.  ``shared`` arguments carry no batch axis at
    call time; the caller broadcasts them across the batch.
    """

    params: tuple[str, ...]
    treedef: Any
    shared: bool = False


@dataclass(frozen=True)
class Interface:
    """Pytree calling convention of a function (recorded by the public API).

    ``args`` maps positional pytree arguments onto flat IR parameters;
    ``out_treedef``/``out_leaves`` describe how the flat IR outputs are
    reassembled into the result pytree.
    """

    args: tuple[ArgBinding, ...]
    out_treedef: Any
    out_leaves: tuple[str, ...]


@dataclass
class Function:
    """A function in the source IR.

    ``param_specs`` / ``output_specs`` are ``jax.ShapeDtypeStruct`` per
    *batch member* (no batch dimension).  Output specs must be declared
    because recursive functions cannot have their output types inferred by a
    simple forward pass; everything else is inferred (see typecheck.py).

    ``iface``, when present, records the pytree calling convention the
    public :mod:`repro.core.batching` API uses to flatten positional pytree
    arguments into ``params`` and unflatten ``outputs`` into a result tree.
    """

    name: str
    params: tuple[str, ...]
    outputs: tuple[str, ...]
    blocks: list[Block] = field(default_factory=list)
    param_specs: dict[str, jax.ShapeDtypeStruct] = field(default_factory=dict)
    output_specs: dict[str, jax.ShapeDtypeStruct] = field(default_factory=dict)
    # Filled by type inference: spec for every local variable.
    var_specs: dict[str, jax.ShapeDtypeStruct] = field(default_factory=dict)
    # Optional pytree calling convention (see Interface).
    iface: Optional[Interface] = None

    def validate(self) -> None:
        for i, blk in enumerate(self.blocks):
            if blk.term is None:
                raise ValueError(f"{self.name}: block {i} has no terminator")
            for tgt in _targets(blk.term):
                if not (0 <= tgt < len(self.blocks)):
                    raise ValueError(
                        f"{self.name}: block {i} jumps to out-of-range {tgt}"
                    )
        for p in self.params:
            if p not in self.param_specs:
                raise ValueError(f"{self.name}: missing param spec for {p!r}")
        for o in self.outputs:
            if o not in self.output_specs:
                raise ValueError(f"{self.name}: missing output spec for {o!r}")


@dataclass
class Program:
    functions: dict[str, Function]
    main: str

    def validate(self) -> None:
        if self.main not in self.functions:
            raise ValueError(f"main function {self.main!r} not defined")
        for fn in self.functions.values():
            fn.validate()
            for blk in fn.blocks:
                for op in blk.ops:
                    if isinstance(op, Call) and op.callee not in self.functions:
                        raise ValueError(
                            f"{fn.name}: call to undefined function {op.callee!r}"
                        )


def _targets(term: Terminator) -> tuple[int, ...]:
    if isinstance(term, Jump):
        return (term.target,)
    if isinstance(term, Branch):
        return (term.true, term.false)
    return ()


def successors(blocks: list[Block], i: int) -> tuple[int, ...]:
    return _targets(blocks[i].term)


# --------------------------------------------------------------------------
# Lowered IR (paper Fig. 4)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class LPrim:
    """Masked in-place update of the tops of ``outs`` (paper's ``Update``)."""

    outs: tuple[str, ...]
    fn: Callable[..., Any]
    ins: tuple[str, ...]
    name: str = "prim"
    batched: bool = False
    tag: Optional[str] = None


@dataclass(frozen=True)
class LPush:
    """Bury the current top of ``var`` and set the new top to ``src``'s top.

    With ``src == var`` this duplicates the top (a caller-save).  With
    ``src != var`` it is argument passing into a recursive frame.
    """

    var: str
    src: str


@dataclass(frozen=True)
class LPop:
    """Restore ``var``'s top from its stack."""

    var: str


@dataclass(frozen=True)
class LJump:
    target: int


@dataclass(frozen=True)
class LBranch:
    var: str
    true: int
    false: int


@dataclass(frozen=True)
class LPushJump:
    """Enter a function body: bury ``ret`` on the pc stack, jump to ``target``.

    Algorithm 2: ``Set pc_top = ret; PUSH target onto pc``.
    """

    target: int
    ret: int


@dataclass(frozen=True)
class LReturn:
    """Pop the pc stack (control resumes at the buried return address)."""


LTerminator = LJump | LBranch | LPushJump | LReturn
LOp = LPrim | LPush | LPop


@dataclass
class LBlock:
    ops: list[LOp] = field(default_factory=list)
    term: Optional[LTerminator] = None
    label: str = ""


@dataclass(frozen=True)
class StateLayout:
    """Packed VM-state layout produced by ``StateLayoutPacking``.

    ``groups`` maps each packed array variable (a synthetic
    ``%pgo/pack<N>`` name with spec ``(k,) + member_shape``) to its member
    variables in slot order.  A member's top lives at ``tops[packed][:, slot]``
    instead of its own ``tops[member]`` buffer; inside a block the members
    are materialized by an ``unpack`` prim and written back by a single
    ``pack`` prim, so every boundary surface (inject/park/outputs/stepper,
    mesh sharding, stack kernels) reads and writes through this mapping.
    """

    groups: dict[str, tuple[str, ...]]

    def members(self) -> frozenset[str]:
        return frozenset(m for ms in self.groups.values() for m in ms)

    def slot_of(self, var: str) -> Optional[tuple[str, int]]:
        """``(packed_var, slot)`` for a member, else ``None``."""
        for packed, ms in self.groups.items():
            if var in ms:
                return packed, ms.index(var)
        return None


@dataclass
class LoweredProgram:
    """The merged, stack-explicit program that the PC VM executes."""

    blocks: list[LBlock]
    entry: int
    main_params: tuple[str, ...]  # qualified names
    main_outputs: tuple[str, ...]  # qualified names
    var_specs: dict[str, jax.ShapeDtypeStruct]
    stack_vars: frozenset[str]  # vars that need a stack (paper opt. iii)
    temp_vars: frozenset[str]  # block-local temporaries (paper opt. ii)
    func_entries: dict[str, int]  # function name -> entry block index
    # Superblock-fusion provenance (fusion.py): new block index -> the
    # original (pre-fusion) block indices whose ops it concatenates, in
    # execution order.  ``None`` when the program was never fused.
    fused_from: Optional[dict[int, tuple[int, ...]]] = None
    # Profile-guided-optimization provenance.  ``block_weights[i]`` is the
    # profile-estimated dispatch count of block ``i`` (seeded by
    # ``ProfileGuidedFusion`` from a ``BlockProfile`` and propagated through
    # every renumbering pass); ``None`` when the program is unprofiled.
    block_weights: Optional[tuple[int, ...]] = None
    # ``BlockReordering`` permutation: ``block_order[new] = old`` index in
    # the program that pass consumed.  ``None`` when never reordered.
    block_order: Optional[tuple[int, ...]] = None
    # Packed-state layout recorded by ``StateLayoutPacking`` (see
    # :class:`StateLayout`); ``None`` when state is unpacked.
    state_layout: Optional[StateLayout] = None

    @property
    def exit_index(self) -> int:
        """Sentinel pc value meaning "this member has halted"."""
        return len(self.blocks)

    def var_class(self, var: str) -> str:
        """``"stack"`` (has a stack + pointer), ``"temp"`` (block-local,
        never enters VM state) or ``"state"`` (masked top buffer only)."""
        if var in self.stack_vars:
            return "stack"
        if var in self.temp_vars:
            return "temp"
        return "state"

    def pretty(self) -> str:
        lines = []
        if self.block_order is not None:
            perm = ",".join(str(o) for o in self.block_order)
            lines.append(f"reordered: [{perm}]   <new index -> old index>")
        if self.state_layout is not None:
            for packed, members in self.state_layout.groups.items():
                lines.append(
                    f"layout {packed}: [{', '.join(members)}]"
                )
        rev_entries = {v: k for k, v in self.func_entries.items()}
        for i, blk in enumerate(self.blocks):
            hdr = f"[{i}] {blk.label}"
            if i in rev_entries:
                hdr += f"   <entry of {rev_entries[i]}>"
            if self.fused_from is not None and i in self.fused_from:
                srcs = ",".join(str(s) for s in self.fused_from[i])
                hdr += f"   <fused from {srcs}>"
            if self.block_weights is not None:
                hdr += f"   <weight {self.block_weights[i]}>"
            lines.append(hdr)
            for op in blk.ops:
                if isinstance(op, LPrim):
                    lines.append(
                        f"    {', '.join(op.outs)} = {op.name}({', '.join(op.ins)})"
                    )
                elif isinstance(op, LPush):
                    lines.append(f"    push {op.var} <- {op.src}")
                elif isinstance(op, LPop):
                    lines.append(f"    pop  {op.var}")
            t = blk.term
            if isinstance(t, LJump):
                lines.append(f"    jump {t.target}")
            elif isinstance(t, LBranch):
                lines.append(f"    branch {t.var} ? {t.true} : {t.false}")
            elif isinstance(t, LPushJump):
                lines.append(f"    pushjump {t.target} (ret {t.ret})")
            elif isinstance(t, LReturn):
                lines.append("    return")
        lines.append("vars:")
        for v in sorted(self.var_specs):
            spec = self.var_specs[v]
            lines.append(
                f"    {v}: {self.var_class(v)} "
                f"{tuple(spec.shape)} {spec.dtype}"
            )
        return "\n".join(lines)


def qualify(func: str, var: str) -> str:
    return f"{func}/{var}"


def prim_reads(op: LOp) -> tuple[str, ...]:
    if isinstance(op, LPrim):
        return op.ins
    if isinstance(op, LPush):
        return (op.src,)
    return ()


def prim_writes(op: LOp) -> tuple[str, ...]:
    if isinstance(op, LPrim):
        return op.outs
    if isinstance(op, (LPush, LPop)):
        return (op.var,)
    return ()


def identity_prim(out: str, src: str, name: str = "copy") -> LPrim:
    return LPrim(outs=(out,), fn=lambda x: x, ins=(src,), name=name)


def dataclass_replace(obj, **kw):
    return dataclasses.replace(obj, **kw)
