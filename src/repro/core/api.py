"""Legacy dict-based API of the autobatching core (deprecated shim).

.. deprecated::
    This module is kept as a thin compatibility shim.  New code should use
    the decorator-first, pytree-native API in :mod:`repro.core.batching`::

        from repro.core.batching import autobatch, Batched, Shared

    which accepts positional pytree arguments, caches compiled artifacts
    across batch sizes, and unifies the two frontends.

Legacy usage::

    from repro.core import api, frontend

    pb = frontend.ProgramBuilder()
    ... build functions ...
    program = pb.build()

    batched = api.autobatch(program, batch_size=1024, backend="pc")
    result = batched(inputs)          # dict of [batch, ...] outputs

Backends
--------
``pc``           Program-counter autobatching (Algorithm 2): one fused
                 ``lax.while_loop`` — compiles end-to-end with XLA, batches
                 across recursion depths.  The paper's contribution.
``local``        Local static autobatching (Algorithm 1), "hybrid" flavor:
                 host-Python control, jitted block bodies.
``local_eager``  Local static autobatching with op-by-op dispatch (the
                 paper's eager arm).
``reference``    Unbatched oracle (per-member Python recursion).
"""
from __future__ import annotations

import warnings
from typing import Any, Optional

from . import fusion, ir, local_static, lowering, pc_vm, reference

BACKENDS = ("pc", "local", "local_eager", "reference")


class BatchedProgram:
    def __init__(
        self,
        program: ir.Program,
        batch_size: int,
        backend: str = "pc",
        max_depth: int = 32,
        max_steps: int = 1_000_000,
        use_kernel: bool = False,
        collect_stats: bool = True,
        schedule: str = "earliest",
        fuse: bool = False,  # legacy shim keeps the seed's unfused default
        mesh=None,  # lane sharding: None | device count | 1-D Mesh
        verify: bool = False,  # run the lowered-IR verifier between passes
        compact_every: Optional[int] = None,  # lane compaction cadence
    ):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
        self.program = program
        self.backend = backend
        self.batch_size = batch_size
        self.main = program.functions[program.main]
        self.last_result: Optional[pc_vm.VMResult] = None
        if backend == "pc":
            self.lowered = lowering.lower(program, verify=verify)
            if fuse:
                self.lowered = fusion.fuse(self.lowered, verify=verify)
            self.vm = pc_vm.ProgramCounterVM(
                self.lowered,
                pc_vm.VMConfig(
                    batch_size=batch_size,
                    max_depth=max_depth,
                    max_steps=max_steps,
                    use_kernel=use_kernel,
                    collect_block_stats=collect_stats,
                    schedule=schedule,
                    mesh=mesh,
                    compact_every=compact_every,
                ),
            )
        elif backend in ("local", "local_eager"):
            self.batcher = local_static.LocalStaticBatcher(
                program, batch_size, jit_blocks=(backend == "local")
            )
        # "reference" needs no preparation.
        self._ran = False

    def __call__(self, inputs: dict[str, Any]) -> dict[str, Any]:
        self._ran = True
        if self.backend in ("local", "local_eager"):
            # Per-run counters, matching the pc backend's last_result
            # semantics (the batcher accumulates across runs by itself).
            self.batcher.stats = local_static.LocalStats()
        if self.backend == "pc":
            # Qualify input names for the merged namespace.
            q = {
                ir.qualify(self.program.main, k): v for k, v in inputs.items()
            }
            res = self.vm.run(q)
            self.last_result = res
            return {
                k.split("/", 1)[1]: v for k, v in res.outputs.items()
            }
        if self.backend in ("local", "local_eager"):
            return self.batcher.run(inputs)
        return reference.run_reference_batch(self.program, inputs)

    # ------------------------------------------------------------------
    # Introspection / AOT
    # ------------------------------------------------------------------

    def lower_aot(self, inputs: dict[str, Any]):
        """AOT-lower the full batched computation (pc backend only)."""
        if self.backend != "pc":
            raise ValueError("AOT lowering requires the 'pc' backend")
        q = {ir.qualify(self.program.main, k): v for k, v in inputs.items()}
        return self.vm.lower(q)

    @property
    def utilization(self) -> dict[str, float]:
        """Per-tag batch utilization of the last run (paper Figure 6).

        ``utilization[tag] = active_member_evals / (executions * batch_size)``.

        Semantics (identical on every backend): before any run, returns
        ``{}``; after a run, every tag the program executed maps to a float
        in ``[0, 1]`` (``0.0`` for tags that executed with no active
        members).  The ``reference`` backend keeps no counters and always
        returns ``{}``.
        """
        if not self._ran:
            return {}
        if self.backend == "pc":
            if self.last_result is None:
                return {}
            return {
                tag: act / (ex * self.batch_size) if ex else 0.0
                for tag, (ex, act) in self.last_result.tag_stats.items()
            }
        if self.backend in ("local", "local_eager"):
            st = self.batcher.stats
            return {
                tag: st.tag_active.get(tag, 0)
                / (st.tag_execs[tag] * self.batch_size)
                if st.tag_execs.get(tag)
                else 0.0
                for tag in st.tag_execs
            }
        return {}


def autobatch(
    program: ir.Program, batch_size: int, backend: str = "pc", **kw
) -> BatchedProgram:
    """Deprecated: use :func:`repro.core.batching.autobatch` instead.

    Kept as a thin shim over :class:`BatchedProgram` for callers still on
    the dict-of-names calling convention.  Semantics match the pytree API
    with two legacy differences: ``fuse`` defaults to ``False`` (the seed's
    unfused lowering), and stack overflow is *contained* rather than
    raised — overflowed members return invalid results, flagged per member
    in ``last_result.depth_exceeded``, while other members stay exact.
    The pc knobs (``schedule``, ``fuse``, ``use_kernel``, ``mesh``) pass
    through unchanged; ``utilization``/``tag_stats`` cover the most recent
    call only, identically on every backend (``{}`` before any run).
    """
    warnings.warn(
        "repro.core.api.autobatch is deprecated; use the pytree-native "
        "repro.core.batching.autobatch instead",
        DeprecationWarning,
        stacklevel=2,
    )
    return BatchedProgram(program, batch_size, backend=backend, **kw)
