"""Lowering from the source CFG IR (Fig. 2) to the stack-explicit merged
program (Fig. 4) that the program-counter VM executes.

The lowering implements the paper's calling convention and compiler
optimizations:

* **Caller-saves, per-variable stacks** (opt. i): at each call site that can
  re-enter the caller's frame, the caller pushes every variable that is live
  after the call (minus the call's outputs).  Argument passing into a
  recursive callee is itself a push onto the parameter's stack (burying the
  outer frame's value); the caller pops everything it pushed after the call
  returns.
* **Temporaries** (opt. ii): variables whose every read is preceded by a
  write within the same lowered block never enter VM state at all — they are
  ordinary intermediate values inside the fused block body.
* **Stack only when needed** (opt. iii): variables that are never pushed or
  popped get no stack or stack pointer; updates mask their cached top only.
* **Top-of-stack caching** (opt. iv): structural in the VM — every variable's
  current value lives in a dense ``[batch, ...]`` "top" buffer; the
  ``[depth, batch, ...]`` stack array is touched only by pushes and pops.
* **Pop-push elimination** (opt. v): within a block, ``pop v`` followed by
  ``push v <- src`` (``src != v``) with no intervening mention of ``v``
  cancels into a masked in-place update of the top.  This fires exactly in
  the hot "sequence of sibling calls" pattern (e.g. NUTS's two ``build_tree``
  recursions).
"""
from __future__ import annotations

import itertools
from typing import Any

from . import analysis, ir

# Symbolic jump targets used during emission, patched at the end:
#   ("blk", fname, orig_block_idx)  -> lowered index of that block's head
#   ("entry", fname)                -> lowered entry of fname
#   int                             -> already-concrete lowered index
_Sym = Any


def lower(
    program: ir.Program, *, verify: bool = False
) -> ir.LoweredProgram:
    """Lower ``program`` to the stack-explicit merged form.

    Emission is followed by the block-local optimization passes
    (``passes.lowering_passes()``: pop-push elimination, temp detection).
    With ``verify=True`` the lowered-IR verifier runs on the raw emission
    and between every pass.
    """
    program.validate()
    analysis.infer_types(program)
    cg = analysis.CallGraph(program)

    lowered: list[ir.LBlock] = []
    blockmap: dict[tuple[str, int], int] = {}
    func_entries: dict[str, int] = {}
    tmp_counter = itertools.count()

    def fresh(fname: str) -> str:
        return ir.qualify(fname, f"%arg{next(tmp_counter)}")

    # Qualified specs for every variable (temps added as we emit them).
    var_specs: dict[str, Any] = {}
    for fname, func in program.functions.items():
        for v, spec in func.var_specs.items():
            var_specs[ir.qualify(fname, v)] = spec

    for fname, func in program.functions.items():
        q = lambda v, _f=fname: ir.qualify(_f, v)
        lv = analysis.Liveness(func)
        for bi, blk in enumerate(func.blocks):
            cur = ir.LBlock(label=f"{fname}.{bi}")
            blockmap[(fname, bi)] = len(lowered)
            if bi == 0:
                func_entries[fname] = len(lowered)
            lowered.append(cur)
            for oi, op in enumerate(blk.ops):
                if isinstance(op, ir.Prim):
                    cur.ops.append(
                        ir.LPrim(
                            outs=tuple(q(o) for o in op.outs),
                            fn=op.fn,
                            ins=tuple(q(i) for i in op.ins),
                            name=op.name,
                            batched=op.batched,
                            tag=op.tag,
                        )
                    )
                    continue
                # ---- Call lowering ----
                callee = program.functions[op.callee]
                reenters = cg.can_reenter(fname, op.callee)
                recursive = cg.is_recursive(op.callee)
                # Save set: caller vars live after the call, minus the call's
                # own outputs, minus callee params (recursive self-calls pass
                # args by pushing the param itself, which is the save).
                saves: list[str] = []
                if reenters:
                    live = lv.live_after(bi, oi) - set(op.outs)
                    if op.callee == fname:
                        live -= set(callee.params)
                    saves = sorted(q(v) for v in live)
                # Argument values: route through fresh temps when the callee
                # is the caller (param writes could clobber arg reads).
                arg_srcs: list[str] = []
                for a in op.ins:
                    if op.callee == fname:
                        t = fresh(fname)
                        var_specs[t] = func.var_specs[a]
                        cur.ops.append(ir.identity_prim(t, q(a), name="argcopy"))
                        arg_srcs.append(t)
                    else:
                        arg_srcs.append(q(a))
                for v in saves:
                    cur.ops.append(ir.LPush(var=v, src=v))
                pushed_params: list[str] = []
                for p, src in zip(callee.params, arg_srcs):
                    pq = ir.qualify(op.callee, p)
                    if recursive:
                        cur.ops.append(ir.LPush(var=pq, src=src))
                        pushed_params.append(pq)
                    else:
                        cur.ops.append(ir.identity_prim(pq, src, name="argset"))
                ret_idx = len(lowered)
                cur.term = ir.LPushJump(target=("entry", op.callee), ret=ret_idx)
                # ---- Return-site block ----
                cur = ir.LBlock(label=f"{fname}.{bi}.ret{oi}")
                lowered.append(cur)
                for y, o in zip(op.outs, callee.outputs):
                    cur.ops.append(
                        ir.identity_prim(q(y), ir.qualify(op.callee, o), name="retval")
                    )
                for pq in reversed(pushed_params):
                    cur.ops.append(ir.LPop(var=pq))
                for v in reversed(saves):
                    cur.ops.append(ir.LPop(var=v))
            # ---- Original terminator ----
            t = blk.term
            if isinstance(t, ir.Jump):
                cur.term = ir.LJump(target=("blk", fname, t.target))
            elif isinstance(t, ir.Branch):
                cur.term = ir.LBranch(
                    var=q(t.var),
                    true=("blk", fname, t.true),
                    false=("blk", fname, t.false),
                )
            elif isinstance(t, ir.Return):
                cur.term = ir.LReturn()
            else:
                raise ValueError(
                    f"unterminated block {fname}.{bi} "
                    f"({blk.label or 'unlabeled'}): terminator {t!r} is not "
                    "a Jump, Branch or Return"
                )

    _patch_targets(lowered, blockmap, func_entries)

    stack_vars = frozenset(
        op.var
        for blk in lowered
        for op in blk.ops
        if isinstance(op, (ir.LPush, ir.LPop))
    )
    main = program.functions[program.main]
    main_params = tuple(ir.qualify(program.main, p) for p in main.params)
    main_outputs = tuple(ir.qualify(program.main, o) for o in main.outputs)
    temp_vars = find_temporaries(lowered, stack_vars, main_params, main_outputs)

    raw = ir.LoweredProgram(
        blocks=lowered,
        entry=func_entries[program.main],
        main_params=main_params,
        main_outputs=main_outputs,
        var_specs=var_specs,
        stack_vars=stack_vars,
        temp_vars=temp_vars,
        func_entries=func_entries,
    )
    # The block-local optimizations ((v) pop-push elimination, (ii) temp
    # detection) run as pipeline passes over the raw emission.
    from . import passes  # deferred: passes imports this module

    pipeline = passes.PassPipeline(
        passes.lowering_passes(), verify=verify, debug=verify
    )
    return pipeline.run(raw)


def _resolve(sym: _Sym, blockmap, func_entries) -> int:
    if isinstance(sym, int):
        return sym
    kind = sym[0]
    if kind == "blk":
        return blockmap[(sym[1], sym[2])]
    if kind == "entry":
        return func_entries[sym[1]]
    raise AssertionError(sym)


def _patch_targets(lowered, blockmap, func_entries) -> None:
    for i, blk in enumerate(lowered):
        t = blk.term
        if isinstance(t, ir.LJump):
            blk.term = ir.LJump(_resolve(t.target, blockmap, func_entries))
        elif isinstance(t, ir.LBranch):
            blk.term = ir.LBranch(
                var=t.var,
                true=_resolve(t.true, blockmap, func_entries),
                false=_resolve(t.false, blockmap, func_entries),
            )
        elif isinstance(t, ir.LPushJump):
            blk.term = ir.LPushJump(
                target=_resolve(t.target, blockmap, func_entries),
                ret=_resolve(t.ret, blockmap, func_entries),
            )


def popush_eliminate(lowered: list[ir.LBlock]) -> None:
    """Paper optimization (v): cancel ``pop v ... push v <- src`` pairs.

    Sound when nothing between the pop and the push mentions ``v`` (read or
    write) and ``src != v``.  The pair is replaced by a masked in-place
    update of the top (an identity LPrim at the push's position).
    """
    for blk in lowered:
        changed = True
        while changed:
            changed = False
            ops = blk.ops
            for i, op in enumerate(ops):
                if not isinstance(op, ir.LPop):
                    continue
                v = op.var
                for j in range(i + 1, len(ops)):
                    mentions = set(ir.prim_reads(ops[j])) | set(
                        ir.prim_writes(ops[j])
                    )
                    if isinstance(ops[j], ir.LPush) and ops[j].var == v:
                        if ops[j].src != v:
                            # Cancel: drop the pop, update in place.
                            new_ops = (
                                ops[:i]
                                + ops[i + 1 : j]
                                + [ir.identity_prim(v, ops[j].src, name="popush")]
                                + ops[j + 1 :]
                            )
                            blk.ops = new_ops
                            changed = True
                        break
                    if v in mentions:
                        break
                if changed:
                    break


def recompute_var_classes(
    blocks, main_params, main_outputs, state_layout=None
) -> tuple[frozenset[str], frozenset[str]]:
    """Re-derive ``(stack_vars, temp_vars)`` for a transformed block list.

    One shared implementation for every pass that rewrites blocks (jump-chain
    fusion, pop-push elimination, temp detection, the PGO passes): the pushed/
    popped set is re-scanned from the ops and temporaries re-detected, with
    packed-layout members (``state_layout``) always block-local.
    """
    stack_vars = frozenset(
        op.var
        for blk in blocks
        for op in blk.ops
        if isinstance(op, (ir.LPush, ir.LPop))
    )
    temp_vars = find_temporaries(
        blocks, stack_vars, main_params, main_outputs,
        state_layout=state_layout,
    )
    return stack_vars, temp_vars


def find_temporaries(
    lowered, stack_vars, main_params, main_outputs, *, state_layout=None
) -> frozenset[str]:
    """Paper optimization (ii): variables that never cross a VM iteration.

    Syntactic criterion: in every block that mentions the variable, each read
    (including a terminator read) is preceded by a write within that same
    block.  Such variables are ordinary intermediates of the fused block body
    and need no masked top buffer in VM state.

    Members of a packed ``state_layout`` group are exempt from the
    main-param/output exclusion: their cross-block value lives in the packed
    array (written back by the group's ``pack`` prim), so the members
    themselves are block-local by construction.
    """
    not_temp: set[str] = set(stack_vars) | set(main_params) | set(main_outputs)
    if state_layout is not None:
        not_temp -= state_layout.members()
    mentioned: set[str] = set()
    for blk in lowered:
        written: set[str] = set()
        for op in blk.ops:
            for r in ir.prim_reads(op):
                mentioned.add(r)
                if r not in written:
                    not_temp.add(r)
            for w in ir.prim_writes(op):
                mentioned.add(w)
                written.add(w)
        if isinstance(blk.term, ir.LBranch):
            mentioned.add(blk.term.var)
            if blk.term.var not in written:
                not_temp.add(blk.term.var)
    return frozenset(mentioned - not_temp)
