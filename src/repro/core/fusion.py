"""Superblock fusion over the lowered, stack-explicit program.

The program-counter VM (paper Algorithm 2) dispatches exactly one lowered
block per ``lax.while_loop`` iteration, so every block boundary costs a full
dispatch round-trip: a global argmin/argmax over the batch's pc values, one
``lax.switch``, and a masked update of the whole VM state.  The lowering of
``Call`` (lowering.py) emits many *tiny* straight-line blocks — argcopy
glue, retval/pop return sites, loop-header hops — that make the hot loop
pay that round-trip for a handful of element-wise ops.

This pass shrinks the block graph to its control-relevant skeleton by
**jump-chain fusion**:

* a block whose terminator is an unconditional ``LJump`` absorbs its
  target's ops and adopts its terminator, iterated to a fixed point along
  the chain (stopping at conditional branches, call/return boundaries, and
  cycles);
* when the target had that single jump predecessor, this is a pure merge
  and the target block disappears;
* when the target is a join block with several jump predecessors (the
  common shape: both arms of an ``if`` jump to the join), its ops are
  *tail-duplicated* into each predecessor; the join block itself is removed
  once no conditional branch or call/return site still enters it.

Blocks whose index is load-bearing are pinned and never absorbed: the
program entry, function entries (``LPushJump`` targets), and return sites
(``LPushJump.ret``, entered dynamically by ``LReturn``).

Fusion is a pure CFG transformation of per-member straight-line code under
one mask, so batched execution is **bit-exact**: each member executes the
same primitive sequence in the same order as in the unfused program, only
with fewer VM dispatch steps.  Relation to the paper's optimizations
(i)–(v): fusion runs *after* the lowering already applied (i) caller-save
stacks, (iii) stack-only-when-needed and (iv) top-of-stack caching, and it
re-runs (v) pop-push elimination and (ii) temporary detection on the merged
superblocks — a pop/push pair or a def-before-use chain that used to span
a block boundary becomes block-local, so the pair cancels and the variable
drops out of VM state entirely.

Entry point: :func:`fuse`, which since the pass-pipeline refactor is just
``passes.PassPipeline(passes.fusion_passes())`` — :func:`fuse_chains` here
is the chain-concatenation step (the ``JumpChainFusion`` pass), and the
block-local re-optimizations are the shared ``PopPushElimination`` /
``TempDetection`` passes.  Provenance is recorded on
``LoweredProgram.fused_from`` (new block index -> original indices), which
the VM surfaces in its per-run scheduler stats.
"""
from __future__ import annotations

from . import analysis, ir, lowering


def fuse(
    low: ir.LoweredProgram, *, verify: bool = False
) -> ir.LoweredProgram:
    """Return a semantically identical program with fused superblocks.

    The input is not mutated.  ``fused_from`` on the result maps each new
    block index to the tuple of input block indices whose ops it
    concatenates (composed through an already-fused input).  With
    ``verify=True`` the lowered-IR verifier runs between every pass of the
    fusion pipeline (see passes.py).
    """
    from . import passes  # deferred: passes imports this module

    pipeline = passes.PassPipeline(
        passes.fusion_passes(), verify=verify, debug=verify
    )
    return pipeline.run(low)


def fuse_chains(low: ir.LoweredProgram) -> ir.LoweredProgram:
    """Jump-chain fusion proper (the ``JumpChainFusion`` pass body):
    concatenate unconditional jump chains, drop unreachable blocks, compact
    indices and record provenance.  Variable classes are recomputed so the
    result is self-consistent, but the block-local optimizations (popush
    elimination, temp detection on the merged superblocks) are separate
    passes.
    """
    blocks = low.blocks
    n = len(blocks)
    pinned = analysis.pinned_blocks(low)

    # ---- 1. Follow every unconditional jump chain, concatenating ops. ----
    # Chains are followed over the *original* blocks so the result is
    # independent of processing order; cycles and pinned targets cut them.
    fused: list[ir.LBlock] = []
    sources: list[tuple[int, ...]] = []
    for i, blk in enumerate(blocks):
        ops = list(blk.ops)
        term = blk.term
        label = blk.label
        chain = [i]
        while (
            isinstance(term, ir.LJump)
            and term.target not in pinned
            and term.target not in chain
        ):
            nxt = blocks[term.target]
            chain.append(term.target)
            ops.extend(nxt.ops)
            label = f"{label}+{nxt.label}"
            term = nxt.term
        fused.append(ir.LBlock(ops=ops, term=term, label=label))
        sources.append(tuple(chain))

    # ---- 2. Drop blocks no longer reachable from any control root. ----
    # Roots are the program entry plus every function entry (a function
    # may be registered without being called; keep its body addressable).
    roots = {low.entry} | set(low.func_entries.values())
    reachable: set[int] = set()
    stack = list(roots)
    while stack:
        b = stack.pop()
        if b in reachable:
            continue
        reachable.add(b)
        stack.extend(analysis.lowered_targets(fused[b].term))

    # ---- 3. Compact indices and retarget terminators. ----
    index: dict[int, int] = {}
    new_blocks: list[ir.LBlock] = []
    fused_from: dict[int, tuple[int, ...]] = {}
    for i in range(n):
        if i not in reachable:
            continue
        index[i] = len(new_blocks)
        new_blocks.append(fused[i])
        srcs = sources[i]
        if low.fused_from is not None:  # compose through a prior fusion
            srcs = tuple(s for j in srcs for s in low.fused_from[j])
        fused_from[index[i]] = srcs
    for blk in new_blocks:
        t = blk.term
        if isinstance(t, ir.LJump):
            blk.term = ir.LJump(index[t.target])
        elif isinstance(t, ir.LBranch):
            blk.term = ir.LBranch(
                var=t.var, true=index[t.true], false=index[t.false]
            )
        elif isinstance(t, ir.LPushJump):
            blk.term = ir.LPushJump(
                target=index[t.target], ret=index[t.ret]
            )

    # Recompute the variable classes for the merged blocks (dropping an
    # unreachable block can shrink the pushed/popped set).  The block-local
    # re-optimizations — (v) popush pairs newly confined to one superblock,
    # (ii) temp detection on the merged bodies — run as their own passes.
    stack_vars, temp_vars = lowering.recompute_var_classes(
        new_blocks, low.main_params, low.main_outputs,
        state_layout=low.state_layout,
    )

    # Profile weights survive the renumbering: a merged chain is dispatched
    # exactly as often as its head block was.
    block_weights = None
    if low.block_weights is not None:
        block_weights = tuple(
            low.block_weights[i] for i in range(n) if i in index
        )

    return ir.LoweredProgram(
        blocks=new_blocks,
        entry=index[low.entry],
        main_params=low.main_params,
        main_outputs=low.main_outputs,
        var_specs=low.var_specs,
        stack_vars=stack_vars,
        temp_vars=temp_vars,
        func_entries={f: index[e] for f, e in low.func_entries.items()},
        fused_from=fused_from,
        block_weights=block_weights,
        state_layout=low.state_layout,
    )
