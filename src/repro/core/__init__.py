"""Autobatching core — the paper's primary contribution.

Source IR (Fig. 2) -> lowering with the five compiler optimizations ->
either the host-recursive local-static interpreter (Algorithm 1) or the
fully-compiled program-counter VM (Algorithm 2).  Every lowered-IR
transform runs as a pass in :mod:`passes`, with executable invariants in
:mod:`verifier`.
"""
from . import (
    analysis,
    api,
    ast_frontend,
    batching,
    frontend,
    fusion,
    ir,
    local_static,
    lowering,
    passes,
    pc_vm,
    reference,
    verifier,
)
from .api import BatchedProgram
from .ast_frontend import Namespace
from .batching import AutobatchedFunction, Batched, Shared, autobatch
from .frontend import BOOL, F32, I32, FunctionBuilder, ProgramBuilder, spec
from .passes import PassError, PassPipeline
from .verifier import VerificationError, verify

__all__ = [
    "analysis",
    "api",
    "ast_frontend",
    "autobatch",
    "AutobatchedFunction",
    "Batched",
    "BatchedProgram",
    "batching",
    "BOOL",
    "F32",
    "frontend",
    "FunctionBuilder",
    "fusion",
    "I32",
    "ir",
    "local_static",
    "lowering",
    "Namespace",
    "PassError",
    "passes",
    "PassPipeline",
    "pc_vm",
    "ProgramBuilder",
    "reference",
    "Shared",
    "spec",
    "VerificationError",
    "verifier",
    "verify",
]
