"""Autobatching core — the paper's primary contribution.

Source IR (Fig. 2) -> lowering with the five compiler optimizations ->
either the host-recursive local-static interpreter (Algorithm 1) or the
fully-compiled program-counter VM (Algorithm 2).
"""
from . import (
    analysis,
    api,
    ast_frontend,
    batching,
    frontend,
    ir,
    local_static,
    lowering,
    pc_vm,
    reference,
)
from .api import BatchedProgram
from .ast_frontend import Namespace
from .batching import AutobatchedFunction, Batched, Shared, autobatch
from .frontend import BOOL, F32, I32, FunctionBuilder, ProgramBuilder, spec

__all__ = [
    "analysis",
    "api",
    "ast_frontend",
    "autobatch",
    "AutobatchedFunction",
    "Batched",
    "BatchedProgram",
    "batching",
    "BOOL",
    "F32",
    "frontend",
    "FunctionBuilder",
    "I32",
    "ir",
    "local_static",
    "lowering",
    "Namespace",
    "pc_vm",
    "ProgramBuilder",
    "reference",
    "Shared",
    "spec",
]
