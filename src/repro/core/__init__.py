"""Autobatching core — the paper's primary contribution.

Source IR (Fig. 2) -> lowering with the five compiler optimizations ->
either the host-recursive local-static interpreter (Algorithm 1) or the
fully-compiled program-counter VM (Algorithm 2).
"""
from . import analysis, api, frontend, ir, local_static, lowering, pc_vm, reference
from .api import BatchedProgram, autobatch
from .frontend import BOOL, F32, I32, FunctionBuilder, ProgramBuilder, spec

__all__ = [
    "analysis",
    "api",
    "autobatch",
    "BatchedProgram",
    "BOOL",
    "F32",
    "frontend",
    "FunctionBuilder",
    "I32",
    "ir",
    "local_static",
    "lowering",
    "pc_vm",
    "ProgramBuilder",
    "reference",
    "spec",
]
