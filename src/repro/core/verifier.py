"""Executable invariants of the lowered, stack-explicit IR.

The paper's transformation is only sound if the lowered program
(``ir.LoweredProgram``) stays semantically equivalent to the source
program while lowering, fusion and the other pipeline passes rewrite it.
:func:`verify` checks every invariant those transforms rely on:

* **CFG well-formedness** — every block has a lowered terminator, every
  terminator target (including ``LPushJump.ret``) is in range, every
  ``LPushJump`` targets a function entry, and every load-bearing block
  (``analysis.pinned_blocks``: program entry, function entries, return
  sites) is reachable from the control roots.
* **Stack balance** — along every acyclic path of every function frame,
  each variable's push/pop delta is non-negative, merge points agree,
  and ``LReturn`` is reached with all deltas at zero
  (``analysis.stack_effects``).
* **Variable classes** — ``stack_vars`` is exactly the set of variables
  some ``LPush``/``LPop`` touches, and ``temp_vars`` (which never enter
  VM state) are written before every read within each block that
  mentions them.
* **Types** — every mentioned variable has a spec, ``LPush`` sources
  match their destination, and every ``LPrim`` agrees with its declared
  output specs under ``jax.eval_shape``.
* **Provenance** — ``fused_from`` covers every block with a non-empty
  source chain, and no two blocks claim the same chain head (unless the
  profile-guided inliner legitimately tail-duplicated whole frames).
* **Layout packing** — every ``state_layout`` group packs ≥ 2 same-spec,
  non-stack member variables into a packed array whose spec is
  ``(k,) + member_shape``; members are block-local temps, belong to
  exactly one group, and the packed array itself is VM state.
* **Reordering** — ``block_order``, when present, is a permutation of
  ``0..n-1`` (the ``BlockReordering`` provenance).

``PassPipeline`` (passes.py) runs :func:`verify` between passes so a
broken transform is caught at the pass that produced it, not at runtime.
"""
from __future__ import annotations

import jax

from . import analysis, ir


class VerificationError(ValueError):
    """A ``LoweredProgram`` violates a structural or semantic invariant."""


def verify(lowered: ir.LoweredProgram, *, check_specs: bool = True) -> None:
    """Raise :class:`VerificationError` on the first violated invariant.

    ``check_specs=False`` skips the ``jax.eval_shape`` type check of
    every primitive (the one non-structural — and by far the most
    expensive — invariant).
    """
    _check_structure(lowered)
    _check_reachability(lowered)
    _check_stack_balance(lowered)
    _check_var_classes(lowered)
    if check_specs:
        _check_specs(lowered)
    _check_provenance(lowered)
    _check_layout(lowered)
    _check_reorder(lowered)


def _fail(msg: str) -> None:
    raise VerificationError(msg)


def _label(lowered: ir.LoweredProgram, i: int) -> str:
    return f"block {i} ({lowered.blocks[i].label or 'unlabeled'})"


# --------------------------------------------------------------------------
# Structure + reachability
# --------------------------------------------------------------------------


def _check_structure(lowered: ir.LoweredProgram) -> None:
    n = len(lowered.blocks)
    if n == 0:
        _fail("program has no blocks")
    if not (0 <= lowered.entry < n):
        _fail(f"entry {lowered.entry} is out of range [0, {n})")
    for fname, e in lowered.func_entries.items():
        if not (0 <= e < n):
            _fail(f"entry of function {fname!r} is out of range: {e}")
    entries = set(lowered.func_entries.values())
    if lowered.entry not in entries:
        _fail(f"entry {lowered.entry} is not a function entry")
    for i, blk in enumerate(lowered.blocks):
        for op in blk.ops:
            if not isinstance(op, (ir.LPrim, ir.LPush, ir.LPop)):
                _fail(f"{_label(lowered, i)}: invalid lowered op {op!r}")
        t = blk.term
        if not isinstance(t, (ir.LJump, ir.LBranch, ir.LPushJump,
                              ir.LReturn)):
            _fail(f"{_label(lowered, i)}: invalid terminator {t!r}")
        for tgt in analysis.lowered_targets(t):
            if not (0 <= tgt < n):
                _fail(
                    f"{_label(lowered, i)}: terminator target {tgt} is "
                    f"out of range [0, {n})"
                )
        if isinstance(t, ir.LPushJump) and t.target not in entries:
            _fail(
                f"{_label(lowered, i)}: pushjump target {t.target} is "
                "not a function entry"
            )


def _check_reachability(lowered: ir.LoweredProgram) -> None:
    roots = {lowered.entry} | set(lowered.func_entries.values())
    reachable: set[int] = set()
    stack = list(roots)
    while stack:
        b = stack.pop()
        if b in reachable:
            continue
        reachable.add(b)
        stack.extend(analysis.lowered_targets(lowered.blocks[b].term))
    for b in sorted(analysis.pinned_blocks(lowered)):
        if b not in reachable:
            _fail(
                f"pinned {_label(lowered, b)} is unreachable from the "
                "control roots (entry + function entries)"
            )


# --------------------------------------------------------------------------
# Stack balance
# --------------------------------------------------------------------------


def _check_stack_balance(lowered: ir.LoweredProgram) -> None:
    try:
        analysis.stack_effects(lowered)
    except ValueError as e:
        raise VerificationError(f"stack balance: {e}") from e


# --------------------------------------------------------------------------
# Variable classes (stack_vars exactness, temp def-before-use)
# --------------------------------------------------------------------------


def _check_var_classes(lowered: ir.LoweredProgram) -> None:
    actual = frozenset(
        op.var
        for blk in lowered.blocks
        for op in blk.ops
        if isinstance(op, (ir.LPush, ir.LPop))
    )
    if actual != lowered.stack_vars:
        missing = sorted(actual - lowered.stack_vars)
        extra = sorted(lowered.stack_vars - actual)
        _fail(
            "stack_vars is not exactly the pushed/popped set: "
            f"missing {missing}, extra {extra}"
        )
    overlap = lowered.temp_vars & lowered.stack_vars
    if overlap:
        _fail(f"temp_vars overlap stack_vars: {sorted(overlap)}")
    io = set(lowered.main_params) | set(lowered.main_outputs)
    if lowered.state_layout is not None:
        # Packed members are block-local by construction: their cross-block
        # value lives in the packed array, so a main param/output member is
        # legitimately a temp (the VM boundary reads/writes the packed slot).
        io -= lowered.state_layout.members()
    bad_io = lowered.temp_vars & io
    if bad_io:
        _fail(f"temp_vars include main params/outputs: {sorted(bad_io)}")
    for i, blk in enumerate(lowered.blocks):
        written: set[str] = set()
        for op in blk.ops:
            for r in ir.prim_reads(op):
                if r in lowered.temp_vars and r not in written:
                    _fail(
                        f"{_label(lowered, i)}: temp var {r!r} is read "
                        "before any write in this block (def-before-use)"
                    )
            written.update(ir.prim_writes(op))
        if (
            isinstance(blk.term, ir.LBranch)
            and blk.term.var in lowered.temp_vars
            and blk.term.var not in written
        ):
            _fail(
                f"{_label(lowered, i)}: temp var {blk.term.var!r} is "
                "read by the terminator but never written in this block"
            )


# --------------------------------------------------------------------------
# Types (var_specs consistency via jax.eval_shape)
# --------------------------------------------------------------------------


def _specs_eq(a, b) -> bool:
    return tuple(a.shape) == tuple(b.shape) and a.dtype == b.dtype


def _check_specs(lowered: ir.LoweredProgram) -> None:
    specs = lowered.var_specs
    for v in (*lowered.main_params, *lowered.main_outputs):
        if v not in specs:
            _fail(f"main variable {v!r} has no var_specs entry")
    checked: set[int] = set()  # fusion tail-duplicates share op objects
    for i, blk in enumerate(lowered.blocks):
        for op in blk.ops:
            for v in (*ir.prim_reads(op), *ir.prim_writes(op)):
                if v not in specs:
                    _fail(
                        f"{_label(lowered, i)}: variable {v!r} has no "
                        "var_specs entry"
                    )
            if isinstance(op, ir.LPush):
                if not _specs_eq(specs[op.var], specs[op.src]):
                    _fail(
                        f"{_label(lowered, i)}: push {op.var} <- {op.src} "
                        f"mixes specs {specs[op.var]} vs {specs[op.src]}"
                    )
                continue
            if not isinstance(op, ir.LPrim) or id(op) in checked:
                continue
            checked.add(id(op))
            _check_prim(lowered, i, op, specs)
        if isinstance(blk.term, ir.LBranch) and blk.term.var not in specs:
            _fail(
                f"{_label(lowered, i)}: branch variable {blk.term.var!r} "
                "has no var_specs entry"
            )


def _check_prim(lowered, i: int, op: ir.LPrim, specs) -> None:
    in_specs = [specs[v] for v in op.ins]
    if op.batched:
        # Batched prims consume/produce a leading batch axis; type-check
        # at batch size 1 and strip it (mirrors analysis.infer_types).
        in_specs = [
            jax.ShapeDtypeStruct((1,) + tuple(s.shape), s.dtype)
            for s in in_specs
        ]
    try:
        out = jax.eval_shape(op.fn, *in_specs)
    except Exception as e:
        raise VerificationError(
            f"{_label(lowered, i)}: primitive {op.name!r}({op.ins}) does "
            f"not type-check: {e}"
        ) from e
    outs = out if isinstance(out, tuple) else (out,)
    if op.batched:
        for o in outs:
            if not o.shape or o.shape[0] != 1:
                _fail(
                    f"{_label(lowered, i)}: batched primitive {op.name!r} "
                    f"output lost its batch axis: {o.shape}"
                )
        outs = tuple(
            jax.ShapeDtypeStruct(o.shape[1:], o.dtype) for o in outs
        )
    if len(outs) != len(op.outs):
        _fail(
            f"{_label(lowered, i)}: primitive {op.name!r} returns "
            f"{len(outs)} values for {len(op.outs)} outputs"
        )
    for name, o in zip(op.outs, outs):
        if not _specs_eq(specs[name], o):
            _fail(
                f"{_label(lowered, i)}: primitive {op.name!r} writes "
                f"{name!r} as {jax.ShapeDtypeStruct(o.shape, o.dtype)} "
                f"but var_specs declares {specs[name]}"
            )


# --------------------------------------------------------------------------
# Fusion provenance
# --------------------------------------------------------------------------


def _check_provenance(lowered: ir.LoweredProgram) -> None:
    prov = lowered.fused_from
    if prov is None:
        return
    n = len(lowered.blocks)
    if set(prov) != set(range(n)):
        missing = sorted(set(range(n)) - set(prov))
        extra = sorted(set(prov) - set(range(n)))
        _fail(
            f"fused_from keys are not exactly 0..{n - 1}: "
            f"missing blocks {missing}, extra keys {extra}"
        )
    heads: dict[int, int] = {}
    for b in range(n):
        srcs = prov[b]
        if not srcs:
            _fail(f"fused_from[{b}] is empty: block {b} has no provenance")
        for s in srcs:
            if not isinstance(s, int) or s < 0:
                _fail(f"fused_from[{b}] has invalid source index {s!r}")
        if len(set(srcs)) != len(srcs):
            _fail(f"fused_from[{b}] repeats a source block: {srcs}")
        head = srcs[0]
        if head in heads and lowered.block_weights is None:
            # Structural fusion never duplicates a chain head; the
            # profile-guided inliner (which seeds block_weights) does —
            # a tail-duplicated frame copy shares its source chain.
            _fail(
                f"blocks {heads[head]} and {b} both claim original block "
                f"{head} as their chain head (provenance is not a "
                "partition)"
            )
        heads[head] = b


# --------------------------------------------------------------------------
# PGO invariants: state-layout packing + block reordering
# --------------------------------------------------------------------------


def _check_layout(lowered: ir.LoweredProgram) -> None:
    layout = lowered.state_layout
    if layout is None:
        return
    seen: dict[str, str] = {}
    for packed, members in layout.groups.items():
        if len(members) < 2:
            _fail(
                f"layout group {packed!r} packs {len(members)} member(s); "
                "a group needs >= 2 to cut masked updates"
            )
        if packed not in lowered.var_specs:
            _fail(f"packed variable {packed!r} has no var_specs entry")
        if packed in lowered.temp_vars or packed in lowered.stack_vars:
            _fail(
                f"packed variable {packed!r} must be VM state "
                f"(class {lowered.var_class(packed)!r})"
            )
        pspec = lowered.var_specs[packed]
        mspecs = []
        for m in members:
            if m in seen:
                _fail(
                    f"layout member {m!r} belongs to both {seen[m]!r} "
                    f"and {packed!r}"
                )
            seen[m] = packed
            if m in lowered.stack_vars:
                _fail(f"layout member {m!r} is a stack variable")
            if m not in lowered.temp_vars:
                _fail(
                    f"layout member {m!r} must be a block-local temp "
                    f"(class {lowered.var_class(m)!r})"
                )
            if m not in lowered.var_specs:
                _fail(f"layout member {m!r} has no var_specs entry")
            mspecs.append(lowered.var_specs[m])
        first = mspecs[0]
        for m, s in zip(members, mspecs):
            if not _specs_eq(s, first):
                _fail(
                    f"layout group {packed!r} mixes member specs: "
                    f"{members[0]!r} is {first} but {m!r} is {s}"
                )
        want = (len(members),) + tuple(first.shape)
        if tuple(pspec.shape) != want or pspec.dtype != first.dtype:
            _fail(
                f"packed variable {packed!r} spec {pspec} does not match "
                f"(k,) + member shape {want} / dtype {first.dtype}"
            )


def _check_reorder(lowered: ir.LoweredProgram) -> None:
    n = len(lowered.blocks)
    if lowered.block_weights is not None and len(lowered.block_weights) != n:
        _fail(
            f"block_weights has {len(lowered.block_weights)} entries for "
            f"{n} blocks"
        )
    order = lowered.block_order
    if order is None:
        return
    if sorted(order) != list(range(n)):
        _fail(
            f"block_order is not a permutation of 0..{n - 1}: {order}"
        )
