"""Program-counter autobatching VM (paper Algorithm 2), TPU-native.

The whole batched program executes as ONE ``jax.lax.while_loop`` whose body

  1. picks the next block index via a pluggable *schedule* (see below),
  2. dispatches to that block's fused body via ``jax.lax.switch``,
  3. masks all state updates to the locally-active members.

Schedules (``VMConfig.schedule``):

* ``"earliest"`` — the paper's Algorithm 1/2 heuristic: the smallest block
  index any live member's pc-top points at.  Deterministic sweep order;
  members parked at later blocks wait.
* ``"popular"``  — the occupancy heuristic of Lao et al. (2020): the block
  where the most live members currently reside, maximizing SIMD occupancy
  per dispatch.  Ties break toward the lowest index.
* ``"sweep"``    — run *every* block once per loop iteration under its own
  mask, with no ``lax.switch`` at all.  Amortizes dispatch overhead for
  small (post-fusion) programs when members are spread across many blocks;
  one loop iteration can advance a member through several blocks.

All schedules are bit-exact with each other and with the reference
interpreter: every block body masks its updates to the members whose pc-top
selects it, so per-member semantics are schedule-independent.

Because recursion is materialized into fixed-shape ``[depth, batch, ...]``
stack arrays, the VM contains no host control flow at all: it jits, lowers
and compiles like any other XLA program, and members at *different stack
depths* batch together whenever their pc-tops coincide (the paper's central
contribution).

Primitive-execution strategy is *masking* (`jnp.where` selects), which is
the TPU-friendly choice (see DESIGN.md §2).  Stack traffic — the only
gathers/scatters — is confined to pushes and pops thanks to the top-of-stack
cache (paper opt. iv), and can be routed through the Pallas ``stack_ops``
kernel on TPU (``use_kernel=True``).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import ir

Array = jax.Array
_I32 = jnp.int32


def _bcast(mask: Array, val: Array) -> Array:
    """Broadcast a [Z] bool mask against a [Z, ...] value."""
    return mask.reshape(mask.shape + (1,) * (val.ndim - 1))


def _masked(mask: Array, new: Array, old: Array) -> Array:
    return jnp.where(_bcast(mask, new), new, old)


def _scatter_push(stack: Array, ptr: Array, val: Array, mask: Array) -> Array:
    """Bury ``val`` at depth ``ptr`` for active rows. stack: [D, Z, ...]."""
    z = stack.shape[1]
    rows = jnp.where(mask, ptr, stack.shape[0])  # OOB rows dropped
    return stack.at[rows, jnp.arange(z)].set(val, mode="drop")


def _gather_top(stack: Array, ptr: Array) -> Array:
    z = stack.shape[1]
    return stack[jnp.clip(ptr, 0, stack.shape[0] - 1), jnp.arange(z)]


SCHEDULES = ("earliest", "popular", "sweep")


class StackOverflow(RuntimeError):
    """A member's pc or variable stack exceeded ``max_depth``.

    Out-of-range pushes are dropped (``mode="drop"``), so overflowing
    members produce invalid results while other members stay exact; the
    per-member ``VMResult.depth_exceeded`` flag records who overflowed.
    """


@dataclass(frozen=True)
class VMConfig:
    batch_size: int
    max_depth: int = 32  # stack slots (usable call depth = max_depth - 1)
    max_steps: int = 1_000_000
    use_kernel: bool = False  # route stack traffic through Pallas stack_ops
    collect_block_stats: bool = True
    schedule: str = "earliest"  # one of SCHEDULES


@dataclass(frozen=True)
class SchedulerStats:
    """Per-run scheduling summary (host-side ints/floats, post-run).

    ``steps``/``mean_occupancy`` require a device sync and are therefore
    only materialized when ``collect_block_stats=True``; with stats off
    they are ``None``/``nan`` and the run's result stays async.
    """

    schedule: str
    fused: bool  # whether the program went through superblock fusion
    num_blocks: int
    steps: Optional[int]  # loop iterations (one sweep each for "sweep")
    mean_occupancy: float  # active members per dispatch / batch_size
    # Superblock provenance: fused block index -> original block indices
    # (None when the program was never fused).
    fused_from: Optional[dict[int, tuple[int, ...]]]


@dataclass
class VMResult:
    outputs: dict[str, Array]
    steps: Array
    converged: Array  # bool: all members halted within max_steps
    block_exec: Optional[Array]  # [num_blocks] times each block ran
    block_active: Optional[Array]  # [num_blocks] total active members
    tag_stats: dict[str, tuple[int, int]]  # tag -> (execs, active) post-run
    depth_exceeded: Optional[Array] = None  # [batch] bool: stack overflowed
    sched: Optional[SchedulerStats] = None


class ProgramCounterVM:
    """Compiled batched executor for a :class:`ir.LoweredProgram`."""

    def __init__(self, lowered: ir.LoweredProgram, config: VMConfig):
        if config.schedule not in SCHEDULES:
            raise ValueError(
                f"schedule must be one of {SCHEDULES}, "
                f"got {config.schedule!r}"
            )
        self.lowered = lowered
        self.config = config
        self.num_blocks = len(lowered.blocks)
        self._state_vars = [
            v
            for v in sorted(lowered.var_specs)
            if v not in lowered.temp_vars
        ]
        self._block_fns = [
            self._make_block_fn(i, blk) for i, blk in enumerate(lowered.blocks)
        ]
        # tag -> [(block_idx, multiplicity)] for post-run instrumentation.
        self._tag_blocks: dict[str, list[tuple[int, int]]] = {}
        for i, blk in enumerate(lowered.blocks):
            for op in blk.ops:
                if isinstance(op, ir.LPrim) and op.tag:
                    entry = self._tag_blocks.setdefault(op.tag, [])
                    entry.append((i, 1))
        self._jitted = jax.jit(self._run)

    # ------------------------------------------------------------------
    # State construction
    # ------------------------------------------------------------------

    def init_state(self, inputs: dict[str, Array]) -> dict[str, Any]:
        cfg = self.config
        z, d = cfg.batch_size, cfg.max_depth
        lp = self.lowered
        tops: dict[str, Array] = {}
        stacks: dict[str, Array] = {}
        ptrs: dict[str, Array] = {}
        for v in self._state_vars:
            spec = lp.var_specs[v]
            tops[v] = jnp.zeros((z,) + tuple(spec.shape), spec.dtype)
            if v in lp.stack_vars:
                stacks[v] = jnp.zeros((d, z) + tuple(spec.shape), spec.dtype)
                ptrs[v] = jnp.zeros((z,), _I32)
        for p in lp.main_params:
            x = jnp.asarray(inputs[p])
            if x.shape != (z,) + tuple(lp.var_specs[p].shape):
                raise ValueError(
                    f"input {p!r}: expected batched shape "
                    f"{(z,) + tuple(lp.var_specs[p].shape)}, got {x.shape}"
                )
            tops[p] = x.astype(lp.var_specs[p].dtype)
        pc_stack = jnp.full((d, z), lp.exit_index, _I32)
        state = {
            "pc_top": jnp.full((z,), lp.entry, _I32),
            "pc_stack": pc_stack,  # slot 0 holds the exit sentinel
            "pc_ptr": jnp.ones((z,), _I32),
            "tops": tops,
            "stacks": stacks,
            "ptrs": ptrs,
            "steps": jnp.zeros((), _I32),
            # Per-member overflow flag: set when a push would land at or
            # beyond max_depth (the scatter drops it, invalidating that
            # member's results).
            "depth_exceeded": jnp.zeros((z,), jnp.bool_),
        }
        if self.config.collect_block_stats:
            state["block_exec"] = jnp.zeros((self.num_blocks,), _I32)
            state["block_active"] = jnp.zeros((self.num_blocks,), _I32)
        return state

    # ------------------------------------------------------------------
    # Block body compilation
    # ------------------------------------------------------------------

    def _make_block_fn(self, bidx: int, blk: ir.LBlock) -> Callable:
        lowered = self.lowered
        temp_vars = lowered.temp_vars
        use_kernel = self.config.use_kernel
        max_depth = self.config.max_depth

        if use_kernel:
            from repro.kernels.stack_ops import ops as _sk

        def run(state: dict[str, Any]) -> dict[str, Any]:
            mask = state["pc_top"] == bidx
            imask = mask.astype(_I32)
            tops = dict(state["tops"])
            stacks = dict(state["stacks"])
            ptrs = dict(state["ptrs"])
            depth_exceeded = state["depth_exceeded"]
            temps: dict[str, Array] = {}

            def read(v: str) -> Array:
                return temps[v] if v in temp_vars else tops[v]

            def write(v: str, val: Array) -> None:
                if v in temp_vars:
                    temps[v] = val
                else:
                    tops[v] = _masked(mask, val.astype(tops[v].dtype), tops[v])

            for op in blk.ops:
                if isinstance(op, ir.LPrim):
                    if not op.ins and not op.batched:
                        # Nullary primitive (constant): broadcast to the batch.
                        z = mask.shape[0]
                        outs = op.fn()
                        outs = outs if isinstance(outs, tuple) else (outs,)
                        outs = tuple(
                            jnp.broadcast_to(
                                jnp.asarray(o), (z,) + jnp.shape(jnp.asarray(o))
                            )
                            for o in outs
                        )
                    else:
                        fn = op.fn if op.batched else jax.vmap(op.fn)
                        outs = fn(*[read(i) for i in op.ins])
                        if len(op.outs) == 1:
                            outs = (outs,)
                    for name, val in zip(op.outs, outs):
                        write(name, val)
                elif isinstance(op, ir.LPush):
                    old_top = tops[op.var]
                    depth_exceeded = jnp.logical_or(
                        depth_exceeded,
                        jnp.logical_and(mask, ptrs[op.var] >= max_depth),
                    )
                    if use_kernel:
                        stacks[op.var] = _sk.masked_push(
                            stacks[op.var], ptrs[op.var], old_top, mask
                        )
                    else:
                        stacks[op.var] = _scatter_push(
                            stacks[op.var], ptrs[op.var], old_top, mask
                        )
                    ptrs[op.var] = ptrs[op.var] + imask
                    tops[op.var] = _masked(mask, read(op.src), old_top)
                elif isinstance(op, ir.LPop):
                    new_ptr = ptrs[op.var] - imask
                    if use_kernel:
                        restored = _sk.masked_peek(stacks[op.var], new_ptr)
                    else:
                        restored = _gather_top(stacks[op.var], new_ptr)
                    tops[op.var] = _masked(mask, restored, tops[op.var])
                    ptrs[op.var] = new_ptr
                else:  # pragma: no cover
                    raise AssertionError(op)

            pc_top = state["pc_top"]
            pc_stack = state["pc_stack"]
            pc_ptr = state["pc_ptr"]
            t = blk.term
            if isinstance(t, ir.LJump):
                pc_top = jnp.where(mask, t.target, pc_top)
            elif isinstance(t, ir.LBranch):
                cond = read(t.var)
                pc_top = jnp.where(
                    mask, jnp.where(cond, t.true, t.false), pc_top
                )
            elif isinstance(t, ir.LPushJump):
                # Bury the return address; jump to the callee entry.
                ret = jnp.full_like(pc_top, t.ret)
                depth_exceeded = jnp.logical_or(
                    depth_exceeded, jnp.logical_and(mask, pc_ptr >= max_depth)
                )
                pc_stack = _scatter_push(pc_stack, pc_ptr, ret, mask)
                pc_ptr = pc_ptr + imask
                pc_top = jnp.where(mask, t.target, pc_top)
            elif isinstance(t, ir.LReturn):
                new_ptr = pc_ptr - imask
                restored = _gather_top(pc_stack, new_ptr)
                pc_top = jnp.where(mask, restored, pc_top)
                pc_ptr = new_ptr
            else:  # pragma: no cover
                raise AssertionError(t)

            out = dict(state)
            out.update(
                pc_top=pc_top,
                pc_stack=pc_stack,
                pc_ptr=pc_ptr,
                tops=tops,
                stacks=stacks,
                ptrs=ptrs,
                depth_exceeded=depth_exceeded,
            )
            return out

        return run

    # ------------------------------------------------------------------
    # The VM loop
    # ------------------------------------------------------------------

    def _pick_block(self, state: dict[str, Any]) -> Array:
        """The schedule's block choice for one dispatch (traced)."""
        exit_idx = self.lowered.exit_index
        pc_top = state["pc_top"]
        live = pc_top < exit_idx
        if self.config.schedule == "popular":
            # Occupancy argmax: the block where most live members reside.
            counts = (
                jnp.zeros((self.num_blocks,), _I32)
                .at[jnp.where(live, pc_top, self.num_blocks)]
                .add(1, mode="drop")
            )
            return jnp.argmax(counts).astype(_I32)
        # Earliest-block heuristic (Algorithm 1/2's block choice).
        return jnp.min(jnp.where(live, pc_top, exit_idx)).astype(_I32)

    def _run(self, inputs: dict[str, Array]) -> dict[str, Any]:
        lp = self.lowered
        exit_idx = lp.exit_index
        collect = self.config.collect_block_stats
        state = self.init_state(inputs)

        def cond(state):
            return jnp.logical_and(
                state["steps"] < self.config.max_steps,
                jnp.any(state["pc_top"] < exit_idx),
            )

        def body_switch(state):
            i = self._pick_block(state)
            if collect:
                active = jnp.sum((state["pc_top"] == i).astype(_I32))
                state = dict(state)
                state["block_exec"] = state["block_exec"].at[i].add(1)
                state["block_active"] = state["block_active"].at[i].add(active)
            state = lax.switch(i, self._block_fns, state)
            state = dict(state)
            state["steps"] = state["steps"] + 1
            return state

        def body_sweep(state):
            # Run every resident block once, in index order, each under its
            # own mask — no lax.switch at all.  A member can traverse
            # several (forward) blocks within one sweep.
            for b, fn in enumerate(self._block_fns):
                if collect:
                    active = jnp.sum((state["pc_top"] == b).astype(_I32))
                    state = dict(state)
                    # Count a dispatch only when it had resident members,
                    # so utilization stays comparable across schedules.
                    state["block_exec"] = (
                        state["block_exec"].at[b].add((active > 0).astype(_I32))
                    )
                    state["block_active"] = (
                        state["block_active"].at[b].add(active)
                    )
                state = fn(state)
            state = dict(state)
            state["steps"] = state["steps"] + 1
            return state

        body = body_sweep if self.config.schedule == "sweep" else body_switch
        return lax.while_loop(cond, body, state)

    def run(self, inputs: dict[str, Array]) -> VMResult:
        """Execute the batched program to completion (jitted end-to-end)."""
        state = self._jitted(inputs)
        return self._result(state)

    def _result(self, state) -> VMResult:
        lp = self.lowered
        outputs = {o: state["tops"][o] for o in lp.main_outputs}
        converged = jnp.all(state["pc_top"] >= lp.exit_index)
        block_exec = state.get("block_exec")
        block_active = state.get("block_active")
        tag_stats: dict[str, tuple[int, int]] = {}
        mean_occ = float("nan")
        steps = None
        if block_exec is not None:
            be = jax.device_get(block_exec)
            ba = jax.device_get(block_active)
            for tag, entries in self._tag_blocks.items():
                execs = sum(int(be[b]) * m for b, m in entries)
                active = sum(int(ba[b]) * m for b, m in entries)
                tag_stats[tag] = (execs, active)
            dispatches = int(be.sum())
            if dispatches:
                mean_occ = float(ba.sum()) / (
                    dispatches * self.config.batch_size
                )
            steps = int(jax.device_get(state["steps"]))
        sched = SchedulerStats(
            schedule=self.config.schedule,
            fused=lp.fused_from is not None,
            num_blocks=self.num_blocks,
            steps=steps,
            mean_occupancy=mean_occ,
            fused_from=lp.fused_from,
        )
        return VMResult(
            outputs=outputs,
            steps=state["steps"],
            converged=converged,
            block_exec=block_exec,
            block_active=block_active,
            tag_stats=tag_stats,
            depth_exceeded=state.get("depth_exceeded"),
            sched=sched,
        )

    # ------------------------------------------------------------------
    # AOT entry points (for dry-runs and benchmarking)
    # ------------------------------------------------------------------

    def lower(self, inputs: dict[str, Array]):
        return self._jitted.lower(inputs)

    def step_fn(self) -> Callable:
        """One VM step as a standalone jittable function of the state.

        Honors ``config.schedule``: a single scheduled dispatch for
        ``earliest``/``popular``, a full masked pass over every block for
        ``sweep``.
        """

        def step(state):
            if self.config.schedule == "sweep":
                for fn in self._block_fns:
                    state = fn(state)
                return state
            i = self._pick_block(state)
            return lax.switch(i, self._block_fns, state)

        return step
