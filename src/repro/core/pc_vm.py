"""Program-counter autobatching VM (paper Algorithm 2), TPU-native.

The whole batched program executes as ONE ``jax.lax.while_loop`` whose body

  1. picks the next block index via a pluggable *schedule* (see below),
  2. dispatches to that block's fused body via ``jax.lax.switch``,
  3. masks all state updates to the locally-active members.

Schedules (``VMConfig.schedule``):

* ``"earliest"`` — the paper's Algorithm 1/2 heuristic: the smallest block
  index any live member's pc-top points at.  Deterministic sweep order;
  members parked at later blocks wait.
* ``"popular"``  — the occupancy heuristic of Lao et al. (2020): the block
  where the most live members currently reside, maximizing SIMD occupancy
  per dispatch.  Ties break toward the lowest index.
* ``"sweep"``    — run *every* block once per loop iteration under its own
  mask, with no ``lax.switch`` at all.  Amortizes dispatch overhead for
  small (post-fusion) programs when members are spread across many blocks;
  one loop iteration can advance a member through several blocks.
* ``"lookahead"`` — occupancy over the block's CFG successors: score each
  resident block by ``2*count[b] + sum(count[s] for s in successors(b))``
  and dispatch the argmax.  Prefers blocks whose completion *feeds* other
  populated blocks, so divergent members re-converge sooner than under
  plain ``"popular"``.  Ties break toward the lowest index.

All schedules are bit-exact with each other and with the reference
interpreter: every block body masks its updates to the members whose pc-top
selects it, so per-member semantics are schedule-independent.

Because recursion is materialized into fixed-shape ``[depth, batch, ...]``
stack arrays, the VM contains no host control flow at all: it jits, lowers
and compiles like any other XLA program, and members at *different stack
depths* batch together whenever their pc-tops coincide (the paper's central
contribution).

Primitive-execution strategy is *masking* (`jnp.where` selects), which is
the TPU-friendly choice (see DESIGN.md §2).  Stack traffic — the only
gathers/scatters — is confined to pushes and pops thanks to the top-of-stack
cache (paper opt. iv), and can be routed through the Pallas ``stack_ops``
kernel on TPU (``use_kernel=True``).

Multi-device lane sharding (``VMConfig.mesh``):

Every piece of VM state is *lane-major* — ``[batch, ...]`` tops/pointers/
masks and ``[depth, batch, ...]`` stacks — and every block body is
elementwise per lane, so the whole step is embarrassingly data-parallel.
With ``mesh=N`` (or an explicit 1-D ``jax.sharding.Mesh``) the VM lays out
each state array with a ``NamedSharding`` that splits the lane axis across
the mesh, and the single ``lax.while_loop`` compiles as one SPMD program.
The only cross-device traffic per iteration is scalar all-reduces:

* the liveness check in ``cond`` (``any(pc_top < exit)`` — one bool),
* the schedule's block choice in ``_pick_block`` (``min``/``argmax`` over
  per-lane pc values — one i32),
* with ``collect_block_stats=True``, the per-dispatch occupancy count
  (one i32; disable stats to drop it).

All schedules stay bit-exact under sharding: block bodies are per-lane, and
the reductions above are integer min/sum/argmax, which are associative and
placement-independent.  The loop-carried state is donated on accelerator
backends so steady-state memory is flat at one copy of the VM state.

Segmented (resumable) execution:

``run()`` executes to completion, but the VM can also run in *segments*:
``start()`` builds the initial state snapshot, ``run_segment(state, n)``
advances it by at most ``n`` loop iterations and returns the updated
snapshot, and ``result(state)`` materializes a :class:`VMResult` from any
snapshot.  The segment loop reuses the exact same body function as the
single-shot loop and the snapshot carries *all* execution state (pc
stack/top, variable tops/stacks/pointers, overflow flags, step and
occupancy counters), so chaining segments of any sizes is bit-exact with
a single ``run()`` — the loop merely observes an extra iteration bound in
its ``cond``.  Between segments the host may retire finished lanes
(``lane_done``), park idle ones (``park``), and re-initialize a masked
subset with fresh inputs (``inject``) — the primitive underneath
retire-and-refill continuous batching (see ``repro/serve/engine.py``).
Snapshots are donatable pytrees: on accelerator backends every
state-in/state-out entry point donates its input snapshot, so steady-state
memory stays flat at one copy of the VM state.

Fault containment (``VMConfig.on_fault``):

Batch members run independently, so one misbehaving lane should not be
batch-fatal.  Every lane carries a fault code (``FAULT_OK`` /
``FAULT_STACK_OVERFLOW`` / ``FAULT_NONFINITE`` / ``FAULT_WATCHDOG``;
first fault wins) set when a push overflows ``max_depth``, when a masked
state write produces NaN/Inf (opt-in via ``detect_nonfinite``), or when a
lane stays active past ``lane_step_budget`` dispatches without halting
(opt-in watchdog against data-dependent livelock).  Under
``on_fault="quarantine"`` a faulted lane is excluded from every dispatch
mask and from the liveness reduction the iteration after it faults — its
state freezes, the batch keeps running, and healthy lanes stay bit-exact
with a fault-free run (masking already guarantees per-lane independence).
Under ``on_fault="raise"`` (the default) behavior is the historical
batch-fatal one: the executor raises :class:`StackOverflow` /
:class:`LaneFault` after the run, and an enabled detector halts the loop
early instead of spinning to ``max_steps``.  ``inject`` clears the fault
code and watchdog clock of refilled lanes.

Occupancy-aware lane compaction (``VMConfig.compact_every``):

Divergence scatters the members resident at a block across the lane axis,
so a dispatch touches many SIMD tiles that are mostly masked out.  With
``compact_every=k`` the loop body, every ``k`` dispatches, *permutes* the
whole lane-major state with a stable sort on (liveness, pc-top) — lanes at
the same program point become contiguous, and dead/quarantined lanes sink
to the high end.  A ``lane_ids`` state vector records which original lane
each row holds; every identity-bearing surface (``VMResult`` outputs and
per-lane flags, ``lane_done``/``lane_fault``, ``Stepper`` views) applies
the inverse permutation, and ``inject``/``park`` translate their
original-order masks and inputs into row order — so compaction is
invisible everywhere except throughput.  Because every schedule picks
blocks from a lane-permutation-invariant histogram/min, the dispatch
sequence, step counts and all outputs are bit-exact with the uncompacted
run (property-tested).  ``mean_occupancy`` is measured per SIMD tile of
:data:`OCCUPANCY_TILE` lanes: active lanes divided by the capacity of the
tiles that held at least one active lane — the quantity compaction
actually improves, and one that never charges fully-idle (parked,
quarantined, retired) tiles.

Dispatch tracing (``VMConfig.trace``):

With ``trace=`` set (``True`` or an int event capacity) the loop carry
gains a fixed-capacity on-device ring buffer that records, per dispatch:
the chosen block id, the per-block live-resident histogram, active /
live / quarantined lane counts, the occupied-tile capacity, whether
compaction ran, and the post-dispatch faulted-lane total.  Recording is
strictly *write-only* with respect to execution — no traced value feeds
back into ``cond``, ``_pick_block`` or any block body — so a traced run
is bit-exact with an untraced one (outputs, step counts, and the dispatch
sequence itself; property-tested across the schedule x fuse x mesh x
compact_every x use_kernel matrix).  Drain the buffer host-side with
:meth:`ProgramCounterVM.get_trace` (or ``VMResult.trace`` after
``run()``) into a typed :class:`repro.obs.trace.DispatchTrace`; the ring
index is ``steps % capacity``, so when a run outlives the capacity the
newest events win and the drain reports how many oldest were dropped.
Under a mesh the buffers are replicated; the per-event counts are the
same integer all-reduces the stats path uses, so tracing composes with
sharding, segments, compaction and quarantine.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh

from . import ir

Array = jax.Array
_I32 = jnp.int32


def _bcast(mask: Array, val: Array) -> Array:
    """Broadcast a [Z] bool mask against a [Z, ...] value."""
    return mask.reshape(mask.shape + (1,) * (val.ndim - 1))


def _masked(mask: Array, new: Array, old: Array) -> Array:
    return jnp.where(_bcast(mask, new), new, old)


def _scatter_push(stack: Array, ptr: Array, val: Array, mask: Array) -> Array:
    """Bury ``val`` at depth ``ptr`` for active rows. stack: [D, Z, ...]."""
    z = stack.shape[1]
    rows = jnp.where(mask, ptr, stack.shape[0])  # OOB rows dropped
    return stack.at[rows, jnp.arange(z)].set(val, mode="drop")


def _gather_top(stack: Array, ptr: Array) -> Array:
    z = stack.shape[1]
    return stack[jnp.clip(ptr, 0, stack.shape[0] - 1), jnp.arange(z)]


def _tile_capacity(mask: Array) -> Array:
    """Lane capacity of the OCCUPANCY_TILE-wide tiles holding >=1 set lane.

    ``mask``: [Z] bool -> i32 scalar.  Tiles are fixed windows over the
    global lane index, so the value is device-placement-independent.  A
    trailing partial tile contributes only its real width.
    """
    z = mask.shape[0]
    t = OCCUPANCY_TILE
    g = -(-z // t)  # ceil(z / t) tiles
    pad = g * t - z
    mp = jnp.pad(mask, (0, pad)) if pad else mask
    occupied = jnp.any(mp.reshape(g, t), axis=1)
    caps = jnp.full((g,), t, _I32)
    if pad:
        caps = caps.at[g - 1].set(t - pad)
    return jnp.sum(jnp.where(occupied, caps, 0)).astype(_I32)


SCHEDULES = ("earliest", "popular", "sweep", "lookahead")

#: SIMD tile width (lanes) used by the occupancy metric: a dispatch's
#: occupancy is active lanes / capacity of the tiles holding at least one
#: active lane.  8 models vector-register granularity; the exact width only
#: scales the metric, it does not change which schedule/compaction wins.
OCCUPANCY_TILE = 8

#: Fault policies (``VMConfig.on_fault``): ``"raise"`` keeps the historical
#: batch-fatal behavior (the executor raises after the run); ``"quarantine"``
#: parks faulted lanes out of the liveness mask so the batch never aborts.
ON_FAULT = ("raise", "quarantine")

# Per-lane fault codes (i32, first fault wins; 0 = healthy).
FAULT_OK = 0
FAULT_STACK_OVERFLOW = 1  # a push landed at or beyond max_depth
FAULT_NONFINITE = 2  # a masked state write produced NaN/Inf (opt-in)
FAULT_WATCHDOG = 3  # lane exceeded its per-lane step budget (opt-in)

#: Human-readable names, indexed by fault code.
FAULT_NAMES = ("ok", "stack_overflow", "nonfinite", "watchdog")

#: Mesh axis name the lane (batch) dimension shards over.
LANE_AXIS = "lanes"


def resolve_mesh(mesh: Any) -> Optional[Mesh]:
    """Normalize a ``VMConfig.mesh`` value to a 1-D ``jax.sharding.Mesh``.

    Accepts ``None`` (no sharding), an integer device count (the first
    ``mesh`` entries of ``jax.devices()`` under the :data:`LANE_AXIS` axis),
    or an explicit 1-D ``Mesh`` whose single axis is the lane axis.
    """
    if mesh is None:
        return None
    if isinstance(mesh, Mesh):
        if len(mesh.axis_names) != 1:
            raise ValueError(
                "pc VM lane sharding needs a 1-D mesh (one axis over the "
                f"batch lanes); got axes {mesh.axis_names}"
            )
        return mesh
    n = int(mesh)
    if n < 1:
        raise ValueError(f"mesh device count must be >= 1, got {n}")
    devices = jax.devices()
    if n > len(devices):
        raise ValueError(
            f"mesh={n} needs {n} devices but only {len(devices)} are "
            "visible (on CPU, set XLA_FLAGS="
            "--xla_force_host_platform_device_count=N to fake a mesh)"
        )
    return Mesh(np.asarray(devices[:n]), (LANE_AXIS,))


def mesh_cache_key(mesh: Any) -> Optional[tuple]:
    """A hashable identity for a mesh spec, for compilation-cache keys.

    ``None`` stays ``None`` without touching the jax backend; everything
    else resolves to ``(axis_name, device ids)`` so that an int spec and
    the equivalent explicit ``Mesh`` share compiled executors.
    """
    m = resolve_mesh(mesh)
    if m is None:
        return None
    return (m.axis_names, tuple(d.id for d in m.devices.flat))


class StackOverflow(RuntimeError):
    """A member's pc or variable stack exceeded ``max_depth``.

    Out-of-range pushes are dropped (``mode="drop"``), so overflowing
    members produce invalid results while other members stay exact; the
    per-member ``VMResult.depth_exceeded`` flag records who overflowed.

    When raised by the batching executors, the exception carries the
    per-lane evidence as attributes: ``depth_exceeded`` is the ``[batch]``
    bool overflow mask (host ``numpy``), and ``lanes`` is the sorted array
    of offending lane indices — so callers can report *which* requests
    died instead of just that something did.
    """

    def __init__(
        self,
        message: str,
        *,
        depth_exceeded: Optional[np.ndarray] = None,
        lanes: Optional[np.ndarray] = None,
    ):
        super().__init__(message)
        self.depth_exceeded = depth_exceeded
        if lanes is None and depth_exceeded is not None:
            lanes = np.flatnonzero(np.asarray(depth_exceeded))
        self.lanes = lanes


class LaneFault(RuntimeError):
    """One or more lanes faulted (non-finite write or watchdog) under
    ``on_fault="raise"``.

    Attributes: ``fault_codes`` — the ``[batch]`` i32 code array (host
    ``numpy``, see :data:`FAULT_NAMES`); ``lanes`` — indices of the faulted
    lanes; ``faults`` — ``{lane: name}`` for the same lanes.
    """

    def __init__(self, message: str, *, fault_codes: np.ndarray):
        super().__init__(message)
        codes = np.asarray(fault_codes)
        self.fault_codes = codes
        self.lanes = np.flatnonzero(codes != FAULT_OK)
        self.faults = {
            int(i): FAULT_NAMES[int(codes[i])] for i in self.lanes
        }


@dataclass(frozen=True)
class VMConfig:
    batch_size: int
    max_depth: int = 32  # stack slots (usable call depth = max_depth - 1)
    max_steps: int = 1_000_000
    use_kernel: bool = False  # route stack traffic through Pallas stack_ops
    collect_block_stats: bool = True
    schedule: str = "earliest"  # one of SCHEDULES
    # Lane sharding: None (single device), an int device count, or a 1-D
    # jax.sharding.Mesh.  batch_size must divide evenly across the mesh.
    mesh: Any = None
    # Run the lowered-IR verifier (verifier.py) on the program before
    # compiling it — catches a broken transform before it becomes a wrong
    # batched answer.
    verify: bool = False
    # Fault containment.  "raise": faults are batch-fatal — the executor
    # raises StackOverflow/LaneFault after the run (historical behavior).
    # "quarantine": faulted lanes are excluded from the liveness mask and
    # from every block's dispatch mask the iteration after they fault, so
    # the batch keeps running and healthy lanes stay bit-exact with a
    # fault-free run.
    on_fault: str = "raise"
    # Opt-in finiteness check on masked state writes (inexact dtypes only):
    # a lane that writes NaN/Inf into VM state gets FAULT_NONFINITE.
    detect_nonfinite: bool = False
    # Opt-in watchdog against data-dependent livelock: a lane that stays
    # active for more than this many block dispatches without halting gets
    # FAULT_WATCHDOG.  None disables the check.
    lane_step_budget: Optional[int] = None
    # Occupancy-aware lane compaction: every `compact_every` dispatches the
    # loop body stably sorts the lane axis by (liveness, pc-top) so members
    # at the same program point occupy contiguous SIMD tiles.  None (the
    # default) disables compaction and skips all permutation bookkeeping.
    # Bit-exact with the uncompacted run (outputs, steps, fault codes,
    # per-lane ordering) for every schedule.
    compact_every: Optional[int] = None
    # Dispatch tracing: None/False disables, True uses the default ring
    # capacity (repro.obs.trace.DEFAULT_TRACE_CAPACITY events), an int is
    # an explicit capacity.  Purely observational — never changes outputs,
    # steps, or dispatch choices.  Drain with get_trace()/VMResult.trace.
    trace: Any = None

    def __post_init__(self):
        if self.on_fault not in ON_FAULT:
            raise ValueError(
                f"on_fault must be one of {ON_FAULT}, got {self.on_fault!r}"
            )
        if self.lane_step_budget is not None and self.lane_step_budget < 1:
            raise ValueError(
                "lane_step_budget must be >= 1 (or None to disable), got "
                f"{self.lane_step_budget}"
            )
        if self.compact_every is not None and self.compact_every < 1:
            raise ValueError(
                "compact_every must be >= 1 (or None to disable), got "
                f"{self.compact_every}"
            )
        # Normalizes True/int and raises on nonsense (capacity < 1).
        from repro.obs.trace import resolve_capacity

        resolve_capacity(self.trace)


@dataclass(frozen=True)
class SchedulerStats:
    """Per-run scheduling summary (host-side ints/floats, post-run).

    ``steps``/``mean_occupancy`` require a device sync and are therefore
    only materialized when ``collect_block_stats=True``; with stats off
    they are ``None``/``nan`` and the run's result stays async.
    """

    schedule: str
    fused: bool  # whether the program went through superblock fusion
    num_blocks: int
    steps: Optional[int]  # loop iterations (one sweep each for "sweep")
    # Tile-based SIMD occupancy: active lanes per dispatch / capacity of
    # the OCCUPANCY_TILE-lane tiles that held >= 1 active lane.  Excludes
    # fully-idle tiles, so parked/quarantined/retired lanes never dilute
    # it — and lane compaction (compact_every) genuinely raises it.
    mean_occupancy: float
    # Superblock provenance: fused block index -> original block indices
    # (None when the program was never fused).
    fused_from: Optional[dict[int, tuple[int, ...]]]
    # Devices the lane axis was sharded over (1 = unsharded).
    num_devices: int = 1
    # Legacy whole-batch metric: active members per dispatch / batch_size
    # (counts every lane in the denominator, live or not).  Kept for
    # trajectory comparisons with pre-compaction records.
    mean_lane_occupancy: float = float("nan")
    # The compaction cadence this run used (None = no compaction).
    compact_every: Optional[int] = None
    # Total masked whole-state top updates the run performed:
    # sum over blocks of block_exec[b] * (static masked-write count of
    # block b).  Requires collect_block_stats; None otherwise.  This is
    # the quantity StateLayoutPacking shrinks — packed members write one
    # grouped array instead of one `_masked` update per member.
    masked_updates: Optional[int] = None


@dataclass
class VMResult:
    outputs: dict[str, Array]
    steps: Array
    converged: Array  # bool: all members halted within max_steps
    block_exec: Optional[Array]  # [num_blocks] times each block ran
    block_active: Optional[Array]  # [num_blocks] total active members
    tag_stats: dict[str, tuple[int, int]]  # tag -> (execs, active) post-run
    depth_exceeded: Optional[Array] = None  # [batch] bool: stack overflowed
    sched: Optional[SchedulerStats] = None
    fault_code: Optional[Array] = None  # [batch] i32, see FAULT_NAMES
    lane_steps: Optional[Array] = None  # [batch] i32 active-dispatch counts
    # The drained dispatch trace (repro.obs.trace.DispatchTrace) when the
    # run had VMConfig.trace set; None otherwise.
    trace: Optional[Any] = None

    @property
    def fault_mask(self) -> Optional[Array]:
        """[batch] bool: lanes that faulted (None on legacy snapshots)."""
        if self.fault_code is None:
            return None
        return self.fault_code != FAULT_OK


class ProgramCounterVM:
    """Compiled batched executor for a :class:`ir.LoweredProgram`."""

    def __init__(self, lowered: ir.LoweredProgram, config: VMConfig):
        if config.schedule not in SCHEDULES:
            raise ValueError(
                f"schedule must be one of {SCHEDULES}, "
                f"got {config.schedule!r}"
            )
        # on_fault / lane_step_budget are validated by VMConfig itself.
        if config.verify:
            from . import verifier

            verifier.verify(lowered)
        self.lowered = lowered
        self.config = config
        self.num_blocks = len(lowered.blocks)
        # Dispatch-trace ring capacity (None = tracing off).  Resolved
        # once; the buffers live in the loop carry (see init_state).
        from repro.obs.trace import resolve_capacity

        self.trace_capacity = resolve_capacity(config.trace)
        self.mesh = resolve_mesh(config.mesh)
        self._lane_sharding = None
        self._stack_sharding = None
        self._replicated = None
        if self.mesh is not None:
            n = self.mesh.size
            if config.batch_size % n:
                raise ValueError(
                    f"batch_size={config.batch_size} does not divide across "
                    f"the {n}-device mesh; pick a batch that is a multiple "
                    f"of {n}"
                )
            # Lane-major layout rules live with the other sharding rules in
            # launch/sharding.py (one source of truth with the tests).
            from repro.launch.sharding import lane_shardings

            (
                self._lane_sharding,
                self._stack_sharding,
                self._replicated,
            ) = lane_shardings(self.mesh)
        # Pallas stack_ops binding.  Stack traffic is strictly per-lane, so
        # under a mesh the kernel runs shard-locally (one pallas_call per
        # device over its lane slice, via shard_map) — no cross-device
        # traffic, and bit-exact with the XLA scatter/gather path.
        self._kernel_push = self._kernel_peek = None
        if config.use_kernel:
            from repro.kernels.stack_ops import ops as _sk

            if self.mesh is None:
                self._kernel_push = _sk.masked_push
                self._kernel_peek = _sk.masked_peek
            else:
                self._kernel_push, self._kernel_peek = _sk.shard_local(
                    self.mesh
                )
        # "lookahead" scores blocks by occupancy over CFG successors; the
        # [B, B] 0/1 successor matrix is a trace-time constant.
        self._succ_matrix = None
        if config.schedule == "lookahead":
            succ = np.zeros((self.num_blocks, self.num_blocks), np.int32)
            for i, blk in enumerate(lowered.blocks):
                t = blk.term
                if isinstance(t, ir.LJump):
                    targets: tuple[int, ...] = (t.target,)
                elif isinstance(t, ir.LBranch):
                    targets = (t.true, t.false)
                elif isinstance(t, ir.LPushJump):
                    # One-step successor is the callee entry; the return
                    # site is reached only after the callee finishes.
                    targets = (t.target,)
                else:  # LReturn: dynamic target (the buried return pc).
                    targets = ()
                for s in targets:
                    if 0 <= s < self.num_blocks:
                        succ[i, s] = 1
            self._succ_matrix = jnp.asarray(succ)
        self._state_vars = [
            v
            for v in sorted(lowered.var_specs)
            if v not in lowered.temp_vars
        ]
        # Static count of masked whole-state top updates per dispatch of
        # each block: one per LPrim output that lands in VM state plus one
        # per push/pop top write.  Multiplied by block_exec post-run to
        # give SchedulerStats.masked_updates (the metric layout packing
        # cuts: packed members become temps, so a block writes the one
        # grouped array instead of one masked top per member).
        self._masked_writes = [
            sum(
                len([o for o in op.outs if o not in lowered.temp_vars])
                if isinstance(op, ir.LPrim)
                else 1
                for op in blk.ops
            )
            for blk in lowered.blocks
        ]
        self._block_fns = [
            self._make_block_fn(i, blk) for i, blk in enumerate(lowered.blocks)
        ]
        # tag -> [(block_idx, multiplicity)] for post-run instrumentation.
        self._tag_blocks: dict[str, list[tuple[int, int]]] = {}
        for i, blk in enumerate(lowered.blocks):
            for op in blk.ops:
                if isinstance(op, ir.LPrim) and op.tag:
                    entry = self._tag_blocks.setdefault(op.tag, [])
                    entry.append((i, 1))
        # One-program path (kept for .lower()/cost_analysis), plus a
        # two-stage path for run(): init and loop are jitted separately so
        # the loop-carried state pytree can be donated — steady-state
        # memory stays flat at one copy of the VM state.  XLA's CPU client
        # does not implement donation, so only donate on accelerators
        # (avoids a warning per compile).
        self._jitted = jax.jit(self._run)
        self._donate = jax.default_backend() != "cpu"
        self._jitted_start = jax.jit(self._start)
        self._jitted_loop = jax.jit(self._loop, donate_argnums=(0,))
        # Segmented-execution entry points.  All take the state snapshot
        # first and donate it (where the backend supports donation), so a
        # resumable run is as memory-flat as a single-shot one.
        donate = (0,) if self._donate else ()
        self._jitted_segment = jax.jit(self._segment, donate_argnums=donate)
        self._jitted_inject = jax.jit(self._inject, donate_argnums=donate)
        self._jitted_park = jax.jit(self._park, donate_argnums=donate)

    # ------------------------------------------------------------------
    # State construction
    # ------------------------------------------------------------------

    def _layout_slot(self, v: str) -> Optional[tuple[str, int]]:
        """``(packed_var, slot)`` when ``v`` lives in a packed layout group
        (see ``ir.StateLayout``), else None."""
        layout = self.lowered.state_layout
        return None if layout is None else layout.slot_of(v)

    def read_top(self, state: dict[str, Any], v: str) -> Array:
        """Current ``[batch, ...]`` value of a cross-block variable, in row
        order.  Layout-transparent: a packed member is sliced out of its
        grouped array, so inject/park/outputs/Stepper callers never see the
        packed layout.  (Use :meth:`unpermute` for caller lane order.)
        """
        slot = self._layout_slot(v)
        if slot is None:
            return state["tops"][v]
        packed, idx = slot
        return state["tops"][packed][:, idx]

    def init_state(self, inputs: dict[str, Array]) -> dict[str, Any]:
        cfg = self.config
        z, d = cfg.batch_size, cfg.max_depth
        lp = self.lowered
        tops: dict[str, Array] = {}
        stacks: dict[str, Array] = {}
        ptrs: dict[str, Array] = {}
        for v in self._state_vars:
            spec = lp.var_specs[v]
            tops[v] = jnp.zeros((z,) + tuple(spec.shape), spec.dtype)
            if v in lp.stack_vars:
                stacks[v] = jnp.zeros((d, z) + tuple(spec.shape), spec.dtype)
                ptrs[v] = jnp.zeros((z,), _I32)
        for p in lp.main_params:
            x = jnp.asarray(inputs[p])
            if x.shape != (z,) + tuple(lp.var_specs[p].shape):
                raise ValueError(
                    f"input {p!r}: expected batched shape "
                    f"{(z,) + tuple(lp.var_specs[p].shape)}, got {x.shape}"
                )
            x = x.astype(lp.var_specs[p].dtype)
            slot = self._layout_slot(p)
            if slot is None:
                tops[p] = x
            else:
                # Packed-layout member: the param's cross-block home is a
                # slot of the grouped array (the member itself is a temp).
                packed, idx = slot
                tops[packed] = tops[packed].at[:, idx].set(x)
        pc_stack = jnp.full((d, z), lp.exit_index, _I32)
        state = {
            "pc_top": jnp.full((z,), lp.entry, _I32),
            "pc_stack": pc_stack,  # slot 0 holds the exit sentinel
            "pc_ptr": jnp.ones((z,), _I32),
            "tops": tops,
            "stacks": stacks,
            "ptrs": ptrs,
            "steps": jnp.zeros((), _I32),
            # Per-member overflow flag: set when a push would land at or
            # beyond max_depth (the scatter drops it, invalidating that
            # member's results).
            "depth_exceeded": jnp.zeros((z,), jnp.bool_),
            # Per-lane fault code (FAULT_*); first fault wins, inject clears.
            "fault_code": jnp.zeros((z,), _I32),
            # Per-lane count of block dispatches the lane was active in —
            # the watchdog's clock, and cheap per-lane progress telemetry.
            "lane_steps": jnp.zeros((z,), _I32),
        }
        if cfg.compact_every is not None:
            # Which ORIGINAL lane each row currently holds.  Compaction
            # permutes rows; every identity-bearing surface inverts this
            # to restore caller lane order.  Only materialized when
            # compaction is on, so the uncompacted VM carries no overhead.
            state["lane_ids"] = jnp.arange(z, dtype=_I32)
        if self.config.collect_block_stats:
            state["block_exec"] = jnp.zeros((self.num_blocks,), _I32)
            state["block_active"] = jnp.zeros((self.num_blocks,), _I32)
            # Occupied-tile capacity accumulated over dispatches — the
            # denominator of the tile-based mean_occupancy.
            state["tile_acc"] = jnp.zeros((), _I32)
        if self.trace_capacity is not None:
            # Dispatch-trace ring buffers: one event per loop iteration at
            # index steps % capacity (so `steps` doubles as the event
            # count and the drain never needs a separate cursor).  All
            # write-only w.r.t. execution — see the module docstring.
            c = self.trace_capacity
            state["trace"] = {
                "block": jnp.full((c,), -1, _I32),
                "resident": jnp.zeros((c, self.num_blocks), _I32),
                "active": jnp.zeros((c,), _I32),
                "live": jnp.zeros((c,), _I32),
                "quarantined": jnp.zeros((c,), _I32),
                "tile": jnp.zeros((c,), _I32),
                "compacted": jnp.zeros((c,), jnp.bool_),
                "faults": jnp.zeros((c,), _I32),
            }
        return state

    def _shard_state(self, state: dict[str, Any]) -> dict[str, Any]:
        """Pin the lane layout of every state array (no-op without a mesh).

        Lane-major arrays (``[batch, ...]`` tops/pointers/masks) shard their
        leading axis over :data:`LANE_AXIS`; ``[depth, batch, ...]`` stacks
        shard axis 1; scalars and the ``[num_blocks]`` stat counters are
        replicated.  Constraining the initial carry is enough — GSPMD
        propagates the layout through the whole ``lax.while_loop``.
        """
        if self.mesh is None:
            return state
        wsc = jax.lax.with_sharding_constraint
        lane, stack, repl = (
            self._lane_sharding, self._stack_sharding, self._replicated
        )
        out = dict(state)
        out["pc_top"] = wsc(state["pc_top"], lane)
        out["pc_stack"] = wsc(state["pc_stack"], stack)
        out["pc_ptr"] = wsc(state["pc_ptr"], lane)
        out["depth_exceeded"] = wsc(state["depth_exceeded"], lane)
        out["fault_code"] = wsc(state["fault_code"], lane)
        out["lane_steps"] = wsc(state["lane_steps"], lane)
        out["tops"] = {v: wsc(x, lane) for v, x in state["tops"].items()}
        out["stacks"] = {v: wsc(x, stack) for v, x in state["stacks"].items()}
        out["ptrs"] = {v: wsc(x, lane) for v, x in state["ptrs"].items()}
        out["steps"] = wsc(state["steps"], repl)
        if "lane_ids" in state:
            out["lane_ids"] = wsc(state["lane_ids"], lane)
        if "block_exec" in state:
            out["block_exec"] = wsc(state["block_exec"], repl)
            out["block_active"] = wsc(state["block_active"], repl)
            out["tile_acc"] = wsc(state["tile_acc"], repl)
        if "trace" in state:
            # Trace rings are event-major (not lane-major): replicate.
            out["trace"] = {
                k: wsc(x, repl) for k, x in state["trace"].items()
            }
        return out

    # ------------------------------------------------------------------
    # Block body compilation
    # ------------------------------------------------------------------

    def _make_block_fn(self, bidx: int, blk: ir.LBlock) -> Callable:
        lowered = self.lowered
        temp_vars = lowered.temp_vars
        use_kernel = self.config.use_kernel
        max_depth = self.config.max_depth
        quarantine = self.config.on_fault == "quarantine"
        detect_nonfinite = self.config.detect_nonfinite
        budget = self.config.lane_step_budget
        exit_idx = lowered.exit_index
        # Bound in __init__: plain Pallas wrappers, or shard-local (per
        # device lane slice via shard_map) versions when a mesh is set.
        kernel_push, kernel_peek = self._kernel_push, self._kernel_peek

        def run(state: dict[str, Any]) -> dict[str, Any]:
            mask = state["pc_top"] == bidx
            fault_code = state["fault_code"]
            if quarantine:
                # Quarantined lanes never dispatch again: every masked
                # update below sees them as inactive, freezing their state.
                mask = jnp.logical_and(mask, fault_code == FAULT_OK)
            imask = mask.astype(_I32)
            tops = dict(state["tops"])
            stacks = dict(state["stacks"])
            ptrs = dict(state["ptrs"])
            depth_exceeded = state["depth_exceeded"]
            temps: dict[str, Array] = {}

            def set_fault(where: Array, code: int) -> None:
                # First fault wins: only OK lanes take a new code.
                nonlocal fault_code
                fault_code = jnp.where(
                    jnp.logical_and(where, fault_code == FAULT_OK),
                    jnp.asarray(code, _I32),
                    fault_code,
                )

            def check_finite(val: Array) -> None:
                # Opt-in NONFINITE detection on values entering VM state.
                if not jnp.issubdtype(val.dtype, jnp.inexact):
                    return
                bad = jnp.logical_not(jnp.isfinite(val))
                if val.ndim > 1:
                    bad = jnp.any(bad, axis=tuple(range(1, val.ndim)))
                set_fault(jnp.logical_and(mask, bad), FAULT_NONFINITE)

            def read(v: str) -> Array:
                return temps[v] if v in temp_vars else tops[v]

            def write(v: str, val: Array) -> None:
                if v in temp_vars:
                    temps[v] = val
                else:
                    if detect_nonfinite:
                        check_finite(val)
                    tops[v] = _masked(mask, val.astype(tops[v].dtype), tops[v])

            for op in blk.ops:
                if isinstance(op, ir.LPrim):
                    if not op.ins and not op.batched:
                        # Nullary primitive (constant): broadcast to the batch.
                        z = mask.shape[0]
                        outs = op.fn()
                        outs = outs if isinstance(outs, tuple) else (outs,)
                        outs = tuple(
                            jnp.broadcast_to(
                                jnp.asarray(o), (z,) + jnp.shape(jnp.asarray(o))
                            )
                            for o in outs
                        )
                    else:
                        fn = op.fn if op.batched else jax.vmap(op.fn)
                        outs = fn(*[read(i) for i in op.ins])
                        if len(op.outs) == 1:
                            outs = (outs,)
                    for name, val in zip(op.outs, outs):
                        write(name, val)
                elif isinstance(op, ir.LPush):
                    old_top = tops[op.var]
                    overflow = jnp.logical_and(
                        mask, ptrs[op.var] >= max_depth
                    )
                    depth_exceeded = jnp.logical_or(depth_exceeded, overflow)
                    set_fault(overflow, FAULT_STACK_OVERFLOW)
                    if use_kernel:
                        stacks[op.var] = kernel_push(
                            stacks[op.var], ptrs[op.var], old_top, mask
                        )
                    else:
                        stacks[op.var] = _scatter_push(
                            stacks[op.var], ptrs[op.var], old_top, mask
                        )
                    ptrs[op.var] = ptrs[op.var] + imask
                    new_top = read(op.src)
                    if detect_nonfinite:
                        check_finite(new_top)
                    tops[op.var] = _masked(mask, new_top, old_top)
                elif isinstance(op, ir.LPop):
                    new_ptr = ptrs[op.var] - imask
                    if use_kernel:
                        restored = kernel_peek(stacks[op.var], new_ptr)
                    else:
                        restored = _gather_top(stacks[op.var], new_ptr)
                    tops[op.var] = _masked(mask, restored, tops[op.var])
                    ptrs[op.var] = new_ptr
                else:  # pragma: no cover
                    raise AssertionError(op)

            pc_top = state["pc_top"]
            pc_stack = state["pc_stack"]
            pc_ptr = state["pc_ptr"]
            t = blk.term
            if isinstance(t, ir.LJump):
                pc_top = jnp.where(mask, t.target, pc_top)
            elif isinstance(t, ir.LBranch):
                cond = read(t.var)
                pc_top = jnp.where(
                    mask, jnp.where(cond, t.true, t.false), pc_top
                )
            elif isinstance(t, ir.LPushJump):
                # Bury the return address; jump to the callee entry.
                ret = jnp.full_like(pc_top, t.ret)
                pc_overflow = jnp.logical_and(mask, pc_ptr >= max_depth)
                depth_exceeded = jnp.logical_or(depth_exceeded, pc_overflow)
                set_fault(pc_overflow, FAULT_STACK_OVERFLOW)
                pc_stack = _scatter_push(pc_stack, pc_ptr, ret, mask)
                pc_ptr = pc_ptr + imask
                pc_top = jnp.where(mask, t.target, pc_top)
            elif isinstance(t, ir.LReturn):
                new_ptr = pc_ptr - imask
                restored = _gather_top(pc_stack, new_ptr)
                pc_top = jnp.where(mask, restored, pc_top)
                pc_ptr = new_ptr
            else:  # pragma: no cover
                raise AssertionError(t)

            # Watchdog: lanes pay one tick per dispatch they were active
            # in; a lane that burns its budget without halting is faulted.
            lane_steps = state["lane_steps"] + imask
            if budget is not None:
                set_fault(
                    jnp.logical_and(
                        jnp.logical_and(mask, lane_steps >= budget),
                        pc_top < exit_idx,
                    ),
                    FAULT_WATCHDOG,
                )

            out = dict(state)
            out.update(
                pc_top=pc_top,
                pc_stack=pc_stack,
                pc_ptr=pc_ptr,
                tops=tops,
                stacks=stacks,
                ptrs=ptrs,
                depth_exceeded=depth_exceeded,
                fault_code=fault_code,
                lane_steps=lane_steps,
            )
            return out

        def scoped_run(state: dict[str, Any]) -> dict[str, Any]:
            # Label the block body in the HLO metadata so device profiles
            # (jax.profiler / XProf) line up with DispatchTrace events by
            # block id.  Pure metadata — numerics and scheduling are
            # untouched.
            with jax.named_scope(f"pcvm.block{bidx}"):
                return run(state)

        return scoped_run

    # ------------------------------------------------------------------
    # The VM loop
    # ------------------------------------------------------------------

    def _pick_block(self, state: dict[str, Any]) -> Array:
        """The schedule's block choice for one dispatch (traced).

        With a mesh this is one of the two global reductions in the whole
        program (the other is liveness in ``cond``): a min/argmax over the
        per-lane pc values that all-reduces ONE i32 scalar per iteration —
        there is deliberately no lane-shaped cross-device traffic here.
        """
        exit_idx = self.lowered.exit_index
        pc_top = state["pc_top"]
        live = self._live_mask(state)
        schedule = self.config.schedule
        if schedule in ("popular", "lookahead"):
            # Occupancy argmax: the block where most live members reside.
            # The [num_blocks] histogram is replicated; the scatter-add over
            # lanes reduces to a per-block integer sum (associative, so the
            # result is identical however lanes are placed).
            counts = (
                jnp.zeros((self.num_blocks,), _I32)
                .at[jnp.where(live, pc_top, self.num_blocks)]
                .add(1, mode="drop")
            )
            if schedule == "popular":
                return jnp.argmax(counts).astype(_I32)
            # Lookahead: own residents count double, plus the residents of
            # the block's CFG successors — a populated block that feeds
            # other populated blocks re-converges the batch fastest.  Only
            # resident blocks are eligible (score -1 keeps empty blocks
            # out); integer arithmetic on a replicated [B] vector, so the
            # pick is deterministic and placement-independent.
            score = 2 * counts + self._succ_matrix @ counts
            score = jnp.where(counts > 0, score, -1)
            return jnp.argmax(score).astype(_I32)
        # Earliest-block heuristic (Algorithm 1/2's block choice).
        return jnp.min(jnp.where(live, pc_top, exit_idx)).astype(_I32)

    def _start(self, inputs: dict[str, Array]) -> dict[str, Any]:
        """Inputs -> initial VM state, with the lane layout pinned."""
        return self._shard_state(self.init_state(inputs))

    def _run(self, inputs: dict[str, Array]) -> dict[str, Any]:
        return self._loop(self._start(inputs))

    def _live_mask(self, state: dict[str, Any]) -> Array:
        """[batch] bool: lanes that still dispatch.  Under quarantine a
        faulted lane is no longer live, whatever its pc says."""
        live = state["pc_top"] < self.lowered.exit_index
        if self.config.on_fault == "quarantine":
            live = jnp.logical_and(live, state["fault_code"] == FAULT_OK)
        return live

    def _liveness_cond(self, state: dict[str, Any]) -> Array:
        # Global liveness: ``any`` over the lane axis — a single bool
        # all-reduce per iteration under a mesh.
        cond = jnp.logical_and(
            state["steps"] < self.config.max_steps,
            jnp.any(self._live_mask(state)),
        )
        if self.config.on_fault == "raise" and (
            self.config.detect_nonfinite
            or self.config.lane_step_budget is not None
        ):
            # Fail fast: a NONFINITE/WATCHDOG fault is batch-fatal under
            # "raise", so stop the loop instead of spinning to max_steps
            # (a livelocked lane would otherwise never let cond go false).
            cond = jnp.logical_and(
                cond,
                jnp.logical_not(
                    jnp.any(state["fault_code"] >= FAULT_NONFINITE)
                ),
            )
        return cond

    def _trace_event(
        self, state: dict[str, Any], block: Any, dispatch_mask: Array
    ) -> dict[str, Array]:
        """Pre-dispatch snapshot of one trace event (traced scalars).

        Everything here is *derived* from the state the scheduler already
        read — the histogram is the same scatter-add ``_pick_block`` uses
        and the counts are the same integer all-reduces the stats path
        performs — so recording cannot perturb execution.
        """
        live = self._live_mask(state)
        counts = (
            jnp.zeros((self.num_blocks,), _I32)
            .at[jnp.where(live, state["pc_top"], self.num_blocks)]
            .add(1, mode="drop")
        )
        return {
            # Pre-increment steps == this dispatch's global ordinal ==
            # its ring slot (idx = step % capacity).
            "step": state["steps"],
            "block": jnp.asarray(block, _I32),
            "resident": counts,
            "active": jnp.sum(dispatch_mask.astype(_I32)),
            "live": jnp.sum(live.astype(_I32)),
            "quarantined": jnp.sum(
                (state["fault_code"] != FAULT_OK).astype(_I32)
            ),
            "tile": _tile_capacity(dispatch_mask),
        }

    def _trace_commit(
        self, state: dict[str, Any], ev: dict[str, Array]
    ) -> dict[str, Any]:
        """Write one event into the ring (post-dispatch, steps bumped).

        The fault count is read *after* the dispatch so the event shows
        faults the dispatch itself caused; the compaction flag mirrors
        ``_maybe_compact``'s cadence condition exactly.
        """
        idx = ev["step"] % self.trace_capacity
        k = self.config.compact_every
        compacted = (
            jnp.asarray(False)
            if k is None
            else (state["steps"] % k) == 0  # post-increment, == _maybe_compact
        )
        faults = jnp.sum((state["fault_code"] != FAULT_OK).astype(_I32))
        tb = dict(state["trace"])
        tb["block"] = tb["block"].at[idx].set(ev["block"])
        tb["resident"] = tb["resident"].at[idx].set(ev["resident"])
        tb["active"] = tb["active"].at[idx].set(ev["active"])
        tb["live"] = tb["live"].at[idx].set(ev["live"])
        tb["quarantined"] = tb["quarantined"].at[idx].set(ev["quarantined"])
        tb["tile"] = tb["tile"].at[idx].set(ev["tile"])
        tb["compacted"] = tb["compacted"].at[idx].set(compacted)
        tb["faults"] = tb["faults"].at[idx].set(faults)
        out = dict(state)
        out["trace"] = tb
        return out

    def _make_body(self) -> Callable:
        """The loop body for this config's schedule (shared by the
        single-shot and segmented loops, so the two are bit-exact)."""
        collect = self.config.collect_block_stats
        tracing = self.trace_capacity is not None
        quarantine = self.config.on_fault == "quarantine"

        def resident(state, b):
            # The same mask the block body dispatches under — quarantined
            # lanes don't count toward occupancy.
            m = state["pc_top"] == b
            if quarantine:
                m = jnp.logical_and(m, state["fault_code"] == FAULT_OK)
            return m

        def body_switch(state):
            i = self._pick_block(state)
            if collect:
                m = resident(state, i)
                active = jnp.sum(m.astype(_I32))
                state = dict(state)
                state["block_exec"] = state["block_exec"].at[i].add(1)
                state["block_active"] = state["block_active"].at[i].add(active)
                state["tile_acc"] = state["tile_acc"] + _tile_capacity(m)
            ev = self._trace_event(state, i, resident(state, i)) if tracing \
                else None
            state = lax.switch(i, self._block_fns, state)
            state = dict(state)
            state["steps"] = state["steps"] + 1
            if tracing:
                state = self._trace_commit(state, ev)
            return self._maybe_compact(state)

        def body_sweep(state):
            # One trace event per sweep iteration: there is no single
            # chosen block (block = -1, obs.trace.SWEEP_BLOCK) and every
            # live lane is dispatchable, so active/tile cover the live set.
            ev = (
                self._trace_event(state, -1, self._live_mask(state))
                if tracing else None
            )
            # Run every resident block once, in index order, each under its
            # own mask — no lax.switch at all.  A member can traverse
            # several (forward) blocks within one sweep.
            for b, fn in enumerate(self._block_fns):
                if collect:
                    m = resident(state, b)
                    active = jnp.sum(m.astype(_I32))
                    state = dict(state)
                    # Count a dispatch only when it had resident members,
                    # so utilization stays comparable across schedules.
                    state["block_exec"] = (
                        state["block_exec"].at[b].add((active > 0).astype(_I32))
                    )
                    state["block_active"] = (
                        state["block_active"].at[b].add(active)
                    )
                    state["tile_acc"] = state["tile_acc"] + jnp.where(
                        active > 0, _tile_capacity(m), 0
                    )
                state = fn(state)
            state = dict(state)
            state["steps"] = state["steps"] + 1
            if tracing:
                state = self._trace_commit(state, ev)
            return self._maybe_compact(state)

        return body_sweep if self.config.schedule == "sweep" else body_switch

    # ------------------------------------------------------------------
    # Occupancy-aware lane compaction
    # ------------------------------------------------------------------

    def _compact(self, state: dict[str, Any]) -> dict[str, Any]:
        """Permute the lane axis so same-pc live lanes are contiguous.

        Stable argsort on ``(liveness, pc_top)``: live lanes group by
        program point in block order, exited/quarantined lanes sink to the
        high end.  Every lane-major array moves by the same permutation
        and ``lane_ids`` records it, so per-lane semantics are untouched —
        only the SIMD tile layout changes.  Schedules read lane state
        through permutation-invariant reductions (histogram / min / any),
        so the dispatch sequence is bit-exact with the uncompacted run.
        """
        live = self._live_mask(state)
        key = jnp.where(
            live, state["pc_top"], jnp.asarray(self.num_blocks + 1, _I32)
        )
        perm = jnp.argsort(key, stable=True)

        def take(x):  # [batch, ...] arrays
            return jnp.take(x, perm, axis=0)

        def take1(x):  # [depth, batch, ...] stacks
            return jnp.take(x, perm, axis=1)

        out = dict(state)
        for k in (
            "pc_top", "pc_ptr", "depth_exceeded",
            "fault_code", "lane_steps", "lane_ids",
        ):
            out[k] = take(state[k])
        out["pc_stack"] = take1(state["pc_stack"])
        out["tops"] = {v: take(x) for v, x in state["tops"].items()}
        out["stacks"] = {v: take1(x) for v, x in state["stacks"].items()}
        out["ptrs"] = {v: take(x) for v, x in state["ptrs"].items()}
        return self._shard_state(out)

    def _maybe_compact(self, state: dict[str, Any]) -> dict[str, Any]:
        """Compaction hook at the end of every loop body iteration."""
        k = self.config.compact_every
        if k is None:
            return state
        if k == 1:
            return self._compact(state)
        # ``steps`` was just incremented, so the first compaction lands
        # after dispatch k — a traced-counter condition, shared by the
        # single-shot and segmented loops (steps is global), so segment
        # boundaries never change where compaction happens.
        return lax.cond(
            state["steps"] % k == 0,
            self._compact,
            lambda s: self._shard_state(dict(s)),
            state,
        )

    def _lane_restore(self, state: dict[str, Any]) -> Optional[Array]:
        """Inverse lane permutation (row -> original order), or None when
        compaction is off and rows already are in caller order."""
        if self.config.compact_every is None:
            return None
        return jnp.argsort(state["lane_ids"])

    def unpermute(self, state: dict[str, Any], x: Array) -> Array:
        """View a row-order ``[batch, ...]`` array in original lane order.

        Identity when compaction is off.  Every public per-lane surface
        (results, halt/fault flags, Stepper views) goes through this, so
        callers never observe the compaction permutation.
        """
        inv = self._lane_restore(state)
        return x if inv is None else jnp.take(x, inv, axis=0)

    def _lane_select(self, state: dict[str, Any], x: Array) -> Array:
        """Translate an original-lane-order ``[batch, ...]`` array (an
        inject/park mask or fresh inputs) into current row order."""
        if self.config.compact_every is None:
            return x
        return jnp.take(x, state["lane_ids"], axis=0)

    def _loop(self, state: dict[str, Any]) -> dict[str, Any]:
        return lax.while_loop(self._liveness_cond, self._make_body(), state)

    def _segment(self, state: dict[str, Any], num_steps: Array) -> dict[str, Any]:
        """At most ``num_steps`` more loop iterations from ``state``.

        ``num_steps`` is a traced i32 scalar, so every segment size shares
        one compiled executable.  The body is the exact function the
        single-shot loop runs; only the ``cond`` gains the extra bound
        (``steps`` is part of the carry, so the bound composes with
        ``max_steps`` exactly as a single shot would observe it).
        """
        limit = jnp.minimum(
            state["steps"] + jnp.asarray(num_steps, _I32),
            jnp.asarray(self.config.max_steps, _I32),
        )

        def cond(st):
            return jnp.logical_and(
                st["steps"] < limit, self._liveness_cond(st)
            )

        return lax.while_loop(cond, self._make_body(), state)

    def run(self, inputs: dict[str, Array]) -> VMResult:
        """Execute the batched program to completion (jitted end-to-end).

        On accelerators this runs two jitted stages — state construction,
        then the while loop with the state pytree donated into it — so a
        run never holds more than one copy of the VM state.  On CPU (no
        donation support) the single composed program is used; the staged
        path would just cost an extra compile and dispatch.
        """
        # Host-side profiler annotation: a jax.profiler trace of the
        # caller shows VM runs as named spans that device profiles (and
        # DispatchTrace timelines) can be lined up against.
        with jax.profiler.TraceAnnotation("pcvm.run"):
            if not self._donate:
                return self._result(self._jitted(inputs))
            state = self._jitted_start(inputs)
            state = self._jitted_loop(state)
            return self._result(state)

    # ------------------------------------------------------------------
    # Segmented (resumable) execution
    # ------------------------------------------------------------------

    def start(self, inputs: dict[str, Array]) -> dict[str, Any]:
        """Inputs -> an initial state snapshot (lane layout pinned).

        The snapshot is an ordinary pytree of arrays; hold it on the host,
        checkpoint it, or feed it straight back into :meth:`run_segment`.
        """
        return self._jitted_start(inputs)

    def run_segment(
        self, state: dict[str, Any], num_steps: int
    ) -> dict[str, Any]:
        """Advance a snapshot by at most ``num_steps`` loop iterations.

        Returns the updated snapshot (the input snapshot is donated on
        accelerator backends — do not reuse it).  A chain of segments of
        any sizes is bit-exact with a single :meth:`run`: the segment loop
        runs the identical body and the snapshot carries every piece of
        execution state.  ``num_steps`` counts loop iterations — single
        block dispatches for ``earliest``/``popular``, whole sweeps for
        ``sweep`` — matching the ``steps`` counter.
        """
        # Segment boundaries show up as named spans in jax.profiler
        # traces, so host-loop overhead (admit/retire between segments)
        # is separable from VM time.
        with jax.profiler.TraceAnnotation("pcvm.run_segment"):
            return self._jitted_segment(state, jnp.asarray(num_steps, _I32))

    def lane_done(self, state: dict[str, Any]) -> Array:
        """Per-lane halt flags: ``[batch]`` bool, True once a lane exited.

        Like every per-lane surface, reported in original (caller) lane
        order regardless of ``compact_every``."""
        return self.unpermute(
            state, state["pc_top"] >= self.lowered.exit_index
        )

    def lane_fault(self, state: dict[str, Any]) -> Array:
        """Per-lane fault codes: ``[batch]`` i32 (see :data:`FAULT_NAMES`)."""
        return self.unpermute(state, state["fault_code"])

    def lane_faulted(self, state: dict[str, Any]) -> Array:
        """Per-lane fault flags: ``[batch]`` bool, True once a lane faulted."""
        return self.unpermute(state, state["fault_code"] != FAULT_OK)

    def lane_depth_exceeded(self, state: dict[str, Any]) -> Array:
        """Per-lane overflow flags, original lane order: ``[batch]`` bool."""
        return self.unpermute(state, state["depth_exceeded"])

    def park(self, state: dict[str, Any], mask: Array) -> dict[str, Any]:
        """Force masked lanes to the exit block (idle, excluded from
        liveness).  Used to hold lanes that have no work assigned yet."""
        return self._jitted_park(state, jnp.asarray(mask, jnp.bool_))

    def inject(
        self, state: dict[str, Any], mask: Array, inputs: dict[str, Array]
    ) -> dict[str, Any]:
        """Re-initialize the masked lanes with fresh program inputs.

        For lanes where ``mask`` is True this is exactly ``init_state``:
        pc reset to the entry block, pc/variable stacks and pointers
        cleared, overflow flags cleared, non-parameter tops zeroed, and
        parameter tops loaded from ``inputs`` (full ``[batch, ...]``
        arrays; unmasked rows are ignored).  Unmasked lanes — and the
        global step/occupancy counters — are untouched, so in-flight work
        keeps running.  This is the refill half of retire-and-refill.
        """
        cfg = self.config
        lp = self.lowered
        z = cfg.batch_size
        fresh: dict[str, Array] = {}
        for p in lp.main_params:
            x = jnp.asarray(inputs[p])
            if x.shape != (z,) + tuple(lp.var_specs[p].shape):
                raise ValueError(
                    f"inject input {p!r}: expected batched shape "
                    f"{(z,) + tuple(lp.var_specs[p].shape)}, got {x.shape}"
                )
            fresh[p] = x.astype(lp.var_specs[p].dtype)
        return self._jitted_inject(state, jnp.asarray(mask, jnp.bool_), fresh)

    def _park(self, state: dict[str, Any], mask: Array) -> dict[str, Any]:
        mask = self._lane_select(state, mask)  # caller order -> row order
        out = dict(state)
        out["pc_top"] = jnp.where(
            mask, jnp.asarray(self.lowered.exit_index, _I32), state["pc_top"]
        )
        return self._shard_state(out)

    def _inject(
        self,
        state: dict[str, Any],
        mask: Array,
        fresh: dict[str, Array],
    ) -> dict[str, Any]:
        lp = self.lowered
        # Callers address lanes by original identity; rows may be permuted.
        mask = self._lane_select(state, mask)
        fresh = {p: self._lane_select(state, x) for p, x in fresh.items()}

        def col_masked(new, old):
            # [depth, batch, ...] arrays: mask selects whole lane columns.
            m = mask.reshape((1,) + mask.shape + (1,) * (old.ndim - 2))
            return jnp.where(m, new, old)

        out = dict(state)
        out["pc_top"] = jnp.where(
            mask, jnp.asarray(lp.entry, _I32), state["pc_top"]
        )
        out["pc_ptr"] = jnp.where(mask, 1, state["pc_ptr"])
        out["pc_stack"] = col_masked(
            jnp.asarray(lp.exit_index, _I32), state["pc_stack"]
        )
        out["depth_exceeded"] = jnp.logical_and(
            state["depth_exceeded"], jnp.logical_not(mask)
        )
        # A refilled lane starts healthy: fault code and watchdog clock
        # reset with the rest of its state.
        out["fault_code"] = jnp.where(mask, FAULT_OK, state["fault_code"])
        out["lane_steps"] = jnp.where(mask, 0, state["lane_steps"])
        tops = dict(state["tops"])
        for v in self._state_vars:
            tops[v] = _masked(mask, jnp.zeros_like(tops[v]), tops[v])
        for p in lp.main_params:
            slot = self._layout_slot(p)
            if slot is None:
                tops[p] = _masked(mask, fresh[p], tops[p])
            else:
                # Packed-layout member: masked write into the param's slot
                # of the grouped array (already zeroed above with the rest
                # of VM state).
                packed, idx = slot
                tops[packed] = tops[packed].at[:, idx].set(
                    _masked(mask, fresh[p], tops[packed][:, idx])
                )
        out["tops"] = tops
        out["stacks"] = {
            v: col_masked(jnp.zeros_like(s), s)
            for v, s in state["stacks"].items()
        }
        out["ptrs"] = {
            v: jnp.where(mask, 0, p) for v, p in state["ptrs"].items()
        }
        return self._shard_state(out)

    def result(self, state: dict[str, Any]) -> VMResult:
        """Materialize a :class:`VMResult` from a state snapshot.

        Valid on any snapshot; ``converged`` reports whether *all* lanes
        have halted (partial snapshots simply report in-flight tops)."""
        return self._result(state)

    def get_trace(self, state: dict[str, Any]):
        """Drain the dispatch-trace ring buffer from a state snapshot.

        Returns a :class:`repro.obs.trace.DispatchTrace` (host numpy,
        oldest surviving event first), or ``None`` when the VM was built
        without ``trace=``.  Valid on any snapshot — mid-run, between
        :meth:`run_segment` calls, or after completion; draining syncs
        the device (it reads the buffers) but does not consume them, so
        a later drain sees the same events plus any new ones.
        """
        if self.trace_capacity is None:
            return None
        from repro.obs.trace import drain

        buffers = jax.device_get(state["trace"])
        total = int(jax.device_get(state["steps"]))
        return drain(
            buffers,
            total=total,
            schedule=self.config.schedule,
            num_blocks=self.num_blocks,
            batch_size=self.config.batch_size,
        )

    def _result(self, state) -> VMResult:
        lp = self.lowered
        # Restore caller lane order on every per-lane array (identity when
        # compaction is off) — compaction must be invisible in results.
        inv = self._lane_restore(state)

        def restore(x):
            return x if (x is None or inv is None) else jnp.take(x, inv, 0)

        outputs = {o: restore(self.read_top(state, o)) for o in lp.main_outputs}
        done = state["pc_top"] >= lp.exit_index
        if self.config.on_fault == "quarantine":
            # A quarantined lane will never reach the exit block; the run
            # still converged if every lane either halted or faulted.
            done = jnp.logical_or(done, state["fault_code"] != FAULT_OK)
        converged = jnp.all(done)
        block_exec = state.get("block_exec")
        block_active = state.get("block_active")
        tag_stats: dict[str, tuple[int, int]] = {}
        mean_occ = float("nan")
        mean_lane_occ = float("nan")
        steps = None
        masked_updates = None
        if block_exec is not None:
            be = jax.device_get(block_exec)
            ba = jax.device_get(block_active)
            for tag, entries in self._tag_blocks.items():
                execs = sum(int(be[b]) * m for b, m in entries)
                active = sum(int(ba[b]) * m for b, m in entries)
                tag_stats[tag] = (execs, active)
            dispatches = int(be.sum())
            tile_cap = int(jax.device_get(state["tile_acc"]))
            if dispatches:
                # Tile-based SIMD occupancy: actives / occupied-tile
                # capacity (see OCCUPANCY_TILE).  The legacy whole-batch
                # ratio rides along for trajectory comparisons.
                mean_lane_occ = float(ba.sum()) / (
                    dispatches * self.config.batch_size
                )
            if tile_cap:
                mean_occ = float(ba.sum()) / tile_cap
            steps = int(jax.device_get(state["steps"]))
            masked_updates = sum(
                int(be[b]) * w for b, w in enumerate(self._masked_writes)
            )
        sched = SchedulerStats(
            schedule=self.config.schedule,
            fused=lp.fused_from is not None,
            num_blocks=self.num_blocks,
            steps=steps,
            mean_occupancy=mean_occ,
            fused_from=lp.fused_from,
            num_devices=self.mesh.size if self.mesh is not None else 1,
            mean_lane_occupancy=mean_lane_occ,
            compact_every=self.config.compact_every,
            masked_updates=masked_updates,
        )
        return VMResult(
            outputs=outputs,
            steps=state["steps"],
            converged=converged,
            block_exec=block_exec,
            block_active=block_active,
            tag_stats=tag_stats,
            depth_exceeded=restore(state.get("depth_exceeded")),
            sched=sched,
            fault_code=restore(state.get("fault_code")),
            lane_steps=restore(state.get("lane_steps")),
            # Tracing syncs here (the drain reads the device buffers) —
            # like collect_block_stats, enabling it trades result-time
            # asynchrony for observability.
            trace=self.get_trace(state),
        )

    # ------------------------------------------------------------------
    # AOT entry points (for dry-runs and benchmarking)
    # ------------------------------------------------------------------

    def lower(self, inputs: dict[str, Array]):
        return self._jitted.lower(inputs)

    def step_fn(self) -> Callable:
        """One VM step as a standalone jittable function of the state.

        Honors ``config.schedule``: a single scheduled dispatch for
        ``earliest``/``popular``, a full masked pass over every block for
        ``sweep``.
        """

        def step(state):
            if self.config.schedule == "sweep":
                for fn in self._block_fns:
                    state = fn(state)
                return state
            i = self._pick_block(state)
            return lax.switch(i, self._block_fns, state)

        return step
