"""Program-counter autobatching VM (paper Algorithm 2), TPU-native.

The whole batched program executes as ONE ``jax.lax.while_loop`` whose body

  1. picks the earliest block index any live member's pc-top points at,
  2. dispatches to that block's fused body via ``jax.lax.switch``,
  3. masks all state updates to the locally-active members.

Because recursion is materialized into fixed-shape ``[depth, batch, ...]``
stack arrays, the VM contains no host control flow at all: it jits, lowers
and compiles like any other XLA program, and members at *different stack
depths* batch together whenever their pc-tops coincide (the paper's central
contribution).

Primitive-execution strategy is *masking* (`jnp.where` selects), which is
the TPU-friendly choice (see DESIGN.md §2).  Stack traffic — the only
gathers/scatters — is confined to pushes and pops thanks to the top-of-stack
cache (paper opt. iv), and can be routed through the Pallas ``stack_ops``
kernel on TPU (``use_kernel=True``).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import ir

Array = jax.Array
_I32 = jnp.int32


def _bcast(mask: Array, val: Array) -> Array:
    """Broadcast a [Z] bool mask against a [Z, ...] value."""
    return mask.reshape(mask.shape + (1,) * (val.ndim - 1))


def _masked(mask: Array, new: Array, old: Array) -> Array:
    return jnp.where(_bcast(mask, new), new, old)


def _scatter_push(stack: Array, ptr: Array, val: Array, mask: Array) -> Array:
    """Bury ``val`` at depth ``ptr`` for active rows. stack: [D, Z, ...]."""
    z = stack.shape[1]
    rows = jnp.where(mask, ptr, stack.shape[0])  # OOB rows dropped
    return stack.at[rows, jnp.arange(z)].set(val, mode="drop")


def _gather_top(stack: Array, ptr: Array) -> Array:
    z = stack.shape[1]
    return stack[jnp.clip(ptr, 0, stack.shape[0] - 1), jnp.arange(z)]


@dataclass(frozen=True)
class VMConfig:
    batch_size: int
    max_depth: int = 32  # stack slots (usable call depth = max_depth - 1)
    max_steps: int = 1_000_000
    use_kernel: bool = False  # route stack traffic through Pallas stack_ops
    collect_block_stats: bool = True


@dataclass
class VMResult:
    outputs: dict[str, Array]
    steps: Array
    converged: Array  # bool: all members halted within max_steps
    block_exec: Optional[Array]  # [num_blocks] times each block ran
    block_active: Optional[Array]  # [num_blocks] total active members
    tag_stats: dict[str, tuple[int, int]]  # tag -> (execs, active) post-run


class ProgramCounterVM:
    """Compiled batched executor for a :class:`ir.LoweredProgram`."""

    def __init__(self, lowered: ir.LoweredProgram, config: VMConfig):
        self.lowered = lowered
        self.config = config
        self.num_blocks = len(lowered.blocks)
        self._state_vars = [
            v
            for v in sorted(lowered.var_specs)
            if v not in lowered.temp_vars
        ]
        self._block_fns = [
            self._make_block_fn(i, blk) for i, blk in enumerate(lowered.blocks)
        ]
        # tag -> [(block_idx, multiplicity)] for post-run instrumentation.
        self._tag_blocks: dict[str, list[tuple[int, int]]] = {}
        for i, blk in enumerate(lowered.blocks):
            for op in blk.ops:
                if isinstance(op, ir.LPrim) and op.tag:
                    entry = self._tag_blocks.setdefault(op.tag, [])
                    entry.append((i, 1))
        self._jitted = jax.jit(self._run)

    # ------------------------------------------------------------------
    # State construction
    # ------------------------------------------------------------------

    def init_state(self, inputs: dict[str, Array]) -> dict[str, Any]:
        cfg = self.config
        z, d = cfg.batch_size, cfg.max_depth
        lp = self.lowered
        tops: dict[str, Array] = {}
        stacks: dict[str, Array] = {}
        ptrs: dict[str, Array] = {}
        for v in self._state_vars:
            spec = lp.var_specs[v]
            tops[v] = jnp.zeros((z,) + tuple(spec.shape), spec.dtype)
            if v in lp.stack_vars:
                stacks[v] = jnp.zeros((d, z) + tuple(spec.shape), spec.dtype)
                ptrs[v] = jnp.zeros((z,), _I32)
        for p in lp.main_params:
            x = jnp.asarray(inputs[p])
            if x.shape != (z,) + tuple(lp.var_specs[p].shape):
                raise ValueError(
                    f"input {p!r}: expected batched shape "
                    f"{(z,) + tuple(lp.var_specs[p].shape)}, got {x.shape}"
                )
            tops[p] = x.astype(lp.var_specs[p].dtype)
        pc_stack = jnp.full((d, z), lp.exit_index, _I32)
        state = {
            "pc_top": jnp.full((z,), lp.entry, _I32),
            "pc_stack": pc_stack,  # slot 0 holds the exit sentinel
            "pc_ptr": jnp.ones((z,), _I32),
            "tops": tops,
            "stacks": stacks,
            "ptrs": ptrs,
            "steps": jnp.zeros((), _I32),
        }
        if self.config.collect_block_stats:
            state["block_exec"] = jnp.zeros((self.num_blocks,), _I32)
            state["block_active"] = jnp.zeros((self.num_blocks,), _I32)
        return state

    # ------------------------------------------------------------------
    # Block body compilation
    # ------------------------------------------------------------------

    def _make_block_fn(self, bidx: int, blk: ir.LBlock) -> Callable:
        lowered = self.lowered
        temp_vars = lowered.temp_vars
        use_kernel = self.config.use_kernel

        if use_kernel:
            from repro.kernels.stack_ops import ops as _sk

        def run(state: dict[str, Any]) -> dict[str, Any]:
            mask = state["pc_top"] == bidx
            imask = mask.astype(_I32)
            tops = dict(state["tops"])
            stacks = dict(state["stacks"])
            ptrs = dict(state["ptrs"])
            temps: dict[str, Array] = {}

            def read(v: str) -> Array:
                return temps[v] if v in temp_vars else tops[v]

            def write(v: str, val: Array) -> None:
                if v in temp_vars:
                    temps[v] = val
                else:
                    tops[v] = _masked(mask, val.astype(tops[v].dtype), tops[v])

            for op in blk.ops:
                if isinstance(op, ir.LPrim):
                    if not op.ins and not op.batched:
                        # Nullary primitive (constant): broadcast to the batch.
                        z = mask.shape[0]
                        outs = op.fn()
                        outs = outs if isinstance(outs, tuple) else (outs,)
                        outs = tuple(
                            jnp.broadcast_to(
                                jnp.asarray(o), (z,) + jnp.shape(jnp.asarray(o))
                            )
                            for o in outs
                        )
                    else:
                        fn = op.fn if op.batched else jax.vmap(op.fn)
                        outs = fn(*[read(i) for i in op.ins])
                        if len(op.outs) == 1:
                            outs = (outs,)
                    for name, val in zip(op.outs, outs):
                        write(name, val)
                elif isinstance(op, ir.LPush):
                    old_top = tops[op.var]
                    if use_kernel:
                        stacks[op.var] = _sk.masked_push(
                            stacks[op.var], ptrs[op.var], old_top, mask
                        )
                    else:
                        stacks[op.var] = _scatter_push(
                            stacks[op.var], ptrs[op.var], old_top, mask
                        )
                    ptrs[op.var] = ptrs[op.var] + imask
                    tops[op.var] = _masked(mask, read(op.src), old_top)
                elif isinstance(op, ir.LPop):
                    new_ptr = ptrs[op.var] - imask
                    if use_kernel:
                        restored = _sk.masked_peek(stacks[op.var], new_ptr)
                    else:
                        restored = _gather_top(stacks[op.var], new_ptr)
                    tops[op.var] = _masked(mask, restored, tops[op.var])
                    ptrs[op.var] = new_ptr
                else:  # pragma: no cover
                    raise AssertionError(op)

            pc_top = state["pc_top"]
            pc_stack = state["pc_stack"]
            pc_ptr = state["pc_ptr"]
            t = blk.term
            if isinstance(t, ir.LJump):
                pc_top = jnp.where(mask, t.target, pc_top)
            elif isinstance(t, ir.LBranch):
                cond = read(t.var)
                pc_top = jnp.where(
                    mask, jnp.where(cond, t.true, t.false), pc_top
                )
            elif isinstance(t, ir.LPushJump):
                # Bury the return address; jump to the callee entry.
                ret = jnp.full_like(pc_top, t.ret)
                pc_stack = _scatter_push(pc_stack, pc_ptr, ret, mask)
                pc_ptr = pc_ptr + imask
                pc_top = jnp.where(mask, t.target, pc_top)
            elif isinstance(t, ir.LReturn):
                new_ptr = pc_ptr - imask
                restored = _gather_top(pc_stack, new_ptr)
                pc_top = jnp.where(mask, restored, pc_top)
                pc_ptr = new_ptr
            else:  # pragma: no cover
                raise AssertionError(t)

            out = dict(state)
            out.update(
                pc_top=pc_top,
                pc_stack=pc_stack,
                pc_ptr=pc_ptr,
                tops=tops,
                stacks=stacks,
                ptrs=ptrs,
            )
            return out

        return run

    # ------------------------------------------------------------------
    # The VM loop
    # ------------------------------------------------------------------

    def _run(self, inputs: dict[str, Array]) -> dict[str, Any]:
        lp = self.lowered
        exit_idx = lp.exit_index
        state = self.init_state(inputs)

        def cond(state):
            return jnp.logical_and(
                state["steps"] < self.config.max_steps,
                jnp.any(state["pc_top"] < exit_idx),
            )

        def body(state):
            pc_top = state["pc_top"]
            live = pc_top < exit_idx
            # Earliest-block heuristic (Algorithm 1/2's block choice).
            i = jnp.min(jnp.where(live, pc_top, exit_idx)).astype(_I32)
            if self.config.collect_block_stats:
                active = jnp.sum((pc_top == i).astype(_I32))
                state = dict(state)
                state["block_exec"] = state["block_exec"].at[i].add(1)
                state["block_active"] = state["block_active"].at[i].add(active)
            state = lax.switch(i, self._block_fns, state)
            state = dict(state)
            state["steps"] = state["steps"] + 1
            return state

        return lax.while_loop(cond, body, state)

    def run(self, inputs: dict[str, Array]) -> VMResult:
        """Execute the batched program to completion (jitted end-to-end)."""
        state = self._jitted(inputs)
        return self._result(state)

    def _result(self, state) -> VMResult:
        lp = self.lowered
        outputs = {o: state["tops"][o] for o in lp.main_outputs}
        converged = jnp.all(state["pc_top"] >= lp.exit_index)
        block_exec = state.get("block_exec")
        block_active = state.get("block_active")
        tag_stats: dict[str, tuple[int, int]] = {}
        if block_exec is not None:
            be = jax.device_get(block_exec)
            ba = jax.device_get(block_active)
            for tag, entries in self._tag_blocks.items():
                execs = sum(int(be[b]) * m for b, m in entries)
                active = sum(int(ba[b]) * m for b, m in entries)
                tag_stats[tag] = (execs, active)
        return VMResult(
            outputs=outputs,
            steps=state["steps"],
            converged=converged,
            block_exec=block_exec,
            block_active=block_active,
            tag_stats=tag_stats,
        )

    # ------------------------------------------------------------------
    # AOT entry points (for dry-runs and benchmarking)
    # ------------------------------------------------------------------

    def lower(self, inputs: dict[str, Array]):
        return self._jitted.lower(inputs)

    def step_fn(self) -> Callable:
        """One VM step as a standalone jittable function of the state."""

        def step(state):
            pc_top = state["pc_top"]
            live = pc_top < self.lowered.exit_index
            i = jnp.min(
                jnp.where(live, pc_top, self.lowered.exit_index)
            ).astype(_I32)
            return lax.switch(i, self._block_fns, state)

        return step
