"""Composable pass pipeline over the lowered IR (ROADMAP item 5).

The seed hardcoded one transform sequence inside ``lowering.lower`` and
``fusion.fuse``.  This module re-expresses every lowered-IR transform as a
:class:`Pass` — a named, pure ``LoweredProgram -> LoweredProgram`` rewrite —
and runs them through :class:`PassPipeline`, which can execute the verifier
(verifier.py) between every pass so a broken transform is caught *at the
pass that produced it* rather than as a silent wrong answer at runtime.

Passes:

* :class:`JumpChainFusion`    — superblock fusion (fusion.py steps 1–3).
* :class:`PopPushElimination` — paper opt. (v), as a pure pass.
* :class:`TempDetection`      — paper opt. (ii), recomputed after rewrites.
* :class:`DeadCodeElimination` — removes untagged primitives whose outputs
  are dead under :class:`analysis.LoweredLiveness` and drops variables that
  no longer appear anywhere from ``var_specs``, shrinking the masked-update
  footprint the VM pays on every dispatch (VM state is exactly
  ``var_specs - temp_vars``).

:func:`diagnose` bundles the verifier + analyses into a
:class:`Diagnostics` report — the backing for ``fn.diagnostics()`` and the
``tools/irlint.py`` CLI.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Protocol, Sequence, runtime_checkable

from . import analysis, fusion, ir, lowering, verifier


@runtime_checkable
class Pass(Protocol):
    """A named, pure rewrite of a lowered program."""

    name: str

    def run(self, lowered: ir.LoweredProgram) -> ir.LoweredProgram:
        ...  # pragma: no cover - protocol


class PassError(RuntimeError):
    """A pass crashed or produced a program the verifier rejects."""


@dataclass
class PassPipeline:
    """Run a sequence of passes, optionally verifying between every pass.

    With ``verify=True`` the input program and the output of every pass is
    checked by :func:`verifier.verify`; a failure raises :class:`PassError`
    naming the offending pass.  ``debug=True`` additionally appends the
    rejected program's ``pretty()`` dump to the error so the broken block
    can be read directly.
    """

    passes: Sequence[Pass]
    verify: bool = False
    debug: bool = False

    def run(self, lowered: ir.LoweredProgram) -> ir.LoweredProgram:
        self._verify(lowered, where="input program (before any pass ran)")
        for p in self.passes:
            try:
                lowered = p.run(lowered)
            except Exception as e:
                raise PassError(f"pass {p.name!r} failed: {e}") from e
            self._verify(lowered, where=f"pass {p.name!r}")
        return lowered

    def _verify(self, lowered: ir.LoweredProgram, where: str) -> None:
        if not self.verify:
            return
        try:
            verifier.verify(lowered)
        except verifier.VerificationError as e:
            msg = f"{where} produced an invalid program: {e}"
            if self.debug:
                msg += "\n--- offending program ---\n" + lowered.pretty()
            raise PassError(msg) from e


# --------------------------------------------------------------------------
# The existing transforms, as passes
# --------------------------------------------------------------------------


def _recompute_var_classes(
    blocks: list[ir.LBlock], low: ir.LoweredProgram
) -> tuple[frozenset[str], frozenset[str]]:
    stack_vars = frozenset(
        op.var
        for blk in blocks
        for op in blk.ops
        if isinstance(op, (ir.LPush, ir.LPop))
    )
    temp_vars = lowering.find_temporaries(
        blocks, stack_vars, low.main_params, low.main_outputs
    )
    return stack_vars, temp_vars


def _copy_blocks(blocks: Sequence[ir.LBlock]) -> list[ir.LBlock]:
    return [
        ir.LBlock(ops=list(b.ops), term=b.term, label=b.label) for b in blocks
    ]


@dataclass
class JumpChainFusion:
    """Superblock fusion: concatenate unconditional jump chains, drop
    unreachable blocks, record ``fused_from`` provenance (fusion.py)."""

    name: str = "jump-chain-fusion"

    def run(self, lowered: ir.LoweredProgram) -> ir.LoweredProgram:
        return fusion.fuse_chains(lowered)


@dataclass
class PopPushElimination:
    """Paper opt. (v): cancel block-local ``pop v … push v <- src`` pairs
    into masked in-place updates, then recompute the variable classes."""

    name: str = "popush-elimination"

    def run(self, lowered: ir.LoweredProgram) -> ir.LoweredProgram:
        blocks = _copy_blocks(lowered.blocks)
        lowering.popush_eliminate(blocks)
        stack_vars, temp_vars = _recompute_var_classes(blocks, lowered)
        return ir.dataclass_replace(
            lowered, blocks=blocks, stack_vars=stack_vars, temp_vars=temp_vars
        )


@dataclass
class TempDetection:
    """Paper opt. (ii): recompute which variables are block-local
    temporaries (and so never enter VM state) after earlier rewrites."""

    name: str = "temp-detection"

    def run(self, lowered: ir.LoweredProgram) -> ir.LoweredProgram:
        stack_vars, temp_vars = _recompute_var_classes(
            lowered.blocks, lowered
        )
        return ir.dataclass_replace(
            lowered, stack_vars=stack_vars, temp_vars=temp_vars
        )


@dataclass
class DeadCodeElimination:
    """Remove primitives whose outputs are dead and shrink VM state.

    Uses :class:`analysis.LoweredLiveness` (conservative about the dynamic
    ``LReturn`` edges and about values buried by ``LPush``) to delete
    untagged ``LPrim`` ops none of whose outputs are live, to a fixed
    point.  Stack ops are never removed (they move stack pointers), and
    tagged primitives are kept for the ``tag_stats`` instrumentation
    contract even when dead.  Afterwards, variables that no longer appear
    anywhere are dropped from ``var_specs`` — VM state is
    ``var_specs - temp_vars``, so each dropped variable removes one masked
    top buffer from every dispatch step.
    """

    name: str = "dead-code-elimination"

    def run(self, lowered: ir.LoweredProgram) -> ir.LoweredProgram:
        blocks = _copy_blocks(lowered.blocks)
        cur = ir.dataclass_replace(lowered, blocks=blocks)
        changed = True
        while changed:
            changed = False
            lv = analysis.LoweredLiveness(cur)
            for i, blk in enumerate(blocks):
                live = set(lv.live_out[i])
                if isinstance(blk.term, ir.LBranch):
                    live.add(blk.term.var)
                kept: list[ir.LOp] = []
                for op in reversed(blk.ops):
                    if (
                        isinstance(op, ir.LPrim)
                        and op.tag is None
                        and not (set(op.outs) & live)
                    ):
                        changed = True
                        continue
                    kept.append(op)
                    live -= set(ir.prim_writes(op))
                    live |= set(analysis.LoweredLiveness.op_reads(op))
                kept.reverse()
                blk.ops = kept
        mentioned = self._mentioned_vars(cur)
        keep = (
            mentioned
            | set(cur.main_params)
            | set(cur.main_outputs)
        )
        var_specs = {v: s for v, s in cur.var_specs.items() if v in keep}
        stack_vars, temp_vars = _recompute_var_classes(blocks, cur)
        return ir.dataclass_replace(
            cur,
            var_specs=var_specs,
            stack_vars=stack_vars,
            temp_vars=temp_vars,
        )

    @staticmethod
    def _mentioned_vars(lowered: ir.LoweredProgram) -> set[str]:
        vs: set[str] = set()
        for blk in lowered.blocks:
            for op in blk.ops:
                vs.update(ir.prim_reads(op))
                vs.update(ir.prim_writes(op))
            if isinstance(blk.term, ir.LBranch):
                vs.add(blk.term.var)
        return vs


def lowering_passes() -> tuple[Pass, ...]:
    """The post-emission cleanup `lowering.lower` runs: exactly the seed's
    popush-eliminate + find-temporaries sequence, as pipeline passes."""
    return (PopPushElimination(), TempDetection())


def fusion_passes() -> tuple[Pass, ...]:
    """`fusion.fuse` as a pipeline: chain fusion, then the block-local
    optimizations re-run on the merged superblocks (bit-exact with the
    monolithic PR-2 implementation)."""
    return (JumpChainFusion(), PopPushElimination(), TempDetection())


# --------------------------------------------------------------------------
# Diagnostics (fn.diagnostics() / tools/irlint.py)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Diagnostics:
    """Verifier + analysis summary of one lowered program."""

    num_blocks: int
    num_ops: int
    fused: bool
    num_source_blocks: Optional[int]  # pre-fusion block count, if fused
    num_state_vars: int  # masked top buffers the VM updates per dispatch
    num_stack_vars: int
    num_temp_vars: int
    dead_state_vars: tuple[str, ...]  # state DCE would remove
    dead_ops: int  # ops DCE would remove
    pc_depth: Optional[int]
    var_depths: dict[str, int] = field(default_factory=dict)
    required_max_depth: Optional[int] = None
    recursive_cycle: Optional[tuple[str, ...]] = None
    verified: bool = False
    verification_error: Optional[str] = None

    def pretty(self) -> str:
        lines = [
            f"blocks:        {self.num_blocks}"
            + (
                f" (fused from {self.num_source_blocks})"
                if self.fused
                else " (unfused)"
            ),
            f"ops:           {self.num_ops}",
            f"state vars:    {self.num_state_vars} "
            f"(stack: {self.num_stack_vars}, temps excluded: "
            f"{self.num_temp_vars})",
        ]
        if self.dead_ops or self.dead_state_vars:
            lines.append(
                f"dead:          {self.dead_ops} ops, "
                f"{len(self.dead_state_vars)} state vars "
                f"{sorted(self.dead_state_vars)}"
            )
        else:
            lines.append("dead:          none")
        if self.recursive_cycle is not None:
            lines.append(
                "stack bound:   unbounded (recursive cycle "
                + " -> ".join(self.recursive_cycle + self.recursive_cycle[:1])
                + ")"
            )
        else:
            lines.append(
                f"stack bound:   max_depth={self.required_max_depth} "
                f"(pc depth {self.pc_depth}, deepest variable stack "
                f"{max(self.var_depths.values(), default=0)})"
            )
        lines.append(
            "verifier:      ok"
            if self.verified
            else f"verifier:      FAILED: {self.verification_error}"
        )
        return "\n".join(lines)


def diagnose(lowered: ir.LoweredProgram) -> Diagnostics:
    """Run the verifier and every lowered-IR analysis over ``lowered``."""
    verified, err = True, None
    try:
        verifier.verify(lowered)
    except verifier.VerificationError as e:
        verified, err = False, str(e)
    if verified:
        depth = analysis.stack_depth_bound(lowered)
    else:  # analyses assume a well-formed program
        depth = analysis.StackDepthReport(None, {}, None, None)
    state_vars = [
        v for v in sorted(lowered.var_specs) if v not in lowered.temp_vars
    ]
    dead_state: tuple[str, ...] = ()
    dead_ops = 0
    if verified:
        after = DeadCodeElimination().run(lowered)
        after_state = {
            v for v in after.var_specs if v not in after.temp_vars
        }
        dead_state = tuple(sorted(set(state_vars) - after_state))
        dead_ops = sum(len(b.ops) for b in lowered.blocks) - sum(
            len(b.ops) for b in after.blocks
        )
    num_src = (
        len({s for srcs in lowered.fused_from.values() for s in srcs})
        if lowered.fused_from is not None
        else None
    )
    return Diagnostics(
        num_blocks=len(lowered.blocks),
        num_ops=sum(len(b.ops) for b in lowered.blocks),
        fused=lowered.fused_from is not None,
        num_source_blocks=num_src,
        num_state_vars=len(state_vars),
        num_stack_vars=len(lowered.stack_vars),
        num_temp_vars=len(lowered.temp_vars),
        dead_state_vars=dead_state,
        dead_ops=dead_ops,
        pc_depth=depth.pc_depth,
        var_depths=depth.var_depths,
        required_max_depth=depth.required_max_depth,
        recursive_cycle=depth.recursive_cycle,
        verified=verified,
        verification_error=err,
    )
