"""Composable pass pipeline over the lowered IR (ROADMAP item 5).

The seed hardcoded one transform sequence inside ``lowering.lower`` and
``fusion.fuse``.  This module re-expresses every lowered-IR transform as a
:class:`Pass` — a named, pure ``LoweredProgram -> LoweredProgram`` rewrite —
and runs them through :class:`PassPipeline`, which can execute the verifier
(verifier.py) between every pass so a broken transform is caught *at the
pass that produced it* rather than as a silent wrong answer at runtime.

Passes:

* :class:`JumpChainFusion`    — superblock fusion (fusion.py steps 1–3).
* :class:`PopPushElimination` — paper opt. (v), as a pure pass.
* :class:`TempDetection`      — paper opt. (ii), recomputed after rewrites.
* :class:`DeadCodeElimination` — removes untagged primitives whose outputs
  are dead under :class:`analysis.LoweredLiveness` and drops variables that
  no longer appear anywhere from ``var_specs``, shrinking the masked-update
  footprint the VM pays on every dispatch (VM state is exactly
  ``var_specs - temp_vars``).
* :class:`ProfileGuidedFusion`, :class:`StateLayoutPacking`,
  :class:`BlockReordering` — the profile-guided pipeline
  (:func:`pgo_passes`): trace-driven superblock formation across the
  pinned call boundaries structural fusion must skip, hot-state layout
  packing that cuts masked per-dispatch updates, and frequency-ordered
  block renumbering.  All three consume a measured
  ``obs.BlockProfile`` (via the seeded ``block_weights`` provenance).

:func:`diagnose` bundles the verifier + analyses into a
:class:`Diagnostics` report — the backing for ``fn.diagnostics()`` and the
``tools/irlint.py`` CLI.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Protocol, Sequence, runtime_checkable

from . import analysis, fusion, ir, lowering, verifier


@runtime_checkable
class Pass(Protocol):
    """A named, pure rewrite of a lowered program."""

    name: str

    def run(self, lowered: ir.LoweredProgram) -> ir.LoweredProgram:
        ...  # pragma: no cover - protocol


class PassError(RuntimeError):
    """A pass crashed or produced a program the verifier rejects."""


@dataclass
class PassPipeline:
    """Run a sequence of passes, optionally verifying between every pass.

    With ``verify=True`` the input program and the output of every pass is
    checked by :func:`verifier.verify`; a failure raises :class:`PassError`
    naming the offending pass.  ``debug=True`` additionally appends the
    rejected program's ``pretty()`` dump to the error so the broken block
    can be read directly.
    """

    passes: Sequence[Pass]
    verify: bool = False
    debug: bool = False

    def run(self, lowered: ir.LoweredProgram) -> ir.LoweredProgram:
        self._verify(lowered, where="input program (before any pass ran)")
        for p in self.passes:
            try:
                lowered = p.run(lowered)
            except Exception as e:
                raise PassError(f"pass {p.name!r} failed: {e}") from e
            self._verify(lowered, where=f"pass {p.name!r}")
        return lowered

    def _verify(self, lowered: ir.LoweredProgram, where: str) -> None:
        if not self.verify:
            return
        try:
            verifier.verify(lowered)
        except verifier.VerificationError as e:
            msg = f"{where} produced an invalid program: {e}"
            if self.debug:
                msg += "\n--- offending program ---\n" + lowered.pretty()
            raise PassError(msg) from e


# --------------------------------------------------------------------------
# The existing transforms, as passes
# --------------------------------------------------------------------------


def _recompute_var_classes(
    blocks: list[ir.LBlock], low: ir.LoweredProgram
) -> tuple[frozenset[str], frozenset[str]]:
    # One shared implementation (lowering.recompute_var_classes) for every
    # block-rewriting pass, including fusion.fuse_chains.
    return lowering.recompute_var_classes(
        blocks, low.main_params, low.main_outputs,
        state_layout=low.state_layout,
    )


def _copy_blocks(blocks: Sequence[ir.LBlock]) -> list[ir.LBlock]:
    return [
        ir.LBlock(ops=list(b.ops), term=b.term, label=b.label) for b in blocks
    ]


@dataclass
class JumpChainFusion:
    """Superblock fusion: concatenate unconditional jump chains, drop
    unreachable blocks, record ``fused_from`` provenance (fusion.py)."""

    name: str = "jump-chain-fusion"

    def run(self, lowered: ir.LoweredProgram) -> ir.LoweredProgram:
        return fusion.fuse_chains(lowered)


@dataclass
class PopPushElimination:
    """Paper opt. (v): cancel block-local ``pop v … push v <- src`` pairs
    into masked in-place updates, then recompute the variable classes."""

    name: str = "popush-elimination"

    def run(self, lowered: ir.LoweredProgram) -> ir.LoweredProgram:
        blocks = _copy_blocks(lowered.blocks)
        lowering.popush_eliminate(blocks)
        stack_vars, temp_vars = _recompute_var_classes(blocks, lowered)
        return ir.dataclass_replace(
            lowered, blocks=blocks, stack_vars=stack_vars, temp_vars=temp_vars
        )


@dataclass
class TempDetection:
    """Paper opt. (ii): recompute which variables are block-local
    temporaries (and so never enter VM state) after earlier rewrites."""

    name: str = "temp-detection"

    def run(self, lowered: ir.LoweredProgram) -> ir.LoweredProgram:
        stack_vars, temp_vars = _recompute_var_classes(
            lowered.blocks, lowered
        )
        return ir.dataclass_replace(
            lowered, stack_vars=stack_vars, temp_vars=temp_vars
        )


@dataclass
class DeadCodeElimination:
    """Remove primitives whose outputs are dead and shrink VM state.

    Uses :class:`analysis.LoweredLiveness` (conservative about the dynamic
    ``LReturn`` edges and about values buried by ``LPush``) to delete
    untagged ``LPrim`` ops none of whose outputs are live, to a fixed
    point.  Stack ops are never removed (they move stack pointers), and
    tagged primitives are kept for the ``tag_stats`` instrumentation
    contract even when dead.  Afterwards, variables that no longer appear
    anywhere are dropped from ``var_specs`` — VM state is
    ``var_specs - temp_vars``, so each dropped variable removes one masked
    top buffer from every dispatch step.
    """

    name: str = "dead-code-elimination"

    def run(self, lowered: ir.LoweredProgram) -> ir.LoweredProgram:
        blocks = _copy_blocks(lowered.blocks)
        cur = ir.dataclass_replace(lowered, blocks=blocks)
        changed = True
        while changed:
            changed = False
            lv = analysis.LoweredLiveness(cur)
            for i, blk in enumerate(blocks):
                live = set(lv.live_out[i])
                if isinstance(blk.term, ir.LBranch):
                    live.add(blk.term.var)
                kept: list[ir.LOp] = []
                for op in reversed(blk.ops):
                    if (
                        isinstance(op, ir.LPrim)
                        and op.tag is None
                        and not (set(op.outs) & live)
                    ):
                        changed = True
                        continue
                    kept.append(op)
                    live -= set(ir.prim_writes(op))
                    live |= set(analysis.LoweredLiveness.op_reads(op))
                kept.reverse()
                blk.ops = kept
        mentioned = self._mentioned_vars(cur)
        keep = (
            mentioned
            | set(cur.main_params)
            | set(cur.main_outputs)
        )
        var_specs = {v: s for v, s in cur.var_specs.items() if v in keep}
        stack_vars, temp_vars = _recompute_var_classes(blocks, cur)
        return ir.dataclass_replace(
            cur,
            var_specs=var_specs,
            stack_vars=stack_vars,
            temp_vars=temp_vars,
        )

    @staticmethod
    def _mentioned_vars(lowered: ir.LoweredProgram) -> set[str]:
        vs: set[str] = set()
        for blk in lowered.blocks:
            for op in blk.ops:
                vs.update(ir.prim_reads(op))
                vs.update(ir.prim_writes(op))
            if isinstance(blk.term, ir.LBranch):
                vs.add(blk.term.var)
        return vs


# --------------------------------------------------------------------------
# Profile-guided optimization passes (ROADMAP item 5)
# --------------------------------------------------------------------------


def _frame_blocks(blocks: Sequence[ir.LBlock], entry: int) -> list[int]:
    """Blocks of the frame rooted at ``entry``: the intraprocedural CFG
    closure following jumps, branches and call *fallthroughs* (an
    ``LPushJump`` continues at its return site; the callee is another
    frame).  Returned in discovery order, entry first."""
    frame: list[int] = []
    seen: set[int] = set()
    stack = [entry]
    while stack:
        b = stack.pop()
        if b in seen:
            continue
        seen.add(b)
        frame.append(b)
        t = blocks[b].term
        if isinstance(t, ir.LJump):
            stack.append(t.target)
        elif isinstance(t, ir.LBranch):
            stack.extend((t.true, t.false))
        elif isinstance(t, ir.LPushJump):
            stack.append(t.ret)
    return frame


@dataclass
class ProfileGuidedFusion:
    """Trace-driven superblock formation (the PGO tentpole, ROADMAP 5).

    Consumes a ``BlockProfile`` measured on *this exact program* (the
    profile's ``num_blocks`` must match) and rewrites the hot call
    boundaries that structural :class:`JumpChainFusion` must skip because
    their blocks are pinned (function entries and return sites are
    multi-predecessor joins entered dynamically):

    * a function with **exactly one call site** is merged into its caller's
      frame: the ``LPushJump`` becomes a plain ``LJump``, every ``LReturn``
      of the frame becomes an ``LJump`` to the (now unique) return site,
      and the function entry is dropped from ``func_entries`` — un-pinning
      both blocks so the follow-up :class:`JumpChainFusion` absorbs them
      into superblocks;
    * a **hot call site** of a multi-site function gets the callee frame
      *tail-duplicated* (frame-copy inlining): the copy's returns jump
      straight to this site's return address, the copy's internal calls
      still target the original entries (recursion-safe), and the original
      frame keeps serving the remaining sites.  Gated by
      ``max_inline_blocks`` so a large frame is never duplicated.

    Also seeds ``LoweredProgram.block_weights`` with the profile's
    per-block dispatch counts — the hotness signal :class:`StateLayoutPacking`
    and :class:`BlockReordering` consume, propagated by every later
    renumbering pass.

    Bit-exactness: per-lane primitive sequences are unchanged — only pc
    bookkeeping (one less pc push per merged/inlined call) and block
    boundaries move, exactly like structural fusion.
    """

    profile: object  # obs.BlockProfile (duck-typed: core must not import obs)
    min_count: int = 1
    max_inline_blocks: int = 8
    name: str = "profile-guided-fusion"

    def run(self, lowered: ir.LoweredProgram) -> ir.LoweredProgram:
        prof = self.profile
        n = len(lowered.blocks)
        if prof.num_blocks != n:
            raise ValueError(
                f"profile was measured on a {prof.num_blocks}-block program "
                f"but this program has {n} blocks — re-profile with the same "
                "schedule/fuse/dce settings the optimized run will use"
            )
        blocks = _copy_blocks(lowered.blocks)
        weights = [int(prof.dispatches[b]) for b in range(n)]
        func_entries = dict(lowered.func_entries)
        fused_from = (
            dict(lowered.fused_from)
            if lowered.fused_from is not None else None
        )
        entry_of = {e: f for f, e in func_entries.items()}
        main = entry_of[lowered.entry]

        def call_sites(entry: int) -> list[int]:
            return [
                i for i, blk in enumerate(blocks)
                if isinstance(blk.term, ir.LPushJump)
                and blk.term.target == entry
            ]

        # ---- 1. Merge single-call-site functions into their caller. ----
        for fname, entry in sorted(lowered.func_entries.items()):
            if fname == main:
                continue
            sites = call_sites(entry)
            if len(sites) != 1:
                continue
            site = sites[0]
            frame = _frame_blocks(blocks, entry)
            if site in frame:  # a self-recursive only-caller: leave it
                continue
            if weights[site] < self.min_count:
                continue
            ret = blocks[site].term.ret
            for b in frame:
                if isinstance(blocks[b].term, ir.LReturn):
                    blocks[b].term = ir.LJump(ret)
            blocks[site].term = ir.LJump(entry)
            del func_entries[fname]

        # ---- 2. Tail-duplicate small callee frames at hot call sites. ----
        for fname, entry in sorted(lowered.func_entries.items()):
            if fname == main or fname not in func_entries:
                continue
            frame = _frame_blocks(blocks, entry)
            if len(frame) > self.max_inline_blocks:
                continue
            sites = call_sites(entry)
            if len(sites) < 2:
                continue
            for site in sites:
                if weights[site] < self.min_count or site in frame:
                    continue
                ret = blocks[site].term.ret
                mapping = {b: len(blocks) + k for k, b in enumerate(frame)}
                for b in frame:
                    src = blocks[b]
                    t = src.term
                    if isinstance(t, ir.LJump):
                        t = ir.LJump(mapping[t.target])
                    elif isinstance(t, ir.LBranch):
                        t = ir.LBranch(var=t.var, true=mapping[t.true],
                                       false=mapping[t.false])
                    elif isinstance(t, ir.LPushJump):
                        # The callee entry stays original (recursion-safe);
                        # only the intraframe return site is remapped.
                        t = ir.LPushJump(target=t.target, ret=mapping[t.ret])
                    else:  # LReturn: the caller no longer pushes a ret pc
                        t = ir.LJump(ret)
                    blocks.append(ir.LBlock(
                        ops=list(src.ops), term=t,
                        label=f"{src.label}@inline{site}",
                    ))
                    # The copy runs as often as its call site did; real
                    # counts would need a re-profile, this is the estimate.
                    weights.append(min(weights[b], weights[site]))
                    if fused_from is not None:
                        fused_from[len(blocks) - 1] = fused_from[b]
                blocks[site].term = ir.LJump(mapping[entry])

        # Drop functions no remaining call site targets: their entries are
        # un-pinned so the now-private frames can be absorbed (or dropped).
        for fname, entry in list(func_entries.items()):
            if fname != main and not call_sites(entry):
                del func_entries[fname]

        stack_vars, temp_vars = lowering.recompute_var_classes(
            blocks, lowered.main_params, lowered.main_outputs,
            state_layout=lowered.state_layout,
        )
        rewritten = ir.dataclass_replace(
            lowered,
            blocks=blocks,
            func_entries=func_entries,
            fused_from=fused_from,
            stack_vars=stack_vars,
            temp_vars=temp_vars,
            block_weights=tuple(weights),
        )
        # Re-fuse immediately: the rewrites above un-pin entries and return
        # sites (and can leave whole inlined-out frames unreachable), so the
        # chain fusion that concatenates the new superblocks — and compacts
        # the dead frames away — is part of this pass's contract.  It also
        # propagates ``block_weights`` (a merged chain runs as often as its
        # head) and composes ``fused_from``.
        return fusion.fuse_chains(rewritten)


@dataclass
class StateLayoutPacking:
    """Pack hot same-spec VM state members into grouped contiguous arrays.

    Every masked ``_masked(...)`` whole-state update the VM performs per
    dispatch costs one ``jnp.where`` over a ``[batch, ...]`` buffer.  This
    pass groups state variables with identical ``(shape, dtype)`` into one
    packed ``(k,) + shape`` array per group (slot order = profile write
    weight, hottest first): inside each block that mentions members, an
    ``unpack`` prim materializes them as block-local temps and — iff any
    member was written — a single ``pack`` prim writes the group back, so a
    block that used to pay ``m`` masked updates pays one per touched group.
    The mapping is recorded as ``LoweredProgram.state_layout`` and every VM
    boundary (init/inject/park/outputs/stepper, sharding, kernels) reads
    ``tops[packed][:, slot]`` through it.
    """

    min_group: int = 2
    name: str = "state-layout-packing"

    def run(self, lowered: ir.LoweredProgram) -> ir.LoweredProgram:
        if lowered.state_layout is not None:
            raise ValueError("state layout is already packed")
        import jax

        # Candidates: plain state vars (stack vars need their own stacks;
        # temps never enter VM state in the first place).
        weights = lowered.block_weights
        mentions: dict[str, int] = {}
        writes_w: dict[str, int] = {}
        for i, blk in enumerate(lowered.blocks):
            w = int(weights[i]) if weights is not None else 1
            for op in blk.ops:
                for r in ir.prim_reads(op):
                    mentions[r] = mentions.get(r, 0) + 1
                for v in ir.prim_writes(op):
                    mentions[v] = mentions.get(v, 0) + 1
                    writes_w[v] = writes_w.get(v, 0) + w
            if isinstance(blk.term, ir.LBranch):
                mentions[blk.term.var] = mentions.get(blk.term.var, 0) + 1
        by_spec: dict[tuple, list[str]] = {}
        for v in sorted(lowered.var_specs):
            if lowered.var_class(v) != "state" or v not in mentions:
                continue
            spec = lowered.var_specs[v]
            by_spec.setdefault(
                (tuple(spec.shape), str(spec.dtype)), []
            ).append(v)

        groups: dict[str, tuple[str, ...]] = {}
        var_specs = dict(lowered.var_specs)
        for (shape, _dtype), members in sorted(by_spec.items()):
            if len(members) < self.min_group:
                continue
            members = sorted(
                members, key=lambda v: (-writes_w.get(v, 0), v)
            )
            packed = f"%pgo/pack{len(groups)}"
            spec = lowered.var_specs[members[0]]
            groups[packed] = tuple(members)
            var_specs[packed] = jax.ShapeDtypeStruct(
                (len(members),) + tuple(spec.shape), spec.dtype
            )
        if not groups:
            return lowered
        layout = ir.StateLayout(groups=groups)
        member_group = {
            m: packed for packed, ms in groups.items() for m in ms
        }

        def unpack_prim(packed: str, members: tuple[str, ...]) -> ir.LPrim:
            k = len(members)
            return ir.LPrim(
                outs=members,
                fn=lambda p, _k=k: tuple(p[i] for i in range(_k)),
                ins=(packed,),
                name="unpack",
            )

        def pack_prim(packed: str, members: tuple[str, ...]) -> ir.LPrim:
            import jax.numpy as jnp

            return ir.LPrim(
                outs=(packed,),
                fn=lambda *vals: jnp.stack(vals),
                ins=members,
                name="pack",
            )

        blocks = _copy_blocks(lowered.blocks)
        for blk in blocks:
            touched: set[str] = set()
            written: set[str] = set()
            for op in blk.ops:
                for r in ir.prim_reads(op):
                    if r in member_group:
                        touched.add(member_group[r])
                for v in ir.prim_writes(op):
                    if v in member_group:
                        touched.add(member_group[v])
                        written.add(member_group[v])
            if (
                isinstance(blk.term, ir.LBranch)
                and blk.term.var in member_group
            ):
                touched.add(member_group[blk.term.var])
            if not touched:
                continue
            pre = [unpack_prim(p, groups[p]) for p in sorted(touched)]
            post = [pack_prim(p, groups[p]) for p in sorted(written)]
            blk.ops = pre + blk.ops + post

        stack_vars, temp_vars = lowering.recompute_var_classes(
            blocks, lowered.main_params, lowered.main_outputs,
            state_layout=layout,
        )
        return ir.dataclass_replace(
            lowered,
            blocks=blocks,
            var_specs=var_specs,
            stack_vars=stack_vars,
            temp_vars=temp_vars,
            state_layout=layout,
        )


@dataclass
class BlockReordering:
    """Renumber blocks by profile dispatch frequency, hottest first.

    The ``earliest``/``lookahead`` scoring and the ``sweep`` schedule all
    iterate or argmin over block indices, so placing the hot blocks at the
    low indices makes every scheduler touch them first.  Pure renumbering:
    terminators, entries and provenance are remapped, per-lane execution
    is unchanged, and the permutation is recorded as
    ``LoweredProgram.block_order`` (``block_order[new] = old``).
    """

    name: str = "block-reordering"

    def run(self, lowered: ir.LoweredProgram) -> ir.LoweredProgram:
        weights = lowered.block_weights
        if weights is None:
            return lowered  # unprofiled: nothing to order by
        n = len(lowered.blocks)
        perm = sorted(range(n), key=lambda b: (-weights[b], b))
        if perm == list(range(n)):
            return lowered
        new_of = {old: new for new, old in enumerate(perm)}

        def remap(t: ir.LTerminator) -> ir.LTerminator:
            if isinstance(t, ir.LJump):
                return ir.LJump(new_of[t.target])
            if isinstance(t, ir.LBranch):
                return ir.LBranch(var=t.var, true=new_of[t.true],
                                  false=new_of[t.false])
            if isinstance(t, ir.LPushJump):
                return ir.LPushJump(target=new_of[t.target],
                                    ret=new_of[t.ret])
            return t

        blocks = [
            ir.LBlock(
                ops=list(lowered.blocks[old].ops),
                term=remap(lowered.blocks[old].term),
                label=lowered.blocks[old].label,
            )
            for old in perm
        ]
        fused_from = None
        if lowered.fused_from is not None:
            fused_from = {
                new: lowered.fused_from[old] for new, old in enumerate(perm)
            }
        if lowered.block_order is not None:  # compose with a prior reorder
            order = tuple(lowered.block_order[old] for old in perm)
        else:
            order = tuple(perm)
        return ir.dataclass_replace(
            lowered,
            blocks=blocks,
            entry=new_of[lowered.entry],
            func_entries={
                f: new_of[e] for f, e in lowered.func_entries.items()
            },
            fused_from=fused_from,
            block_weights=tuple(weights[old] for old in perm),
            block_order=order,
        )


def pgo_passes(
    profile, *, min_count: int = 1, max_inline_blocks: int = 8
) -> tuple[Pass, ...]:
    """The profile-guided pipeline appended after the structural passes:
    hot-path superblock formation (which re-fuses the un-pinned
    boundaries), block-local cleanups over the new superblocks,
    state-layout packing, and the final frequency renumbering."""
    return (
        ProfileGuidedFusion(
            profile, min_count=min_count,
            max_inline_blocks=max_inline_blocks,
        ),
        PopPushElimination(),
        TempDetection(),
        StateLayoutPacking(),
        BlockReordering(),
    )


def lowering_passes() -> tuple[Pass, ...]:
    """The post-emission cleanup `lowering.lower` runs: exactly the seed's
    popush-eliminate + find-temporaries sequence, as pipeline passes."""
    return (PopPushElimination(), TempDetection())


def fusion_passes() -> tuple[Pass, ...]:
    """`fusion.fuse` as a pipeline: chain fusion, then the block-local
    optimizations re-run on the merged superblocks (bit-exact with the
    monolithic PR-2 implementation)."""
    return (JumpChainFusion(), PopPushElimination(), TempDetection())


# --------------------------------------------------------------------------
# Diagnostics (fn.diagnostics() / tools/irlint.py)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Diagnostics:
    """Verifier + analysis summary of one lowered program."""

    num_blocks: int
    num_ops: int
    fused: bool
    num_source_blocks: Optional[int]  # pre-fusion block count, if fused
    num_state_vars: int  # masked top buffers the VM updates per dispatch
    num_stack_vars: int
    num_temp_vars: int
    dead_state_vars: tuple[str, ...]  # state DCE would remove
    dead_ops: int  # ops DCE would remove
    pc_depth: Optional[int]
    var_depths: dict[str, int] = field(default_factory=dict)
    required_max_depth: Optional[int] = None
    recursive_cycle: Optional[tuple[str, ...]] = None
    verified: bool = False
    verification_error: Optional[str] = None

    def pretty(self) -> str:
        lines = [
            f"blocks:        {self.num_blocks}"
            + (
                f" (fused from {self.num_source_blocks})"
                if self.fused
                else " (unfused)"
            ),
            f"ops:           {self.num_ops}",
            f"state vars:    {self.num_state_vars} "
            f"(stack: {self.num_stack_vars}, temps excluded: "
            f"{self.num_temp_vars})",
        ]
        if self.dead_ops or self.dead_state_vars:
            lines.append(
                f"dead:          {self.dead_ops} ops, "
                f"{len(self.dead_state_vars)} state vars "
                f"{sorted(self.dead_state_vars)}"
            )
        else:
            lines.append("dead:          none")
        if self.recursive_cycle is not None:
            lines.append(
                "stack bound:   unbounded (recursive cycle "
                + " -> ".join(self.recursive_cycle + self.recursive_cycle[:1])
                + ")"
            )
        else:
            lines.append(
                f"stack bound:   max_depth={self.required_max_depth} "
                f"(pc depth {self.pc_depth}, deepest variable stack "
                f"{max(self.var_depths.values(), default=0)})"
            )
        lines.append(
            "verifier:      ok"
            if self.verified
            else f"verifier:      FAILED: {self.verification_error}"
        )
        return "\n".join(lines)


def diagnose(lowered: ir.LoweredProgram) -> Diagnostics:
    """Run the verifier and every lowered-IR analysis over ``lowered``."""
    verified, err = True, None
    try:
        verifier.verify(lowered)
    except verifier.VerificationError as e:
        verified, err = False, str(e)
    if verified:
        depth = analysis.stack_depth_bound(lowered)
    else:  # analyses assume a well-formed program
        depth = analysis.StackDepthReport(None, {}, None, None)
    state_vars = [
        v for v in sorted(lowered.var_specs) if v not in lowered.temp_vars
    ]
    dead_state: tuple[str, ...] = ()
    dead_ops = 0
    if verified:
        after = DeadCodeElimination().run(lowered)
        after_state = {
            v for v in after.var_specs if v not in after.temp_vars
        }
        dead_state = tuple(sorted(set(state_vars) - after_state))
        dead_ops = sum(len(b.ops) for b in lowered.blocks) - sum(
            len(b.ops) for b in after.blocks
        )
    num_src = (
        len({s for srcs in lowered.fused_from.values() for s in srcs})
        if lowered.fused_from is not None
        else None
    )
    return Diagnostics(
        num_blocks=len(lowered.blocks),
        num_ops=sum(len(b.ops) for b in lowered.blocks),
        fused=lowered.fused_from is not None,
        num_source_blocks=num_src,
        num_state_vars=len(state_vars),
        num_stack_vars=len(lowered.stack_vars),
        num_temp_vars=len(lowered.temp_vars),
        dead_state_vars=dead_state,
        dead_ops=dead_ops,
        pc_depth=depth.pc_depth,
        var_depths=depth.var_depths,
        required_max_depth=depth.required_max_depth,
        recursive_cycle=depth.recursive_cycle,
        verified=verified,
        verification_error=err,
    )
