"""Structured frontend for building autobatchable programs.

The paper's frontend is an AutoGraph-based AST transformation of Python
source.  This repo provides two frontends that produce the same Fig-2 IR:

* :class:`FunctionBuilder` — an explicit structured builder with ``if_`` /
  ``orelse`` / ``while_`` context managers and ``call`` for (possibly
  recursive) calls.  This is the primary, fully-general frontend.
* :mod:`repro.core.ast_frontend` — a restricted-Python AST transformer in
  the paper's AutoGraph style (see that module).

Both feed the same unified namespace (:class:`repro.core.ast_frontend
.Namespace`): builder-defined and AST-defined functions can call each other
in one program, and :func:`repro.core.batching.autobatch` — the public
decorator-first API — accepts either kind.

Variables are plain strings.  ``prim`` wraps an arbitrary pure per-member
JAX function; the runtimes batch it automatically.
"""
from __future__ import annotations

import contextlib
import itertools
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import ir


def spec(shape=(), dtype=jnp.float32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


BOOL = spec((), jnp.bool_)
I32 = spec((), jnp.int32)
F32 = spec((), jnp.float32)


class FunctionBuilder:
    def __init__(
        self,
        name: str,
        params: Sequence[str],
        outputs: Sequence[str],
        param_specs: dict[str, jax.ShapeDtypeStruct],
        output_specs: dict[str, jax.ShapeDtypeStruct],
    ):
        self.func = ir.Function(
            name=name,
            params=tuple(params),
            outputs=tuple(outputs),
            blocks=[ir.Block(label=f"{name}.entry")],
            param_specs=dict(param_specs),
            output_specs=dict(output_specs),
        )
        self._cur = 0
        self._tmp = itertools.count()
        self._sealed = False
        self._last_if: Optional[dict] = None

    # ------------------------------------------------------------------
    # Low-level block management
    # ------------------------------------------------------------------

    def _new_block(self, label: str = "") -> int:
        self.func.blocks.append(ir.Block(label=f"{self.func.name}.{label}"))
        return len(self.func.blocks) - 1

    def _emit(self, op: ir.Op) -> None:
        if self._sealed:
            raise RuntimeError("cannot emit after function was finalized")
        blk = self.func.blocks[self._cur]
        if blk.term is not None:
            raise RuntimeError("emitting into a terminated block")
        blk.ops.append(op)
        self._last_if = None

    def _terminate(self, term: ir.Terminator) -> None:
        blk = self.func.blocks[self._cur]
        if blk.term is None:
            blk.term = term

    def fresh(self, hint: str = "t") -> str:
        return f"%{hint}{next(self._tmp)}"

    # ------------------------------------------------------------------
    # Operations
    # ------------------------------------------------------------------

    def prim(
        self,
        fn: Callable,
        ins: Sequence[str] = (),
        out: Optional[str] = None,
        n_out: int = 1,
        name: Optional[str] = None,
        batched: bool = False,
        tag: Optional[str] = None,
    ):
        """Emit ``out(s) = fn(*ins)``; returns the output variable name(s)."""
        if n_out == 1:
            outs = (out or self.fresh(),)
        else:
            outs = tuple(
                out[i] if out else self.fresh() for i in range(n_out)
            )
        self._emit(
            ir.Prim(
                outs=outs,
                fn=fn,
                ins=tuple(ins),
                name=name or getattr(fn, "__name__", "prim"),
                batched=batched,
                tag=tag,
            )
        )
        return outs[0] if n_out == 1 else outs

    def assign(self, out: str, fn: Callable, ins: Sequence[str] = (), **kw) -> str:
        return self.prim(fn, ins, out=out, **kw)

    def const(self, value, dtype=None, out: Optional[str] = None) -> str:
        arr = jnp.asarray(value, dtype)

        def _const():
            return arr

        return self.prim(_const, (), out=out, name=f"const[{value}]")

    def copy(self, src: str, out: Optional[str] = None) -> str:
        return self.prim(lambda x: x, (src,), out=out, name="copy")

    def call(
        self,
        callee: str,
        ins: Sequence[str],
        out: Optional[str] = None,
        n_out: int = 1,
    ):
        if n_out == 1:
            outs = (out or self.fresh("r"),)
        else:
            outs = tuple(out[i] if out else self.fresh("r") for i in range(n_out))
        self._emit(ir.Call(outs=outs, callee=callee, ins=tuple(ins)))
        return outs[0] if n_out == 1 else outs

    # ------------------------------------------------------------------
    # Structured control flow
    # ------------------------------------------------------------------

    @contextlib.contextmanager
    def if_(self, cond_var: str):
        """``with b.if_(c): ...`` — optionally followed by ``with b.orelse():``."""
        branch_block = self._cur
        then_block = self._new_block("then")
        join_block = self._new_block("join")
        self.func.blocks[branch_block].term = ir.Branch(
            var=cond_var, true=then_block, false=join_block
        )
        self._cur = then_block
        yield
        self._terminate(ir.Jump(join_block))
        self._cur = join_block
        self._last_if = {
            "branch_block": branch_block,
            "join_block": join_block,
        }

    @contextlib.contextmanager
    def orelse(self):
        if self._last_if is None:
            raise RuntimeError("orelse() must immediately follow an if_()")
        info = self._last_if
        self._last_if = None
        if self.func.blocks[info["join_block"]].ops:
            raise RuntimeError("orelse() must immediately follow an if_()")
        else_block = self._new_block("else")
        bb = self.func.blocks[info["branch_block"]]
        bb.term = ir.Branch(var=bb.term.var, true=bb.term.true, false=else_block)
        self._cur = else_block
        yield
        self._terminate(ir.Jump(info["join_block"]))
        self._cur = info["join_block"]

    @contextlib.contextmanager
    def while_(self, cond_fn: Callable, cond_ins: Sequence[str]):
        """``with b.while_(lambda i, n: i < n, ['i', 'n']): ...``

        The condition primitive re-evaluates on every iteration.
        """
        cond_block = self._new_block("loop_cond")
        self._terminate(ir.Jump(cond_block))
        self._cur = cond_block
        c = self.prim(cond_fn, cond_ins, name="loop_cond")
        body_block = self._new_block("loop_body")
        join_block = self._new_block("loop_join")
        self.func.blocks[cond_block].term = ir.Branch(
            var=c, true=body_block, false=join_block
        )
        self._cur = body_block
        yield
        self._terminate(ir.Jump(cond_block))
        self._cur = join_block

    def return_(self) -> None:
        self._terminate(ir.Return())

    def build(self) -> ir.Function:
        # Seal every un-terminated block with a Return (convenience for
        # straight-line tails).
        for blk in self.func.blocks:
            if blk.term is None:
                blk.term = ir.Return()
        self._sealed = True
        return self.func


class ProgramBuilder:
    def __init__(self, main: Optional[str] = None):
        self.functions: dict[str, ir.Function] = {}
        self.main = main

    def function(
        self,
        name: str,
        params: Sequence[str],
        outputs: Sequence[str],
        param_specs: dict,
        output_specs: dict,
    ) -> FunctionBuilder:
        fb = FunctionBuilder(name, params, outputs, param_specs, output_specs)
        return fb

    def add(self, fb: FunctionBuilder) -> None:
        func = fb.build()
        self.functions[func.name] = func
        if self.main is None:
            self.main = func.name

    def build(self) -> ir.Program:
        prog = ir.Program(functions=dict(self.functions), main=self.main)
        prog.validate()
        return prog
