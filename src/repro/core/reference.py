"""Unbatched reference interpreter — the ground-truth oracle for tests.

Executes the *source* IR one batch member at a time with plain Python
recursion and plain Python control flow.  Every batching runtime (local
static, program counter VM) must agree with this interpreter member-by-
member; the property tests in tests/ rely on that contract.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np

from . import ir


class RecursionLimit(RuntimeError):
    pass


def run_reference_single(
    program: ir.Program,
    inputs: dict[str, Any],
    max_depth: int = 10_000,
    max_steps: int = 1_000_000,
) -> dict[str, Any]:
    """Run one (unbatched) member through the program."""
    program.validate()
    steps = [0]

    def call(fname: str, args: list[Any], depth: int) -> list[Any]:
        if depth > max_depth:
            raise RecursionLimit(f"exceeded max_depth={max_depth}")
        func = program.functions[fname]
        env: dict[str, Any] = dict(zip(func.params, args))
        bi = 0
        while True:
            steps[0] += 1
            if steps[0] > max_steps:
                raise RecursionLimit(f"exceeded max_steps={max_steps}")
            blk = func.blocks[bi]
            for op in blk.ops:
                if isinstance(op, ir.Prim):
                    outs = op.fn(*[env[i] for i in op.ins])
                    if len(op.outs) == 1:
                        outs = (outs,)
                    for name, val in zip(op.outs, outs):
                        env[name] = val
                else:
                    env_outs = call(op.callee, [env[a] for a in op.ins], depth + 1)
                    for name, val in zip(op.outs, env_outs):
                        env[name] = val
            t = blk.term
            if isinstance(t, ir.Jump):
                bi = t.target
            elif isinstance(t, ir.Branch):
                bi = t.true if bool(env[t.var]) else t.false
            elif isinstance(t, ir.Return):
                return [env[o] for o in func.outputs]

    main = program.functions[program.main]
    args = [np.asarray(inputs[p], main.param_specs[p].dtype) for p in main.params]
    outs = call(program.main, args, 0)
    return dict(zip(main.outputs, outs))


def run_reference_batch(
    program: ir.Program, inputs: dict[str, Any], **kw
) -> dict[str, Any]:
    """Run every member independently; stack the results (the oracle)."""
    main = program.functions[program.main]
    z = int(np.asarray(inputs[main.params[0]]).shape[0]) if main.params else 1
    per_member = []
    for b in range(z):
        member_inputs = {p: np.asarray(inputs[p])[b] for p in main.params}
        per_member.append(run_reference_single(program, member_inputs, **kw))
    return {
        o: np.stack([m[o] for m in per_member], axis=0) for o in main.outputs
    }
