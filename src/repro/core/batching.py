"""Decorator-first, pytree-native autobatching API (the ``vmap``-like surface).

This is the public entry point of the autobatching core.  Where the legacy
``api.autobatch(program, batch_size)`` interface consumed a hand-built IR
program and a dict of qualified string names, this module exposes the paper's
"general program transformation" the way users expect to hold it: a decorator
over restricted Python (or over a :class:`~repro.core.frontend.FunctionBuilder`
program) returning a callable over **positional pytree arguments**::

    from repro.core.batching import autobatch, Batched, Shared
    from repro.core.frontend import I32

    @autobatch(in_specs=(Batched(I32),), out_spec=I32, backend="pc")
    def fib(n):
        if n < 2:
            return n
        return fib(n - 1) + fib(n - 2)

    fib(np.arange(8, dtype=np.int32))        # -> [8] int32 array

Argument model (the ``in_axes`` analog)
---------------------------------------
``Batched(spec)``  — per-member state: the call-time value carries a leading
                     batch axis on every leaf (``vmap``'s ``in_axes=0``).
``Shared(spec)``   — broadcast constants (step sizes, target parameters):
                     the call-time value has *no* batch axis and is shared by
                     every member (``vmap``'s ``in_axes=None``).

Specs are pytrees of ``jax.ShapeDtypeStruct`` (arrays and dtypes are
accepted and normalized).  A multi-leaf pytree argument binds its leaves to
consecutive IR parameters in flatten order; the binding is recorded on the
program's main :class:`ir.Function` as an :class:`ir.Interface` so the
calling convention travels with the IR.

Execution cache
---------------
Tracing (frontend -> IR) happens once per decorated function; the pc
backend's stack-explicit lowering happens once per *program*; per-batch-size
executors and per-aval compiled artifacts are memoized under a
``(backend, batch_size, schedule, fuse, verify, dce, on_fault,
detect_nonfinite, lane_step_budget, compact_every, trace, mesh,
pgo digest, input avals)`` key.  ``cache_info()`` exposes the
counters so callers (and tests) can prove that a repeat call at the same
avals performs no re-trace, no re-lower, and no re-compile, and that a call
at a *new* batch size reuses the lowering.

AOT
---
``fn.lower(*args)`` returns an :class:`AotLowered` handle with
``as_text()`` / ``compile()`` / ``cost_analysis()`` — the replacement for the
legacy ``BatchedProgram.lower_aot``.
"""
from __future__ import annotations

import functools
import inspect
import os
from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import (
    analysis,
    ast_frontend,
    frontend,
    ir,
    local_static,
    lowering,
    passes,
    pc_vm,
    reference,
)

__all__ = [
    "Batched",
    "Shared",
    "AutobatchedFunction",
    "AotLowered",
    "Stepper",
    "autobatch",
    "DEFAULT_NAMESPACE",
]

BACKENDS = ("pc", "local", "local_eager", "reference")

#: Fallback stack depth when ``max_depth=None`` and the program is
#: recursive: an input-dependent call depth has no static bound, so the
#: historical default applies (the overflow message then names the cycle).
DEFAULT_MAX_DEPTH = 32

#: The default unified frontend namespace.  ``@autobatch`` registrations land
#: here unless an explicit ``registry=`` is passed, so decorated functions in
#: one module can call decorated (or builder-registered) functions in another.
DEFAULT_NAMESPACE = ast_frontend.Namespace()


# --------------------------------------------------------------------------
# Argument annotations
# --------------------------------------------------------------------------


class Batched:
    """Per-member argument: call-time leaves carry a leading batch axis."""

    shared = False

    def __init__(self, spec: Any):
        self.spec = spec

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Batched({self.spec!r})"


class Shared:
    """Broadcast argument: one value shared by every batch member."""

    shared = True

    def __init__(self, spec: Any):
        self.spec = spec

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Shared({self.spec!r})"


def _as_profile(pgo: Any):
    """Normalize the ``pgo=`` knob: None, a ``BlockProfile``, or a path to
    a profile JSON saved by ``BlockProfile.save`` (loaded here)."""
    if pgo is None:
        return None
    if isinstance(pgo, (str, os.PathLike)):
        from repro.obs.blockprof import BlockProfile

        return BlockProfile.load(pgo)
    if hasattr(pgo, "dispatches") and hasattr(pgo, "digest"):
        return pgo
    raise TypeError(
        "pgo= expects a repro.obs.blockprof.BlockProfile (or a path to "
        f"one saved as JSON), got {type(pgo).__name__}"
    )


def _as_spec(x: Any) -> jax.ShapeDtypeStruct:
    if isinstance(x, jax.ShapeDtypeStruct):
        return x
    if hasattr(x, "shape") and hasattr(x, "dtype"):
        return jax.ShapeDtypeStruct(tuple(x.shape), jnp.dtype(x.dtype))
    return jax.ShapeDtypeStruct((), jnp.dtype(x))


def _specs_eq(a: jax.ShapeDtypeStruct, b: jax.ShapeDtypeStruct) -> bool:
    return tuple(a.shape) == tuple(b.shape) and a.dtype == b.dtype


def _flatten_spec(entry: Any) -> tuple[list[jax.ShapeDtypeStruct], Any, bool]:
    """Normalize one ``in_specs`` entry -> (leaf specs, treedef, shared)."""
    wrap = entry if isinstance(entry, (Batched, Shared)) else Batched(entry)
    leaves, treedef = jax.tree_util.tree_flatten(wrap.spec)
    if not leaves:
        raise TypeError(f"argument spec {entry!r} has no leaves")
    return [_as_spec(l) for l in leaves], treedef, wrap.shared


# --------------------------------------------------------------------------
# Backend executors (one per (backend, batch_size); own the compiled state)
# --------------------------------------------------------------------------


def _raise_if_overflowed(
    flags, batch_size: int, max_depth: int, hint: str = ""
) -> None:
    """Shared overflow gate: silently-corrupted members (dropped
    out-of-range pushes) must never escape the pytree API.

    ``hint`` carries the static stack-depth analysis' guidance (the
    inferred bound, or the recursive cycle that defeats it).  The raised
    :class:`pc_vm.StackOverflow` carries the per-lane evidence —
    ``exc.depth_exceeded`` (the ``[batch]`` bool mask) and ``exc.lanes``
    (the offending lane indices) — so callers can report *which* members
    died.
    """
    if flags.any():
        flags = np.asarray(flags)
        lanes = np.flatnonzero(flags)
        shown = ", ".join(str(i) for i in lanes[:8])
        if len(lanes) > 8:
            shown += ", ..."
        raise pc_vm.StackOverflow(
            f"pc/variable stack overflow: {len(lanes)} of "
            f"{batch_size} batch members exceeded max_depth={max_depth} "
            f"(lanes {shown}); their results would be invalid "
            "(out-of-range pushes are dropped). "
            + (hint or "Pass a larger max_depth= to autobatch()."),
            depth_exceeded=flags,
            lanes=lanes,
        )


def _raise_if_faulted(codes, batch_size: int) -> None:
    """Shared gate for NONFINITE/WATCHDOG faults under ``on_fault="raise"``:
    the batch is aborted with the per-lane evidence on the exception."""
    codes = np.asarray(codes)
    bad = codes >= pc_vm.FAULT_NONFINITE
    if bad.any():
        lanes = np.flatnonzero(bad)
        kinds = sorted({pc_vm.FAULT_NAMES[int(codes[i])] for i in lanes})
        shown = ", ".join(str(i) for i in lanes[:8])
        if len(lanes) > 8:
            shown += ", ..."
        raise pc_vm.LaneFault(
            f"lane fault ({'/'.join(kinds)}): {len(lanes)} of {batch_size} "
            f"batch members faulted (lanes {shown}); their results would "
            "be invalid. Pass on_fault='quarantine' to autobatch() to "
            "contain faults per lane instead of aborting the batch.",
            fault_codes=codes,
        )


class _PcExecutor:
    def __init__(self, lowered: ir.LoweredProgram, main: str,
                 config: pc_vm.VMConfig, overflow_hint: str = ""):
        self.main = main
        self.batch_size = config.batch_size
        self.overflow_hint = overflow_hint
        self.vm = pc_vm.ProgramCounterVM(lowered, config)
        self.last_result: Optional[pc_vm.VMResult] = None

    def _qualify(self, inputs: dict[str, Any]) -> dict[str, Any]:
        return {ir.qualify(self.main, k): v for k, v in inputs.items()}

    def run(self, inputs: dict[str, Any]) -> dict[str, Any]:
        res = self.vm.run(self._qualify(inputs))
        self.last_result = res
        if self.vm.config.on_fault == "raise":
            # Batch-fatal policy (the historical default): a deliberate
            # device sync before results escape the pytree API.  Under
            # "quarantine" nothing raises — faulted lanes are flagged in
            # last_result.fault_code and healthy lanes stay exact.
            if res.depth_exceeded is not None:
                _raise_if_overflowed(
                    jax.device_get(res.depth_exceeded),
                    self.batch_size, self.vm.config.max_depth,
                    self.overflow_hint,
                )
            cfg = self.vm.config
            if res.fault_code is not None and (
                cfg.detect_nonfinite or cfg.lane_step_budget is not None
            ):
                _raise_if_faulted(
                    jax.device_get(res.fault_code), self.batch_size
                )
        return {k.split("/", 1)[1]: v for k, v in res.outputs.items()}

    def lower(self, inputs: dict[str, Any]):
        return self.vm.lower(self._qualify(inputs))

    @property
    def tag_stats(self) -> dict[str, tuple[int, int]]:
        if self.last_result is None:
            return {}
        return dict(self.last_result.tag_stats)


class _LocalExecutor:
    def __init__(self, program: ir.Program, batch_size: int, jit_blocks: bool):
        self.batch_size = batch_size
        self.batcher = local_static.LocalStaticBatcher(
            program, batch_size, jit_blocks=jit_blocks
        )
        self._ran = False
        self.last_result = None

    def run(self, inputs: dict[str, Any]) -> dict[str, Any]:
        # Per-run counters, matching the pc executor's last_result semantics
        # (LocalStaticBatcher accumulates across runs by itself).
        self.batcher.stats = local_static.LocalStats()
        out = self.batcher.run(inputs)
        self._ran = True
        return out

    @property
    def tag_stats(self) -> dict[str, tuple[int, int]]:
        if not self._ran:
            return {}
        st = self.batcher.stats
        return {
            tag: (st.tag_execs.get(tag, 0), st.tag_active.get(tag, 0))
            for tag in st.tag_execs
        }


class _ReferenceExecutor:
    def __init__(self, program: ir.Program, batch_size: int):
        self.program = program
        self.batch_size = batch_size
        self.last_result = None

    def run(self, inputs: dict[str, Any]) -> dict[str, Any]:
        return reference.run_reference_batch(self.program, inputs)

    @property
    def tag_stats(self) -> dict[str, tuple[int, int]]:
        return {}


# --------------------------------------------------------------------------
# AOT handle
# --------------------------------------------------------------------------


class AotLowered:
    """Handle over an AOT-lowered batched computation (pc backend).

    Replaces the legacy ``BatchedProgram.lower_aot``: supports ``as_text()``
    for StableHLO inspection, ``compile()`` for ahead-of-time compilation,
    and ``cost_analysis()`` (flops/bytes estimates from the compiled
    executable when available, falling back to the lowering).
    """

    def __init__(self, lowered):
        self._lowered = lowered
        self._compiled = None

    def as_text(self) -> str:
        return self._lowered.as_text()

    def compile(self):
        if self._compiled is None:
            self._compiled = self._lowered.compile()
        return self._compiled

    def cost_analysis(self) -> dict[str, float]:
        try:
            cost = self.compile().cost_analysis()
        except Exception:
            cost = self._lowered.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        return dict(cost or {})


# --------------------------------------------------------------------------
# Segmented execution handle
# --------------------------------------------------------------------------


class Stepper:
    """Resumable, state-in/state-out execution of an autobatched function.

    Produced by :meth:`AutobatchedFunction.stepper`; pc backend only.  A
    stepper decouples *holding the VM state* from *advancing it*: the
    caller owns an opaque snapshot pytree and threads it through
    ``step()`` segments, which lets a host loop retire finished lanes and
    refill them with new work between segments (continuous batching — see
    ``repro/serve/engine.py``)::

        st = fn.stepper(*args)          # cache-keyed like fn.lower()
        state = st.init()
        while not st.done(state):
            state = st.step(state, 64)  # <= 64 VM dispatches
        out = st.result(state)          # == fn(*args), bit-exactly

    Snapshots are donatable: on accelerator backends ``step``, ``inject``
    and ``park`` donate the incoming snapshot — do not reuse a snapshot
    after passing it in.  Chaining segments of any sizes is bit-exact with
    the single-shot call for every schedule x fuse x mesh combination
    (property-tested in ``tests/test_core_property.py``).
    """

    def __init__(self, fn: "AutobatchedFunction", inputs: dict, z: int):
        self._fn = fn
        self._ex = fn._executor(z)
        self._inputs = inputs
        self.batch_size = z

    @property
    def vm(self) -> pc_vm.ProgramCounterVM:
        """The underlying VM (shared with plain calls at this batch size)."""
        return self._ex.vm

    def init(self, *args) -> dict:
        """A fresh initial snapshot.

        With no arguments, uses the values ``stepper(...)`` was created
        with; with arguments, re-binds new values (same avals).
        """
        inputs = self._inputs
        if args:
            inputs, z = self._fn._bind(args)
            if z != self.batch_size:
                raise TypeError(
                    f"stepper.init: batch size {z} != {self.batch_size}"
                )
        return self.vm.start(self._ex._qualify(inputs))

    def step(self, state: dict, num_steps: int) -> dict:
        """Advance by at most ``num_steps`` VM loop iterations."""
        return self.vm.run_segment(state, num_steps)

    def lane_done(self, state: dict) -> jax.Array:
        """``[batch]`` bool: which lanes have halted."""
        return self.vm.lane_done(state)

    def fault_code(self, state: dict) -> jax.Array:
        """``[batch]`` i32 per-lane fault codes (``pc_vm.FAULT_NAMES``)."""
        return self.vm.lane_fault(state)

    def lane_faulted(self, state: dict) -> jax.Array:
        """``[batch]`` bool: which lanes have faulted (overflow /
        non-finite write / watchdog).  Faulted lanes never advance again
        under ``on_fault="quarantine"``; ``inject`` resets them."""
        return self.vm.lane_faulted(state)

    def done(self, state: dict) -> bool:
        """True once the VM cannot advance this snapshot any further
        (device sync): every lane has halted or faulted, or the
        ``max_steps`` budget is exhausted — exactly when a single-shot
        call would return, so the ``while not st.done(state)`` drive loop
        terminates whenever ``fn(*args)`` would (check ``lane_done`` /
        ``lane_faulted`` to tell the cases apart).
        """
        terminal = jnp.logical_or(
            self.vm.lane_done(state), self.vm.lane_faulted(state)
        )
        if bool(jax.device_get(jnp.all(terminal))):
            return True
        cfg = self.vm.config
        if cfg.on_fault == "raise" and (
            cfg.detect_nonfinite or cfg.lane_step_budget is not None
        ):
            # Fail-fast policy: the VM loop halts the whole batch at the
            # first detector fault, so no lane will ever advance again —
            # the snapshot is done (result() will raise LaneFault).
            codes = jax.device_get(self.fault_code(state))
            if bool((codes >= pc_vm.FAULT_NONFINITE).any()):
                return True
        return self.steps(state) >= self.vm.config.max_steps

    def steps(self, state: dict) -> int:
        """Total VM loop iterations accumulated in this snapshot."""
        return int(jax.device_get(state["steps"]))

    def trace(self, state: dict):
        """Drain the dispatch trace from a snapshot (device sync).

        Returns a :class:`repro.obs.trace.DispatchTrace` covering every
        dispatch recorded so far (all segments — the ring is part of the
        carried snapshot), or ``None`` when the function was built
        without ``trace=``.  Non-destructive: a later drain sees the
        same events plus any new ones, on the same global step axis.
        """
        return self.vm.get_trace(state)

    def park(self, state: dict, mask) -> dict:
        """Park masked lanes at the exit block (idle until re-injected)."""
        return self.vm.park(state, mask)

    def inject(self, state: dict, mask, *args) -> dict:
        """Re-initialize masked lanes with fresh arguments.

        ``args`` follow the function's calling convention with full
        batched leading axes; only rows where ``mask`` is True are
        consumed.  In-flight (unmasked) lanes are untouched.
        """
        inputs, z = self._fn._bind(args)
        if z != self.batch_size:
            raise TypeError(
                f"stepper.inject: batch size {z} != {self.batch_size}"
            )
        return self.vm.inject(state, mask, self._ex._qualify(inputs))

    def depth_exceeded(self, state: dict) -> jax.Array:
        """``[batch]`` bool: lanes whose stacks overflowed ``max_depth``."""
        return self.vm.lane_depth_exceeded(state)

    def outputs(self, state: dict) -> Any:
        """The output pytree view of a snapshot (no overflow check).

        Rows of lanes that have halted are final; rows of in-flight lanes
        are whatever the program has written so far.  Always in the
        caller's original lane order (compaction is inverted here).
        """
        iface = self._fn._iface
        main = self._ex.main
        return jax.tree_util.tree_unflatten(
            iface.out_treedef,
            [
                # read_top is layout-transparent: an output packed into a
                # grouped array (pgo=) is sliced out of its slot here.
                self.vm.unpermute(
                    state, self.vm.read_top(state, ir.qualify(main, name))
                )
                for name in iface.out_leaves
            ],
        )

    def result(self, state: dict) -> Any:
        """Final outputs with the fault checks of a plain call.

        Under ``on_fault="raise"`` raises :class:`pc_vm.StackOverflow` if
        any lane's stacks exceeded ``max_depth``, or
        :class:`pc_vm.LaneFault` if an enabled detector (non-finite /
        watchdog) tripped — their results would be silently invalid.
        Under ``on_fault="quarantine"`` never raises: inspect
        ``fault_code(state)`` for the per-lane verdicts.
        """
        cfg = self.vm.config
        if cfg.on_fault == "raise":
            # Lane order matters: the exceptions name offending lanes.
            _raise_if_overflowed(
                jax.device_get(self.vm.lane_depth_exceeded(state)),
                self.batch_size, cfg.max_depth,
                self._ex.overflow_hint,
            )
            if cfg.detect_nonfinite or cfg.lane_step_budget is not None:
                _raise_if_faulted(
                    jax.device_get(self.vm.lane_fault(state)),
                    self.batch_size,
                )
        return self.outputs(state)


# --------------------------------------------------------------------------
# The autobatched callable
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class CacheInfo:
    hits: int
    misses: int
    entries: int
    lowerings: int
    traces: int


class AutobatchedFunction:
    """A batched callable over positional pytree arguments.

    Produced by :func:`autobatch`; do not construct directly.  Calling it
    flattens each positional argument against its declared
    ``Batched``/``Shared`` spec, broadcasts shared leaves across the batch,
    runs the backend, and unflattens the flat IR outputs into the declared
    result pytree.
    """

    def __init__(
        self,
        *,
        registry: ast_frontend.Namespace,
        main: str,
        program: Optional[ir.Program],
        iface_args: tuple[ir.ArgBinding, ...],
        arg_specs: dict[str, jax.ShapeDtypeStruct],
        out_treedef,
        out_leaves: tuple[str, ...],
        backend: str,
        batch_size: Optional[int],
        max_depth: Optional[int],
        max_steps: int,
        use_kernel: bool,
        collect_stats: bool,
        schedule: str,
        fuse: bool,
        mesh: Any = None,
        verify: bool = False,
        dce: bool = False,
        on_fault: str = "raise",
        detect_nonfinite: bool = False,
        lane_step_budget: Optional[int] = None,
        compact_every: Optional[int] = None,
        trace: Any = None,
        pgo: Any = None,
    ):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
        if schedule not in pc_vm.SCHEDULES:
            raise ValueError(
                f"schedule must be one of {pc_vm.SCHEDULES}, got {schedule!r}"
            )
        if on_fault not in pc_vm.ON_FAULT:
            raise ValueError(
                f"on_fault must be one of {pc_vm.ON_FAULT}, got {on_fault!r}"
            )
        self.registry = registry
        self.main = main
        self.backend = backend
        self.batch_size = batch_size
        self.schedule = schedule
        self.fuse = fuse
        self.mesh = mesh
        self.verify = verify
        self.dce = dce
        self.on_fault = on_fault
        self.detect_nonfinite = detect_nonfinite
        self.lane_step_budget = lane_step_budget
        self.compact_every = compact_every
        self.trace = trace
        self.pgo = _as_profile(pgo)
        self.max_depth = max_depth  # None: use the static bound (pc)
        # Resolved lazily (resolving may initialize the jax backend, which
        # a decorator at module import time must not do).
        self._mesh_key_cache: Optional[tuple] = None
        self._program = program
        self._iface = ir.Interface(
            args=iface_args, out_treedef=out_treedef, out_leaves=out_leaves
        )
        self._arg_specs = arg_specs
        self._vm_opts = dict(
            max_steps=max_steps, use_kernel=use_kernel,
            collect_block_stats=collect_stats, schedule=schedule, mesh=mesh,
            on_fault=on_fault, detect_nonfinite=detect_nonfinite,
            lane_step_budget=lane_step_budget, compact_every=compact_every,
            trace=trace,
        )
        # Constructor kwargs, for with_options() cloning.  iface pieces
        # are stored unflattened so a clone rebuilds an identical wrapper.
        self._init_kwargs = dict(
            registry=registry, main=main, program=program,
            iface_args=iface_args, arg_specs=arg_specs,
            out_treedef=out_treedef, out_leaves=out_leaves,
            backend=backend, batch_size=batch_size, max_depth=max_depth,
            max_steps=max_steps, use_kernel=use_kernel,
            collect_stats=collect_stats, schedule=schedule, fuse=fuse,
            mesh=mesh, verify=verify, dce=dce, on_fault=on_fault,
            detect_nonfinite=detect_nonfinite,
            lane_step_budget=lane_step_budget, compact_every=compact_every,
            trace=trace, pgo=self.pgo,
        )
        # Caches + instrumentation.
        self._lowered: Optional[ir.LoweredProgram] = None
        self._depth_report: Optional[analysis.StackDepthReport] = None
        self._executors: dict[int, Any] = {}
        self._aval_cache: dict[tuple, Any] = {}
        self._hits = 0
        self._misses = 0
        self._lower_count = 0
        self._trace_count = 0
        self._last_executor = None
        # Pins: what this wrapper re-asserts into the namespace before its
        # (lazy) first trace, so it always traces *its own* definition even
        # if another registration shadowed the name afterwards.  The
        # decorator path pins (fn, param_specs, output_specs); the builder
        # paths pin the ir.Function objects they registered.
        self._pinned: Optional[tuple] = None
        self._pinned_funcs: dict[str, ir.Function] = {}
        self.__name__ = main

    # ------------------------------------------------------------------
    # Program / lowering / executor caches
    # ------------------------------------------------------------------

    @property
    def program(self) -> ir.Program:
        """The traced Fig-2 IR program (traced once, then cached)."""
        if self._program is None:
            # Re-assert pinned definitions: shadowing is last-wins for
            # *name lookups*, but a wrapper always runs what it wrapped.
            if self._pinned is not None:
                fn, param_specs, output_specs = self._pinned
                if self.registry._pyfns.get(self.main) is not fn:
                    self.registry.define(param_specs, output_specs)(fn)
            for fname, func in self._pinned_funcs.items():
                if self.registry._built.get(fname) is not func:
                    self.registry.add(func)
            self._program = self.registry.trace(self.main)
            self._trace_count += 1
        main_fn = self._program.functions[self._program.main]
        if main_fn.iface is not self._iface:
            # Record *this* wrapper's calling convention on the IR without
            # mutating a Function that other wrappers (or the caller's own
            # Program object) may share.
            self._program = ir.Program(
                functions={
                    **self._program.functions,
                    self._program.main: ir.dataclass_replace(
                        main_fn, iface=self._iface
                    ),
                },
                main=self._program.main,
            )
        return self._program

    @property
    def lowered(self) -> ir.LoweredProgram:
        """The merged stack-explicit program (pc backend; lowered once).

        When ``fuse=True`` (the default) the superblock fusion passes run
        as part of this single lowering, so all batch sizes share the
        fused program; ``dce=True`` appends the dead-code-elimination
        pass, and ``verify=True`` runs the lowered-IR verifier between
        every pass of the pipeline.  With ``pgo=`` set, the profile-guided
        passes (``passes.pgo_passes``: trace-driven superblock formation,
        hot-state layout packing, block reordering) run last — the profile
        must have been collected from *this* fuse/dce configuration, since
        its per-block counts are matched against the block graph here.
        """
        if self._lowered is None:
            low = lowering.lower(self.program, verify=self.verify)
            post: list = []
            if self.fuse:
                post.extend(passes.fusion_passes())
            if self.dce:
                post.append(passes.DeadCodeElimination())
            if self.pgo is not None:
                post.extend(passes.pgo_passes(self.pgo))
            if post:
                low = passes.PassPipeline(
                    post, verify=self.verify, debug=self.verify
                ).run(low)
            self._lowered = low
            self._lower_count += 1
        return self._lowered

    @property
    def depth_report(self) -> analysis.StackDepthReport:
        """Static worst-case stack usage of the lowered program (pc)."""
        if self._depth_report is None:
            self._depth_report = analysis.stack_depth_bound(self.lowered)
        return self._depth_report

    @property
    def resolved_max_depth(self) -> int:
        """The ``max_depth`` the VM actually runs with.

        An explicit ``max_depth=`` wins.  With ``max_depth=None``, the
        statically inferred bound (``depth_report.required_max_depth``)
        applies; a recursive program has no static bound and falls back
        to :data:`DEFAULT_MAX_DEPTH`.
        """
        if self.max_depth is not None:
            return self.max_depth
        rep = self.depth_report
        if rep.required_max_depth is None:
            return DEFAULT_MAX_DEPTH
        return rep.required_max_depth

    def _overflow_hint(self) -> str:
        """Actionable guidance for StackOverflow, from the static bound."""
        rep = self.depth_report
        if rep.recursive_cycle is not None:
            cyc = " -> ".join(rep.recursive_cycle + rep.recursive_cycle[:1])
            return (
                f"The program is recursive ({cyc}), so the required depth "
                "depends on the inputs; pass a larger max_depth= to "
                "autobatch()."
            )
        return (
            "The statically inferred bound for this program is "
            f"max_depth={rep.required_max_depth}; pass max_depth= at least "
            "that (or max_depth=None to use the bound) to autobatch()."
        )

    def diagnostics(self) -> passes.Diagnostics:
        """Verifier + static-analysis report over the lowered program.

        pc backend only (the other backends never lower).  See
        :func:`repro.core.passes.diagnose`; ``tools/irlint.py`` prints the
        same report from the command line.
        """
        if self.backend != "pc":
            raise ValueError("diagnostics() requires the 'pc' backend")
        return passes.diagnose(self.lowered)

    def _executor(self, z: int):
        ex = self._executors.get(z)
        if ex is not None:
            return ex
        if self.backend == "pc":
            ex = _PcExecutor(
                self.lowered, self.program.main,
                pc_vm.VMConfig(
                    batch_size=z, max_depth=self.resolved_max_depth,
                    **self._vm_opts,
                ),
                overflow_hint=self._overflow_hint(),
            )
        elif self.backend in ("local", "local_eager"):
            ex = _LocalExecutor(
                self.program, z, jit_blocks=(self.backend == "local")
            )
        else:
            ex = _ReferenceExecutor(self.program, z)
        self._executors[z] = ex
        return ex

    def with_options(self, **overrides: Any) -> "AutobatchedFunction":
        """A clone of this wrapper with some pc knobs changed.

        ``overrides`` take the :func:`autobatch` keyword names (e.g.
        ``trace=4096``, ``schedule="lookahead"``, ``collect_stats=False``).
        The clone shares the traced IR program — and, when ``fuse``/
        ``dce``/``verify`` are unchanged, the lowering — so turning a knob
        costs at most a recompile, never a retrace.  This is how tooling
        (``tools/vmtrace.py``) turns tracing on for an existing
        ``@autobatch`` function without editing its decoration.
        """
        unknown = set(overrides) - set(self._init_kwargs)
        if unknown:
            raise TypeError(
                f"with_options: unknown option(s) {sorted(unknown)}; "
                f"valid names: {sorted(self._init_kwargs)}"
            )
        kw = dict(self._init_kwargs)
        kw.update(overrides)
        clone = AutobatchedFunction(**kw)
        clone._pinned = self._pinned
        clone._pinned_funcs = dict(self._pinned_funcs)
        clone._program = self._program
        if (
            all(
                kw[k] == self._init_kwargs[k]
                for k in ("fuse", "dce", "verify")
            )
            and clone._pgo_digest() == self._pgo_digest()
        ):
            clone._lowered = self._lowered
            clone._depth_report = self._depth_report
        return clone

    def optimize(self, profile: Any) -> "AutobatchedFunction":
        """A clone re-lowered through the profile-guided pipeline.

        ``profile`` is a :class:`repro.obs.blockprof.BlockProfile` (or a
        path to one saved as JSON) collected from a traced run of *this*
        wrapper — typically ``BlockProfile.from_trace(fn.last_trace)``
        after a call with ``trace=`` on.  Equivalent to
        ``fn.with_options(pgo=profile)``: the clone shares the traced IR,
        re-lowers once through ``passes.pgo_passes`` and compiles its own
        executors (the profile digest is part of the cache key).
        """
        return self.with_options(pgo=profile)

    def cache_info(self) -> CacheInfo:
        """Executor/compile cache counters.

        ``hits``/``misses`` count calls against the ``(backend, batch_size,
        input avals)`` key; ``lowerings`` counts stack-explicit lowerings
        (at most 1 per function regardless of how many batch sizes were
        run); ``traces`` counts frontend traces.
        """
        return CacheInfo(
            hits=self._hits,
            misses=self._misses,
            entries=len(self._aval_cache),
            lowerings=self._lower_count,
            traces=self._trace_count,
        )

    # ------------------------------------------------------------------
    # Argument binding
    # ------------------------------------------------------------------

    def _bind(self, args: tuple) -> tuple[dict[str, jax.Array], int]:
        iface = self._iface
        if len(args) != len(iface.args):
            raise TypeError(
                f"{self.main}() takes {len(iface.args)} positional "
                f"argument(s), got {len(args)}"
            )
        flat: list[tuple[ir.ArgBinding, list]] = []
        for i, (binding, arg) in enumerate(zip(iface.args, args)):
            leaves, treedef = jax.tree_util.tree_flatten(arg)
            if treedef != binding.treedef:
                raise TypeError(
                    f"{self.main}() argument {i}: pytree structure "
                    f"{treedef} does not match declared {binding.treedef}"
                )
            flat.append((binding, leaves))
        # Infer the batch size from the first batched leaf.
        z = self.batch_size
        for binding, leaves in flat:
            if binding.shared:
                continue
            for name, leaf in zip(binding.params, leaves):
                spec = self._arg_specs[name]
                shape = jnp.shape(leaf)
                if len(shape) != len(spec.shape) + 1:
                    raise TypeError(
                        f"{self.main}() batched leaf {name!r}: expected a "
                        f"leading batch axis over {tuple(spec.shape)}, got "
                        f"shape {shape}"
                    )
                if z is None:
                    z = int(shape[0])
                elif shape[0] != z:
                    raise TypeError(
                        f"{self.main}() batched leaf {name!r}: batch axis "
                        f"{shape[0]} != {z}"
                    )
        if z is None:
            raise TypeError(
                f"{self.main}() has no Batched arguments; pass "
                "batch_size= to autobatch()"
            )
        inputs: dict[str, jax.Array] = {}
        for binding, leaves in flat:
            for name, leaf in zip(binding.params, leaves):
                spec = self._arg_specs[name]
                x = jnp.asarray(leaf, spec.dtype)
                if binding.shared:
                    if tuple(x.shape) != tuple(spec.shape):
                        raise TypeError(
                            f"{self.main}() shared leaf {name!r}: expected "
                            f"shape {tuple(spec.shape)}, got {tuple(x.shape)}"
                        )
                    x = jnp.broadcast_to(x, (z,) + tuple(spec.shape))
                elif tuple(x.shape) != (z,) + tuple(spec.shape):
                    raise TypeError(
                        f"{self.main}() batched leaf {name!r}: expected "
                        f"shape {(z,) + tuple(spec.shape)}, got "
                        f"{tuple(x.shape)}"
                    )
                inputs[name] = x
        return inputs, z

    def _trace_key(self) -> Optional[int]:
        """Hashable trace identity (the resolved ring capacity)."""
        if self.backend != "pc":
            return None
        from repro.obs.trace import resolve_capacity

        return resolve_capacity(self.trace)

    def _pgo_digest(self) -> Optional[str]:
        """Hashable identity of the guiding profile (None = no PGO)."""
        return None if self.pgo is None else self.pgo.digest()

    def _mesh_key(self) -> Optional[tuple]:
        """Hashable mesh identity (resolved once, at first call time).

        Only the pc backend shards; for the others mesh is ignored
        entirely (like schedule/fuse) and never resolved against the
        device set.
        """
        if self.backend != "pc":
            return None
        if self.mesh is not None and self._mesh_key_cache is None:
            self._mesh_key_cache = pc_vm.mesh_cache_key(self.mesh)
        return self._mesh_key_cache

    def _aval_key(self, inputs: dict[str, jax.Array], z: int) -> tuple:
        # Note: _bind forces every leaf to (z,)+spec.shape / spec.dtype, so
        # today these keys collapse to the batch size; they are kept in
        # full aval form so the cache contract survives future shape- or
        # dtype-polymorphic specs.  schedule/fuse/mesh and the fault knobs
        # are fixed per wrapper but belong to the key contract: two
        # wrappers over the same program with different knobs must never
        # share a compiled executor.
        return (
            self.backend,
            z,
            self.schedule,
            self.fuse,
            self.verify,
            self.dce,
            self.on_fault,
            self.detect_nonfinite,
            self.lane_step_budget,
            self.compact_every,
            self._trace_key(),
            self._mesh_key(),
            self._pgo_digest(),
            tuple(
                (k, tuple(jnp.shape(v)), str(jnp.asarray(v).dtype))
                for k, v in sorted(inputs.items())
            ),
        )

    # ------------------------------------------------------------------
    # Execution
    # ------------------------------------------------------------------

    def __call__(self, *args):
        inputs, z = self._bind(args)
        key = self._aval_key(inputs, z)
        ex = self._aval_cache.get(key)
        if ex is None:
            self._misses += 1
            ex = self._executor(z)
            self._aval_cache[key] = ex
        else:
            self._hits += 1
        self._last_executor = ex
        out = ex.run(inputs)
        return jax.tree_util.tree_unflatten(
            self._iface.out_treedef,
            [out[name] for name in self._iface.out_leaves],
        )

    def lower(self, *args) -> AotLowered:
        """AOT-lower the full batched computation for these avals (pc only)."""
        if self.backend != "pc":
            raise ValueError("AOT lowering requires the 'pc' backend")
        inputs, z = self._bind(args)
        return AotLowered(self._executor(z).lower(inputs))

    def stepper(self, *args) -> Stepper:
        """A :class:`Stepper` for segmented (resumable) execution (pc only).

        Cache-keyed like :meth:`lower`: the stepper shares the per-batch-
        size executor (and its compiled VM) with plain calls, so creating
        one after calling the function costs no extra trace/lower/compile.
        """
        if self.backend != "pc":
            raise ValueError("stepper requires the 'pc' backend")
        inputs, z = self._bind(args)
        return Stepper(self, inputs, z)

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------

    @property
    def last_result(self) -> Optional[pc_vm.VMResult]:
        """The :class:`pc_vm.VMResult` of the most recent pc-backend call."""
        return self._last_executor.last_result if self._last_executor else None

    @property
    def last_trace(self):
        """The :class:`repro.obs.trace.DispatchTrace` of the most recent
        pc-backend call, or ``None`` (no call yet, or ``trace=`` unset)."""
        res = self.last_result
        return res.trace if res is not None else None

    @property
    def scheduler_stats(self) -> Optional[pc_vm.SchedulerStats]:
        """Scheduling summary of the most recent pc-backend call: schedule
        name, fused-or-not, block count, VM steps, mean dispatch occupancy,
        and the fused-block provenance map.  ``None`` before any pc run."""
        res = self.last_result
        return res.sched if res is not None else None

    @property
    def tag_stats(self) -> dict[str, tuple[int, int]]:
        """tag -> (primitive executions, active member-executions).

        Unified across backends: counters cover the *most recent call only*
        on every backend; ``{}`` before any call has run (and always for
        the ``reference`` backend, which keeps no counters).
        """
        return self._last_executor.tag_stats if self._last_executor else {}

    @property
    def utilization(self) -> dict[str, float]:
        """Per-tag batch utilization of the last run (paper Fig. 6).

        ``utilization[tag] = active / (executions * batch_size)``.  Returns
        ``{}`` before any call has run on every backend; tags that executed
        with no active members report ``0.0``.
        """
        ex = self._last_executor
        if ex is None:
            return {}
        z = ex.batch_size
        return {
            tag: (act / (execs * z) if execs else 0.0)
            for tag, (execs, act) in ex.tag_stats.items()
        }


# --------------------------------------------------------------------------
# Interface construction
# --------------------------------------------------------------------------


# The decorator path's out_leaves must match the output names the AST
# transform generates — share the single definition.
_ret_names = ast_frontend._ret_names


def _bind_in_specs(
    name: str,
    params: tuple[str, ...],
    in_specs: Sequence,
    declared: Optional[dict[str, jax.ShapeDtypeStruct]] = None,
) -> tuple[tuple[ir.ArgBinding, ...], dict[str, jax.ShapeDtypeStruct]]:
    """Map ``in_specs`` entries onto IR parameters in flatten order."""
    bindings: list[ir.ArgBinding] = []
    arg_specs: dict[str, jax.ShapeDtypeStruct] = {}
    idx = 0
    for entry in in_specs:
        leaf_specs, treedef, shared = _flatten_spec(entry)
        names = params[idx : idx + len(leaf_specs)]
        if len(names) != len(leaf_specs):
            raise TypeError(
                f"{name}: in_specs bind {idx + len(leaf_specs)} leaves but "
                f"the function has only {len(params)} parameters"
            )
        for p, spec in zip(names, leaf_specs):
            if declared is not None and not _specs_eq(spec, declared[p]):
                raise TypeError(
                    f"{name}: in_specs leaf for parameter {p!r} is {spec} "
                    f"but the program declares {declared[p]}"
                )
            arg_specs[p] = spec
        bindings.append(ir.ArgBinding(tuple(names), treedef, shared))
        idx += len(leaf_specs)
    if idx != len(params):
        raise TypeError(
            f"{name}: in_specs cover {idx} of {len(params)} parameters "
            f"({params[idx:]} unbound)"
        )
    return tuple(bindings), arg_specs


def _contains_dict(tree: Any) -> bool:
    if isinstance(tree, dict):
        return True
    if isinstance(tree, (list, tuple)):
        return any(_contains_dict(x) for x in tree)
    return False


def _bind_out_spec(
    name: str,
    outputs: tuple[str, ...],
    out_spec: Any,
    declared: Optional[dict[str, jax.ShapeDtypeStruct]] = None,
):
    """Resolve the output pytree -> (treedef, IR output names per leaf)."""
    if out_spec is None:
        # Default: a dict keyed by the IR output names.
        tree = {o: o for o in outputs}
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        return treedef, tuple(leaves)
    leaves, treedef = jax.tree_util.tree_flatten(out_spec)
    if all(isinstance(l, str) for l in leaves):
        # Name-based restructuring: leaves name IR outputs.
        for l in leaves:
            if l not in outputs:
                raise TypeError(
                    f"{name}: out_spec names unknown output {l!r} "
                    f"(have {outputs})"
                )
        return treedef, tuple(leaves)
    # Spec leaves: positional against the declared outputs in flatten order.
    # Unordered containers would bind in sorted-key order, silently
    # permuting equal-spec outputs — require name-based string leaves there.
    if _contains_dict(out_spec):
        raise TypeError(
            f"{name}: out_spec dicts with spec leaves are ambiguous "
            "(dict flatten order is sorted-key, not declaration order); "
            "use output-name strings as leaves, e.g. "
            "out_spec={'mean': 'sum_theta'}"
        )
    if len(leaves) != len(outputs):
        raise TypeError(
            f"{name}: out_spec has {len(leaves)} leaves for "
            f"{len(outputs)} outputs"
        )
    if declared is not None:
        for o, l in zip(outputs, leaves):
            spec = _as_spec(l)
            if not _specs_eq(spec, declared[o]):
                raise TypeError(
                    f"{name}: out_spec leaf for output {o!r} is {spec} "
                    f"but the program declares {declared[o]}"
                )
    return treedef, tuple(outputs)


# --------------------------------------------------------------------------
# The decorator / entry point
# --------------------------------------------------------------------------


def autobatch(
    target: Any = None,
    *,
    in_specs: Optional[Sequence] = None,
    out_spec: Any = None,
    backend: str = "pc",
    batch_size: Optional[int] = None,
    max_depth: Optional[int] = None,
    max_steps: int = 1_000_000,
    use_kernel: bool = False,
    collect_stats: bool = True,
    schedule: str = "earliest",
    fuse: bool = True,
    mesh: Any = None,
    verify: bool = False,
    dce: bool = True,
    on_fault: str = "raise",
    detect_nonfinite: bool = False,
    lane_step_budget: Optional[int] = None,
    compact_every: Optional[int] = None,
    trace: Any = None,
    pgo: Any = None,
    registry: Optional[ast_frontend.Namespace] = None,
):
    """Autobatch a restricted-Python function or an IR program.

    Usable three ways:

    1. As a decorator over restricted Python (``in_specs``/``out_spec``
       required; each parameter must be a single-leaf spec)::

           @autobatch(in_specs=(Batched(I32),), out_spec=I32)
           def fib(n): ...

    2. Over a :class:`frontend.ProgramBuilder`, a single
       :class:`frontend.FunctionBuilder` / :class:`ir.Function`, or a
       pre-built :class:`ir.Program`.  ``in_specs`` defaults to
       ``Batched(<declared spec>)`` per parameter; ``out_spec`` defaults to
       a dict keyed by the IR output names (pass a pytree of output-name
       strings to restructure, or of specs bound positionally).

    3. Partially applied (``autobatch(backend=..., ...)``) to get a
       decorator with fixed options.

    ``batch_size=None`` (the default) infers the batch size from the leading
    axis of the first ``Batched`` leaf on every call; executors are cached
    per batch size, and the pc backend's lowering is shared across all of
    them.  All functions registered in the same ``registry`` may call each
    other, whichever frontend defined them.  Decorated Python functions
    default to a process-wide namespace; builder programs default to a
    private one (pass ``registry=`` to share deliberately).

    pc-backend performance knobs (ignored by the other backends; all are
    part of the executor cache key, and all are bit-exact):

    * ``fuse=True`` runs the superblock fusion pass (fusion.py) over the
      stack-explicit lowering, collapsing straight-line jump chains into
      single VM dispatch steps;
    * ``schedule`` picks the VM's next-block policy: ``"earliest"`` (paper
      Algorithm 2), ``"popular"`` (occupancy argmax), ``"sweep"`` (every
      resident block once per loop iteration, no ``lax.switch``) or
      ``"lookahead"`` (occupancy argmax over each block plus its CFG
      successors — re-converges divergent lanes faster than plain
      ``"popular"``);
    * ``compact_every=k`` permutes the lane axis every ``k`` VM dispatches
      so lanes at the same program point occupy contiguous SIMD tiles
      (occupancy-aware lane compaction).  Lane identity is tracked and
      inverted on every output/Stepper/fault surface, so results are
      bit-exact with ``compact_every=None`` (the default: no compaction);
    * ``use_kernel=True`` routes stack pushes/peeks through the Pallas
      ``stack_ops`` kernels (interpret mode off-TPU).  Composes with
      ``mesh``: each device runs the kernel over its own lane slice;
    * ``mesh`` shards the batch-lane axis of every VM state array across
      devices (``None`` = single device, an int device count, or a 1-D
      ``jax.sharding.Mesh``), compiling the whole program as one SPMD
      ``lax.while_loop``; the batch size must divide across the mesh;
    * ``dce=True`` runs the dead-code-elimination pass over the lowered
      program, dropping primitives whose outputs are never observed and
      shrinking the VM state the masked updates touch every dispatch;
    * ``verify=True`` runs the lowered-IR verifier (verifier.py) between
      every pass of the lowering/fusion pipeline;
    * ``max_depth=None`` (the default) sizes the pc/variable stacks from
      the static interprocedural bound (``fn.depth_report``); recursive
      programs have no static bound and fall back to
      ``DEFAULT_MAX_DEPTH=32`` — pass an explicit ``max_depth=`` there
      (a stack overflow names the recursive cycle);
    * ``trace=`` records a per-dispatch trace into a fixed-capacity
      on-device ring buffer (``True`` = the default capacity, an int =
      that many events).  Purely observational — outputs, step counts
      and the dispatch sequence are bit-exact with ``trace=None``.  Read
      it via ``fn.last_trace`` / ``Stepper.trace(state)`` as a
      :class:`repro.obs.trace.DispatchTrace`; render timelines with
      ``repro.obs.timeline`` (see ``docs/observability.md``);
    * ``pgo=`` re-lowers through the profile-guided pipeline
      (``passes.pgo_passes``): a :class:`repro.obs.blockprof.BlockProfile`
      (or a path to one saved as JSON) drives trace-driven superblock
      formation (hot call frames merged or tail-duplicated inline),
      hot-state layout packing (same-dtype state variables grouped into
      one packed array, cutting masked updates per dispatch) and block
      reordering by dispatch frequency.  Outputs stay bit-exact; the
      profile digest is part of the executor cache key.  Collect a
      profile from a traced run and apply it with ``fn.optimize(prof)``
      (``== fn.with_options(pgo=prof)``), or use ``tools/pgo.py``.

    Fault containment knobs (pc backend; also part of the cache key):

    * ``on_fault="raise"`` (the default) keeps faults batch-fatal: the
      executor raises :class:`pc_vm.StackOverflow` (with the per-lane mask
      and lane indices as attributes) or :class:`pc_vm.LaneFault` after
      the run.  ``on_fault="quarantine"`` contains faults per lane: a
      faulted lane is parked out of the liveness mask, the batch never
      aborts, healthy lanes stay bit-exact with a fault-free run, and the
      per-lane verdicts are exposed via ``fn.last_result.fault_code`` /
      ``Stepper.fault_code`` (codes index ``pc_vm.FAULT_NAMES``);
    * ``detect_nonfinite=True`` checks every masked state write of inexact
      dtype for NaN/Inf and faults the writing lane (``NONFINITE``);
    * ``lane_step_budget=N`` arms a per-lane watchdog: a lane active for
      more than ``N`` block dispatches without halting faults
      (``WATCHDOG``) — the guard against data-dependent livelock.
    """
    if target is None:
        return functools.partial(
            autobatch,
            in_specs=in_specs,
            out_spec=out_spec,
            backend=backend,
            batch_size=batch_size,
            max_depth=max_depth,
            max_steps=max_steps,
            use_kernel=use_kernel,
            collect_stats=collect_stats,
            schedule=schedule,
            fuse=fuse,
            mesh=mesh,
            verify=verify,
            dce=dce,
            on_fault=on_fault,
            detect_nonfinite=detect_nonfinite,
            lane_step_budget=lane_step_budget,
            compact_every=compact_every,
            trace=trace,
            pgo=pgo,
            registry=registry,
        )
    if registry is not None:
        ns = registry
    elif isinstance(
        target, (frontend.ProgramBuilder, frontend.FunctionBuilder,
                 ir.Function)
    ):
        # Builder programs default to a private namespace: registering
        # their function names into the process-wide one could silently
        # shadow the callees of not-yet-traced decorated functions.  Pass
        # registry= to share a namespace deliberately (e.g. for AST <->
        # builder cross-calls).
        ns = ast_frontend.Namespace()
    else:
        ns = DEFAULT_NAMESPACE
    opts = dict(
        backend=backend, batch_size=batch_size, max_depth=max_depth,
        max_steps=max_steps, use_kernel=use_kernel, collect_stats=collect_stats,
        schedule=schedule, fuse=fuse, mesh=mesh, verify=verify, dce=dce,
        on_fault=on_fault, detect_nonfinite=detect_nonfinite,
        lane_step_budget=lane_step_budget, compact_every=compact_every,
        trace=trace, pgo=pgo,
    )

    program: Optional[ir.Program] = None
    pinned_funcs: dict[str, ir.Function] = {}
    if isinstance(target, frontend.ProgramBuilder):
        # Feed the builder's functions through the unified namespace so they
        # can call (and be called by) AST-defined functions.
        for func in target.functions.values():
            pinned_funcs[func.name] = ns.add(func)
        main_fn = ns._built[target.main]
        main = target.main
    elif isinstance(target, (frontend.FunctionBuilder, ir.Function)):
        main_fn = ns.add(target)
        main = main_fn.name
        pinned_funcs[main] = main_fn
    elif isinstance(target, ir.Program):
        program = target
        main = target.main
        main_fn = target.functions[main]
    elif callable(target):
        return _autobatch_python(target, ns, in_specs, out_spec, opts)
    else:
        raise TypeError(f"cannot autobatch {target!r}")

    params, outputs = main_fn.params, main_fn.outputs
    if in_specs is None:
        in_specs = tuple(Batched(main_fn.param_specs[p]) for p in params)
    iface_args, arg_specs = _bind_in_specs(
        main, params, in_specs, declared=main_fn.param_specs
    )
    out_treedef, out_leaves = _bind_out_spec(
        main, outputs, out_spec, declared=main_fn.output_specs
    )
    wrapped = AutobatchedFunction(
        registry=ns, main=main, program=program,
        iface_args=iface_args, arg_specs=arg_specs,
        out_treedef=out_treedef, out_leaves=out_leaves, **opts,
    )
    wrapped._pinned_funcs = pinned_funcs
    return wrapped


def _autobatch_python(fn, ns, in_specs, out_spec, opts) -> AutobatchedFunction:
    name = fn.__name__
    params = tuple(inspect.signature(fn).parameters)
    if in_specs is None or out_spec is None:
        raise TypeError(
            f"@autobatch over Python function {name!r} requires in_specs= "
            "and out_spec= (output types of recursive functions cannot be "
            "inferred)"
        )
    iface_args, arg_specs = _bind_in_specs(name, params, in_specs)
    for binding in iface_args:
        if len(binding.params) != 1:
            raise TypeError(
                f"{name}: restricted-Python parameters must be single-leaf "
                f"specs (argument binding {binding.params} has "
                f"{len(binding.params)} leaves); use a FunctionBuilder "
                "program for multi-leaf pytree arguments"
            )
    if _contains_dict(out_spec):
        raise TypeError(
            f"{name}: out_spec dicts with spec leaves are ambiguous "
            "(dict flatten order is sorted-key, not declaration order, so "
            "returned values would bind to sorted keys); use a tuple "
            "out_spec and restructure at the call site"
        )
    out_leaf_specs = [
        _as_spec(l) for l in jax.tree_util.tree_flatten(out_spec)[0]
    ]
    outputs = _ret_names(len(out_leaf_specs))
    out_treedef = jax.tree_util.tree_flatten(out_spec)[1]
    param_specs = {p: arg_specs[p] for p in params}
    ns.define(param_specs=param_specs, output_specs=out_leaf_specs)(fn)
    wrapped = AutobatchedFunction(
        registry=ns, main=name, program=None,
        iface_args=iface_args, arg_specs=arg_specs,
        out_treedef=out_treedef, out_leaves=outputs, **opts,
    )
    wrapped._pinned = (fn, param_specs, out_leaf_specs)
    functools.update_wrapper(wrapped, fn, updated=())
    return wrapped
