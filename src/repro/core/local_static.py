"""Local static autobatching (paper Algorithm 1 / Section 2).

A non-standard interpreter of the *source* IR: data storage and an active-set
mask live on device, control flow and recursion live in host Python (each
``Call`` recurses through the Python stack, exactly as in the paper's
Figure 1).  Within one function invocation the interpreter repeatedly runs
the earliest basic block any locally-active member waits on, masking updates.

Two execution modes, mirroring the paper's experiment arms:

* ``jit_blocks=True``  — the "hybrid" arm: host Python drives control, but
  each basic-block segment is compiled (fused) with XLA.
* ``jit_blocks=False`` — the "eager" arm: every primitive dispatches
  individually (op-by-op), paying per-op overhead.

The limitation the paper highlights is structural here: because recursion is
carried by the *host* stack, members at different recursion depths can never
batch together — each ``Call`` spawns a fresh interpreter invocation for its
locally-active subset only.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import analysis, ir

Array = jax.Array
_I32 = jnp.int32


def _bcast(mask: Array, val: Array) -> Array:
    return mask.reshape(mask.shape + (1,) * (val.ndim - 1))


def _masked(mask: Array, new: Array, old: Array) -> Array:
    return jnp.where(_bcast(mask, new), new, old)


@dataclass
class LocalStats:
    block_execs: int = 0
    primitive_execs: int = 0
    tag_execs: dict[str, int] = None
    tag_active: dict[str, int] = None

    def __post_init__(self):
        self.tag_execs = self.tag_execs or {}
        self.tag_active = self.tag_active or {}


class _Segment:
    """A maximal run of primitives (+ optional terminator) within a block."""

    def __init__(self, ops: list[ir.Prim], term: ir.Terminator | None):
        self.ops = ops
        self.term = term
        self._jitted: Callable | None = None

    def build(self, jit: bool) -> Callable:
        def run(env: dict[str, Array], pc: Array, mask: Array):
            env = dict(env)
            z = mask.shape[0]
            for op in self.ops:
                if not op.ins and not op.batched:
                    outs = op.fn()
                    outs = outs if isinstance(outs, tuple) else (outs,)
                    outs = tuple(
                        jnp.broadcast_to(
                            jnp.asarray(o), (z,) + jnp.shape(jnp.asarray(o))
                        )
                        for o in outs
                    )
                else:
                    fn = op.fn if op.batched else jax.vmap(op.fn)
                    outs = fn(*[env[i] for i in op.ins])
                    if len(op.outs) == 1:
                        outs = (outs,)
                for name, val in zip(op.outs, outs):
                    if name in env:
                        env[name] = _masked(mask, val.astype(env[name].dtype), env[name])
                    else:
                        env[name] = val  # first definition; junk rows masked later
            if self.term is not None:
                pc = _apply_term(self.term, env, pc, mask)
            return env, pc

        if jit:
            return jax.jit(run)
        return run


def _apply_term(term: ir.Terminator, env, pc: Array, mask: Array) -> Array:
    if isinstance(term, ir.Jump):
        return jnp.where(mask, term.target, pc)
    if isinstance(term, ir.Branch):
        cond = env[term.var]
        return jnp.where(mask, jnp.where(cond, term.true, term.false), pc)
    if isinstance(term, ir.Return):
        return jnp.where(mask, np.iinfo(np.int32).max, pc)
    raise AssertionError(term)


class LocalStaticBatcher:
    """Batched executor for a source :class:`ir.Program` (Algorithm 1)."""

    def __init__(self, program: ir.Program, batch_size: int, jit_blocks=True):
        program.validate()
        analysis.infer_types(program)
        self.program = program
        self.batch_size = batch_size
        self.jit_blocks = jit_blocks
        # (fname, block_idx) -> list of ('seg', fn) | ('call', Call)
        self._plans: dict[tuple[str, int], list[tuple[str, Any]]] = {}
        for fname, func in program.functions.items():
            for bi, blk in enumerate(func.blocks):
                self._plans[(fname, bi)] = self._plan_block(blk)
        self.stats = LocalStats()

    def _plan_block(self, blk: ir.Block):
        plan: list[tuple[str, Any]] = []
        run: list[ir.Prim] = []
        for op in blk.ops:
            if isinstance(op, ir.Prim):
                run.append(op)
            else:
                if run:
                    seg = _Segment(run, None)
                    plan.append(("seg", seg.build(self.jit_blocks), run))
                    run = []
                plan.append(("call", op, None))
        seg = _Segment(run, blk.term)
        plan.append(("seg", seg.build(self.jit_blocks), run))
        return plan

    # ------------------------------------------------------------------

    def run(self, inputs: dict[str, Array]) -> dict[str, Array]:
        main = self.program.functions[self.program.main]
        z = self.batch_size
        args = []
        for p in main.params:
            x = jnp.asarray(inputs[p])
            expect = (z,) + tuple(main.param_specs[p].shape)
            if x.shape != expect:
                raise ValueError(f"input {p!r}: expected {expect}, got {x.shape}")
            args.append(x.astype(main.param_specs[p].dtype))
        active = jnp.ones((z,), bool)
        outs = self._run_function(main, args, active)
        return dict(zip(main.outputs, outs))

    def _run_function(
        self, func: ir.Function, args: list[Array], active: Array
    ) -> list[Array]:
        z = self.batch_size
        done_pc = np.iinfo(np.int32).max
        env: dict[str, Array] = {}
        for v, spec in func.var_specs.items():
            env[v] = jnp.zeros((z,) + tuple(spec.shape), spec.dtype)
        for p, a in zip(func.params, args):
            env[p] = a
        pc = jnp.where(active, 0, done_pc)

        while True:
            pc_np = np.asarray(jax.device_get(pc))
            act_np = np.asarray(jax.device_get(active))
            live = act_np & (pc_np != done_pc)
            if not live.any():
                break
            i = int(pc_np[live].min())
            mask = active & (pc == i)
            self.stats.block_execs += 1
            for item in self._plans[(func.name, i)]:
                if item[0] == "seg":
                    _, fn, ops = item
                    env, pc = fn(env, pc, mask)
                    self.stats.primitive_execs += len(ops)
                    n_active = int(np.asarray(jax.device_get(mask)).sum())
                    for op in ops:
                        if op.tag:
                            self.stats.tag_execs[op.tag] = (
                                self.stats.tag_execs.get(op.tag, 0) + 1
                            )
                            self.stats.tag_active[op.tag] = (
                                self.stats.tag_active.get(op.tag, 0) + n_active
                            )
                else:
                    _, op, _ = item
                    callee = self.program.functions[op.callee]
                    call_args = [env[a] for a in op.ins]
                    # Host-language recursion (the paper's Figure 1): the
                    # callee runs to completion for the locally-active subset.
                    outs = self._run_function(callee, call_args, mask)
                    for name, val in zip(op.outs, outs):
                        env[name] = _masked(mask, val.astype(env[name].dtype), env[name])
        return [env[o] for o in func.outputs]
