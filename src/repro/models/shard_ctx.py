"""Activation-sharding context: lets leaf modules (MoE dispatch, SSM
cores) place ``with_sharding_constraint``s without threading the launcher
configuration through every call signature.

The launcher-facing entry is ``Model.axis_rules``; ``Model.forward`` /
``decode_step`` install it here for the duration of the trace.  Rules:

    {"batch": ("pod","data") | ("data",),
     "tp": "model", "ep": "model",
     "sizes": {axis: size}}

``constrain(x, ("batch", None, "tp"))`` maps logical names to mesh axes,
drops entries whose dimension is not divisible, and no-ops when no rules
are installed (unit tests, single-device runs).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax

_RULES: contextvars.ContextVar = contextvars.ContextVar(
    "repro_axis_rules", default=None
)


@contextlib.contextmanager
def use_rules(rules: Optional[dict]):
    token = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(token)


def current_rules() -> Optional[dict]:
    return _RULES.get()


def constrain_strict(x: jax.Array, logical: tuple) -> jax.Array:
    """All-or-nothing constraint: apply only if EVERY named axis divides
    its dimension; otherwise leave the array entirely unconstrained (a
    partial constraint would pin the remaining dims to *replicated*,
    which can be far worse than whatever SPMD picks)."""
    rules = _RULES.get()
    if rules is None:
        return x
    sizes = rules["sizes"]
    for dim, name in enumerate(logical):
        if name is None:
            continue
        axes = rules.get(name)
        if axes is None:
            return x
        if isinstance(axes, str):
            axes = (axes,)
        total = 1
        for a in axes:
            total *= sizes[a]
        if x.shape[dim] % total != 0 or x.shape[dim] < total:
            return x
    return constrain(x, logical)


def constrain(x: jax.Array, logical: tuple) -> jax.Array:
    rules = _RULES.get()
    if rules is None:
        return x
    sizes = rules["sizes"]
    spec = []
    for dim, name in enumerate(logical):
        if name is None:
            spec.append(None)
            continue
        axes = rules.get(name)
        if axes is None:
            spec.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        total = 1
        for a in axes:
            total *= sizes[a]
        if x.shape[dim] % total == 0 and x.shape[dim] >= total:
            spec.append(axes if len(axes) > 1 else axes[0])
        else:
            spec.append(None)
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(x, P(*spec))
