"""Mixture-of-experts FFN: shared + routed top-k experts (fine-grained).

Dispatch is sort-based with a static per-expert capacity — the TPU-native
scheme (MaxText-style): tokens are argsorted by expert id, positioned with a
segment cumsum, scattered into a ``[E, C, d]`` buffer, pushed through a
batched expert GEMM, and gathered back with combine weights.  All shapes are
static (XLA requirement); overflow beyond capacity is dropped (standard) and
reported in aux metrics.

Under expert parallelism the ``[E, ...]`` axis is sharded over the ``model``
mesh axis; the scatter/gather lower to all-to-alls, visible in the dry-run
collective schedule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

from . import shard_ctx
from .layers import Params, init_swiglu, pdtype, swiglu


def init_moe(key: jax.Array, cfg: ArchConfig) -> Params:
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    kr, ke, ks = jax.random.split(key, 3)
    dt = pdtype(cfg)
    keg, keu, ked = jax.random.split(ke, 3)
    p: Params = {
        "router": jax.random.normal(kr, (d, e), dt) / np.sqrt(d),
        "wg": jax.random.normal(keg, (e, d, ff), dt) / np.sqrt(d),
        "wu": jax.random.normal(keu, (e, d, ff), dt) / np.sqrt(d),
        "wd": jax.random.normal(ked, (e, ff, d), dt) / np.sqrt(ff),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_swiglu(
            ks, cfg, d, cfg.moe_d_ff * cfg.num_shared_experts
        )
    return p


def router_probs(params: Params, x: jax.Array, cfg: ArchConfig):
    """x: [T, d] -> (weights [T, k], expert ids [T, k], aux metrics)."""
    logits = (x.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)  # [T, k]
    if cfg.moe_renorm_topk:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # Switch-style load-balance loss: E * sum_e f_e * p_e.
    e = cfg.num_experts
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    assign = jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32)
    fe = jnp.mean(assign, axis=0)  # fraction of tokens (top-1) per expert
    aux_loss = e * jnp.sum(me * fe)
    return top_p, top_e, {"moe_aux_loss": aux_loss}


def moe_ffn(params: Params, x: jax.Array, cfg: ArchConfig
            ) -> tuple[jax.Array, dict]:
    """x: [B, S, d] -> (y, aux).

    Two lowering strategies:

    * **EP shard_map path** (under a mesh with a ``tp``/``ep`` axis and
      ``E % shards == 0``): each model-shard selects, sorts and computes
      ONLY its local experts' tokens from its (model-replicated,
      batch-sharded) activations — dispatch is entirely local — and the
      partial outputs combine with ONE ``psum`` over the model axis.
      This is the correct distributed algorithm; letting XLA's SPMD
      partitioner handle the scatter instead was measured to emit ~4 GB
      all-reduces per layer (§Perf, refuted-hypothesis log).
    * **single-device path**: global sort-and-scatter dispatch (tests,
      CPU runs, meshless traces).
    """
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    e = cfg.num_experts
    xf = x.reshape(t, d)
    top_p, top_e, aux = router_probs(params, xf, cfg)

    rules = shard_ctx.current_rules()
    n_shards = 0
    if rules is not None and rules.get("mesh") is not None:
        tp_axis = rules.get("ep") or rules.get("tp")
        if tp_axis:
            n_shards = rules["sizes"].get(tp_axis, 0)
    if n_shards > 1 and e % n_shards == 0:
        with jax.named_scope("moe_dispatch"):
            y = _moe_ep_shardmap(
                params, x, top_p.reshape(b, s, k), top_e.reshape(b, s, k),
                cfg, rules, tp_axis,
            )
        aux = dict(aux, moe_dropped_frac=-1.0)  # not tracked on this path
        if cfg.num_shared_experts:
            y = y + swiglu(params["shared"], x)
        return y, aux

    capacity = int(np.ceil(t * k * cfg.capacity_factor / e))
    capacity = max(capacity, 4)

    with jax.named_scope("moe_dispatch"):
        return _dispatch_compute_combine(params, x, xf, top_p, top_e, aux,
                                         capacity, cfg)


def _moe_ep_shardmap(params, x, top_p, top_e, cfg, rules, tp_axis):
    """Expert-parallel MoE via shard_map: local dispatch, psum combine."""
    from jax.sharding import PartitionSpec as P

    mesh = rules["mesh"]
    sizes = rules["sizes"]
    daxes = tuple(a for a in rules.get("batch", ()) if a in sizes)
    dp = 1
    for a in daxes:
        dp *= sizes[a]
    n_shards = sizes[tp_axis]
    e = cfg.num_experts
    e_loc = e // n_shards
    k = cfg.top_k
    b, s, d = x.shape
    t_loc = max(1, b // dp) * s
    capacity = max(4, int(np.ceil(t_loc * k * cfg.capacity_factor / e)))
    bspec = P(daxes if len(daxes) > 1 else (daxes[0] if daxes else None))

    def per_shard(wg, wu, wd, x_loc, p_loc, e_idx_loc):
        bl, sl, _ = x_loc.shape
        tl = bl * sl
        xt = x_loc.reshape(tl, d)
        pp = p_loc.reshape(tl * k)
        ee = e_idx_loc.reshape(tl * k)
        my_first = jax.lax.axis_index(tp_axis) * e_loc
        local_e = ee - my_first
        mine = jnp.logical_and(local_e >= 0, local_e < e_loc)
        bucket = jnp.where(mine, local_e, e_loc)  # e_loc = drop bucket
        order = jnp.argsort(bucket)
        sorted_b = bucket[order]
        counts = jnp.bincount(bucket, length=e_loc + 1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(tl * k) - starts[sorted_b]
        keep = jnp.logical_and(sorted_b < e_loc, pos < capacity)
        dst_e = jnp.where(keep, sorted_b, e_loc)
        dst_c = jnp.where(keep, pos, 0)
        src_tok = (jnp.arange(tl * k) // k)[order]
        buf = jnp.zeros((e_loc, capacity, d), x_loc.dtype)
        buf = buf.at[dst_e, dst_c].set(xt[src_tok], mode="drop")
        ct = x_loc.dtype
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(ct)))
        u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(ct))
        ob = jnp.einsum("ecf,efd->ecd", g * u, wd.astype(ct))
        ya = ob[dst_e.clip(0, e_loc - 1), dst_c]
        ya = jnp.where(keep[:, None], ya, 0.0)
        ya = ya * pp[order][:, None].astype(ct)
        y = jnp.zeros((tl, d), ct).at[src_tok].add(ya)
        y = jax.lax.psum(y, tp_axis)  # combine partial expert outputs
        return y.reshape(bl, sl, d)

    fn = jax.shard_map(
        per_shard,
        mesh=mesh,
        in_specs=(
            P(tp_axis), P(tp_axis), P(tp_axis),  # expert weights
            bspec, bspec, bspec,  # activations / routing (batch-sharded)
        ),
        out_specs=bspec,
        check_vma=False,
    )
    return fn(params["wg"], params["wu"], params["wd"], x, top_p, top_e)


def _dispatch_compute_combine(params, x, xf, top_p, top_e, aux,
                              capacity, cfg):
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    e = cfg.num_experts
    # ---- sort assignments by expert id ----
    flat_e = top_e.reshape(t * k)  # assignment -> expert
    flat_w = top_p.reshape(t * k)
    flat_tok = jnp.repeat(jnp.arange(t), k)  # assignment -> token
    order = jnp.argsort(flat_e)  # stable
    sorted_e = flat_e[order]
    # position of each assignment within its expert's segment
    counts = jnp.bincount(flat_e, length=e)  # [E]
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k) - starts[sorted_e]
    keep = pos_in_e < capacity
    # ---- scatter tokens into [E, C, d] ----
    dst_e = jnp.where(keep, sorted_e, e)  # OOB row dropped
    dst_c = jnp.where(keep, pos_in_e, 0)
    src_tok = flat_tok[order]
    buf = jnp.zeros((e, capacity, d), x.dtype)
    buf = buf.at[dst_e, dst_c].set(xf[src_tok], mode="drop")
    # expert-parallel layout: the capacity buffer lives on the expert axis
    buf = shard_ctx.constrain(buf, ("ep", None, None))
    # ---- batched expert GEMMs (SwiGLU) ----
    ct = x.dtype
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["wg"].astype(ct)))
    u = jnp.einsum("ecd,edf->ecf", buf, params["wu"].astype(ct))
    out_buf = jnp.einsum("ecf,efd->ecd", g * u, params["wd"].astype(ct))
    out_buf = shard_ctx.constrain(out_buf, ("ep", None, None))
    # ---- gather back + combine ----
    y_assign = out_buf[dst_e.clip(0, e - 1), dst_c]  # [T*k, d]
    y_assign = jnp.where(keep[:, None], y_assign, 0.0)
    y_assign = y_assign * flat_w[order][:, None].astype(ct)
    y = jnp.zeros((t, d), ct).at[src_tok].add(y_assign)

    dropped = jnp.sum(1.0 - keep.astype(jnp.float32)) / (t * k)
    aux = dict(aux, moe_dropped_frac=dropped)
    if cfg.num_shared_experts:
        y = y + swiglu(params["shared"], xf)
    return y.reshape(b, s, d), aux
