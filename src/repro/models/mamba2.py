"""Mamba2 (SSD) layer: chunked-parallel training scan + O(1) decode step.

TPU adaptation (see DESIGN.md): the CUDA Mamba2 kernel's warp-level scan is
replaced by the *chunked state-space-dual* form — intra-chunk work becomes
dense ``[L, L]`` einsums (MXU-friendly), inter-chunk state is carried by a
``lax.scan`` over ``S / chunk`` steps.  All statistics in float32.

Recurrence (per head h, state ``[P, N]``):
    h_t = exp(A_h * dt_t) * h_{t-1} + dt_t * x_t ⊗ B_t
    y_t = h_t C_t + D_h * x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

from .layers import Params, pdtype, rms_norm_simple


def dims(cfg: ArchConfig) -> tuple[int, int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    p = cfg.ssm_head_dim
    h = d_in // p
    n = cfg.ssm_state
    return d_in, h, p, n


def init_mamba2(key: jax.Array, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    d_in, h, p, n = dims(cfg)
    conv_dim = d_in + 2 * n
    k1, k2, k3 = jax.random.split(key, 3)
    dt = pdtype(cfg)
    # dt_bias init so that softplus(dt_bias) spans [1e-3, 1e-1] (standard).
    u = jax.random.uniform(k3, (h,), jnp.float32)
    dt_init = jnp.exp(u * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inv_softplus
    return {
        "w_in": jax.random.normal(
            k1, (d, 2 * d_in + 2 * n + h), dt
        ) / np.sqrt(d),
        "conv_w": jax.random.normal(
            k2, (cfg.ssm_conv_width, conv_dim), dt
        ) / np.sqrt(cfg.ssm_conv_width),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "dt_bias": dt_bias.astype(dt),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)).astype(dt),
        "d_skip": jnp.ones((h,), dt),
        "gate_norm": jnp.ones((d_in,), dt),
        "w_out": jax.random.normal(k1, (d_in, d), dt) / np.sqrt(d_in),
    }


def _split_proj(params: Params, x: jax.Array, cfg: ArchConfig):
    d_in, h, p, n = dims(cfg)
    zxbcdt = x @ params["w_in"].astype(x.dtype)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * n]
    dt_raw = zxbcdt[..., 2 * d_in + 2 * n :]
    return z, xbc, dt_raw


def _causal_conv(xbc: jax.Array, params: Params, cfg: ArchConfig
                 ) -> jax.Array:
    """Depthwise causal conv over time. xbc: [B, S, C]."""
    w = params["conv_w"].astype(xbc.dtype)  # [W, C]
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1]] * w[i] for i in range(width)
    )
    return jax.nn.silu(out + params["conv_b"].astype(xbc.dtype))


def _ssd_chunked(
    x: jax.Array,  # [B, S, H, P]
    b_in: jax.Array,  # [B, S, N]
    c_in: jax.Array,  # [B, S, N]
    dt: jax.Array,  # [B, S, H]  (f32, post-softplus)
    a: jax.Array,  # [H] (f32, negative)
    chunk: int,
    h0: jax.Array | None = None,  # [B, H, P, N] initial state
):
    """Chunked SSD scan. Returns (y [B,S,H,P], h_final [B,H,P,N])."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    nc = s // chunk
    assert nc * chunk == s, f"seq {s} not divisible by chunk {chunk}"
    xc = x.reshape(bsz, nc, chunk, h, p).swapaxes(0, 1)
    bc = b_in.reshape(bsz, nc, chunk, n).swapaxes(0, 1)
    cc = c_in.reshape(bsz, nc, chunk, n).swapaxes(0, 1)
    dtc = dt.reshape(bsz, nc, chunk, h).swapaxes(0, 1)
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(h_prev, inp):
        xk, bk, ck, dtk = inp  # [B,L,H,P], [B,L,N], [B,L,N], [B,L,H]
        loga = dtk * a  # [B,L,H]  log decay per step
        s_cum = jnp.cumsum(loga, axis=1)  # inclusive
        # intra-chunk: G[b,h,l,j] = (C_l . B_j) exp(s_l - s_j) dt_j, j<=l
        cb = jnp.einsum("bln,bjn->blj", ck, bk,
                        preferred_element_type=jnp.float32)
        decay = s_cum[:, :, None, :] - s_cum[:, None, :, :]  # [B,l,j,H]
        gate = jnp.where(tri[None, :, :, None], jnp.exp(decay), 0.0)
        g = cb[..., None] * gate * dtk[:, None, :, :]  # [B,l,j,H]
        y_intra = jnp.einsum("bljh,bjhp->blhp", g, xk.astype(jnp.float32))
        # inter-chunk: y_l += exp(s_l) * C_l . h_prev
        y_inter = jnp.einsum(
            "bln,bhpn->blhp", ck.astype(jnp.float32), h_prev
        ) * jnp.exp(s_cum)[:, :, :, None]
        # state update: h = exp(s_L) h_prev + sum_j exp(s_L - s_j) dt_j x_j B_j
        tail = jnp.exp(s_cum[:, -1:, :] - s_cum)  # [B,L,H]
        dx = (tail * dtk)[..., None] * xk.astype(jnp.float32)  # [B,L,H,P]
        h_new = jnp.einsum("blhp,bln->bhpn", dx, bk.astype(jnp.float32))
        h_new = h_new + jnp.exp(s_cum[:, -1])[:, :, None, None] * h_prev
        return h_new, (y_intra + y_inter).astype(x.dtype)

    h_fin, ys = jax.lax.scan(body, h0, (xc, bc, cc, dtc))
    y = ys.swapaxes(0, 1).reshape(bsz, s, h, p)
    return y, h_fin


def mamba2_forward(params: Params, x: jax.Array, cfg: ArchConfig
                   ) -> jax.Array:
    """Full-sequence forward. x: [B, S, d] -> [B, S, d]."""
    d_in, h, p, n = dims(cfg)
    bsz, s, _ = x.shape
    z, xbc, dt_raw = _split_proj(params, x, cfg)
    xbc = _causal_conv(xbc, params, cfg)
    xs = xbc[..., :d_in].reshape(bsz, s, h, p)
    b_in = xbc[..., d_in : d_in + n]
    c_in = xbc[..., d_in + n :]
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    y, _ = _ssd_chunked(xs, b_in, c_in, dt, a, cfg.ssm_chunk)
    y = y + params["d_skip"].astype(y.dtype)[None, None, :, None] * xs
    y = y.reshape(bsz, s, d_in)
    y = rms_norm_simple(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    return y @ params["w_out"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_mamba2_cache(cfg: ArchConfig, batch: int, dtype) -> Params:
    d_in, h, p, n = dims(cfg)
    conv_dim = d_in + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, h, p, n), jnp.float32),
    }


def mamba2_decode_step(
    params: Params, x: jax.Array, cfg: ArchConfig, cache: Params
) -> tuple[jax.Array, Params]:
    """x: [B, 1, d] -> (y [B, 1, d], cache). O(1) in context length."""
    d_in, h, p, n = dims(cfg)
    bsz = x.shape[0]
    z, xbc, dt_raw = _split_proj(params, x, cfg)  # [B,1,*]
    # conv over the cached window + this step
    hist = jnp.concatenate([cache["conv"], xbc], axis=1)  # [B, W, C]
    w = params["conv_w"].astype(x.dtype)
    conv_out = jnp.einsum("bwc,wc->bc", hist, w) + params["conv_b"].astype(
        x.dtype
    )
    xbc_t = jax.nn.silu(conv_out)  # [B, C]
    new_conv = hist[:, 1:]
    xs = xbc_t[..., :d_in].reshape(bsz, h, p)
    b_in = xbc_t[..., d_in : d_in + n]
    c_in = xbc_t[..., d_in + n :]
    dt = jax.nn.softplus(
        dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # [B, H]
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)  # [B, H]
    upd = (dt[..., None] * xs.astype(jnp.float32))[..., None] * b_in.astype(
        jnp.float32
    )[:, None, None, :]
    h_new = decay[:, :, None, None] * cache["ssm"] + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, c_in.astype(jnp.float32))
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xs.astype(
        jnp.float32
    )
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = rms_norm_simple(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    y = y @ params["w_out"].astype(x.dtype)
    return y, {"conv": new_conv, "ssm": h_new}
