"""Foundational layers: norms, RoPE / M-RoPE, GQA attention, FFNs.

Conventions
-----------
* Params are plain nested dicts of ``jnp`` arrays (pytrees), stored in
  ``cfg.param_dtype`` and cast to ``cfg.compute_dtype`` at use sites.
* All sequence tensors are ``[batch, seq, ...]``; attention heads are kept
  as a separate axis ``[B, S, H, Dh]`` (never merged until the out-proj).
* Softmax / norm statistics always run in float32.
* KV caches are fixed-shape ring buffers: ``{"k": [B, W, Hkv, Dh], "v": ...}``
  where ``W`` is the cache window (full ``max_len`` or a sliding window).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

Params = dict
NEG_INF = -1e30


def cdtype(cfg: ArchConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ArchConfig, d: int) -> Params:
    p = {"scale": jnp.ones((d,), pdtype(cfg))}
    if cfg.norm == "ln":
        p["bias"] = jnp.zeros((d,), pdtype(cfg))
    return p


def norm(params: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "ln":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
            jnp.float32
        )
    else:
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_simple(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(
        x.dtype
    )


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_angles(
    positions: jax.Array, head_dim: int, theta: float,
    mrope_sections: tuple[int, ...] = (),
) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables.

    ``positions``: ``[B, S]`` (standard) or ``[B, 3, S]`` (M-RoPE: t/h/w
    position per token).  Returns ``cos, sin`` of shape ``[B, S, Dh/2]``.
    """
    half = head_dim // 2
    inv = (theta ** (-np.arange(0, half) * 2.0 / head_dim)).astype(np.float32)
    inv = jnp.asarray(inv)
    if mrope_sections:
        assert positions.ndim == 3, "M-RoPE needs [B, 3, S] positions"
        ang_full = positions[..., None].astype(jnp.float32) * inv  # [B,3,S,h]
        parts = []
        start = 0
        for axis, sec in enumerate(mrope_sections):
            parts.append(ang_full[:, axis, :, start : start + sec])
            start += sec
        angles = jnp.concatenate(parts, axis=-1)  # [B,S,half]
    else:
        angles = positions[..., None].astype(jnp.float32) * inv  # [B,S,half]
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate-half RoPE. ``x``: [B, S, H, Dh]; cos/sin: [B, S, Dh/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[:, :, None, :].astype(jnp.float32)
    sin = sin[:, :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def init_attention(key: jax.Array, cfg: ArchConfig, d: Optional[int] = None
                   ) -> Params:
    d = d or cfg.d_model
    dh = cfg.resolved_head_dim
    h, hk = cfg.num_heads, cfg.num_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale_in = 1.0 / np.sqrt(d)
    scale_out = 1.0 / np.sqrt(h * dh)
    dt = pdtype(cfg)
    p: Params = {
        "wq": jax.random.normal(k1, (d, h * dh), dt) * scale_in,
        "wk": jax.random.normal(k2, (d, hk * dh), dt) * scale_in,
        "wv": jax.random.normal(k3, (d, hk * dh), dt) * scale_in,
        "wo": jax.random.normal(k4, (h * dh, d), dt) * scale_out,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dt)
        p["bk"] = jnp.zeros((hk * dh,), dt)
        p["bv"] = jnp.zeros((hk * dh,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dt)
        p["k_norm"] = jnp.ones((dh,), dt)
    return p


def _project_qkv(params: Params, x: jax.Array, cfg: ArchConfig):
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim
    h, hk = cfg.num_heads, cfg.num_kv_heads
    ct = x.dtype
    q = x @ params["wq"].astype(ct)
    k = x @ params["wk"].astype(ct)
    v = x @ params["wv"].astype(ct)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(ct)
        k = k + params["bk"].astype(ct)
        v = v + params["bv"].astype(ct)
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, hk, dh)
    v = v.reshape(b, s, hk, dh)
    # Pin TP to the HEAD axis (when divisible).  Without this, SPMD may
    # shard Dh (it divides the mesh even when H does not) — and a
    # Dh-sharded contraction turns every score block into an all-reduce
    # (measured: 859 GB/step of ARs on qwen3-14b train; §Perf log).
    from . import shard_ctx

    q = shard_ctx.constrain_strict(q, ("batch", None, "tp", None))
    k = shard_ctx.constrain_strict(k, ("batch", None, "tp", None))
    v = shard_ctx.constrain_strict(v, ("batch", None, "tp", None))
    if cfg.qk_norm:
        q = rms_norm_simple(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm_simple(k, params["k_norm"], cfg.norm_eps)
    return q, k, v


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: [B,S,H,Dh], k: [B,T,Hkv,Dh] -> scores [B,Hkv,G,S,T] (f32)."""
    b, s, h, dh = q.shape
    hk = k.shape[2]
    g = h // hk
    qg = q.reshape(b, s, hk, g, dh)
    return jnp.einsum(
        "bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32
    ) / np.sqrt(dh)


def _gqa_out(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs: [B,Hkv,G,S,T] (f32), v: [B,T,Hkv,Dh] -> [B,S,H*Dh]."""
    b, hk, g, s, t = probs.shape
    dh = v.shape[-1]
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, hk * g * dh)


def attention(
    params: Params,
    x: jax.Array,
    cfg: ArchConfig,
    positions: jax.Array,
    *,
    seg_mask: Optional[jax.Array] = None,
    use_flash: bool = False,
) -> jax.Array:
    """Full-sequence attention (train / prefill).

    ``positions``: [B, S] or [B, 3, S] (M-RoPE).  Causality comes from
    ``cfg.causal``; ``seg_mask`` ([B, S] validity) masks padding.
    """
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg)
    cos, sin = rope_angles(
        positions, cfg.resolved_head_dim, cfg.rope_theta, cfg.mrope_sections
    )
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if use_flash and cfg.causal and seg_mask is None:
        from repro.kernels.flash_attention import ops as flash_ops

        out = flash_ops.flash_attention(q, k, v, causal=True)
        out = out.reshape(b, s, -1)
    else:
        out = _blocked_attention(
            q, k, v, causal=cfg.causal, seg_mask=seg_mask,
            q_chunk=cfg.attn_q_chunk,
        )
    return out @ params["wo"].astype(x.dtype)


def _blocked_attention(q, k, v, *, causal, seg_mask, q_chunk: int):
    """Row-blocked (lazy-softmax) attention: iterate static query chunks so
    the materialized score block is [B, H, q_chunk, T] instead of the full
    [B, H, S, T] — the XLA-side equivalent of flash attention's memory
    behaviour (each query row still sees its whole softmax denominator, so
    no online rescaling is needed).  The loop is a python loop: every
    chunk appears explicitly in the HLO, keeping the dry-run's static
    FLOP/byte accounting exact."""
    b, s, h, dh = q.shape
    t = k.shape[1]
    qc = q_chunk
    while qc > 1 and s % qc:
        qc //= 2
    n = s // qc

    def one_chunk(i, qs):
        with jax.named_scope("attn_core"):
            scores = _gqa_scores(qs, k)  # [B,Hkv,G,qc,T]
            if causal:
                rows = i * qc + jnp.arange(qc)
                cmask = rows[:, None] >= jnp.arange(t)[None, :]
                scores = jnp.where(cmask[None, None, None], scores, NEG_INF)
            if seg_mask is not None:
                scores = jnp.where(
                    seg_mask[:, None, None, None, :], scores, NEG_INF
                )
            probs = jax.nn.softmax(scores, axis=-1)
            return _gqa_out(probs, v)  # [B,qc,H*Dh]

    if n == 1:
        return one_chunk(0, q)
    # lax.scan over query chunks: structurally sequential, so only ONE
    # [*, qc, T] score block is ever live (forward AND backward — each
    # chunk is checkpointed, so its scores are recomputed inside its own
    # backward).  The flash-attention memory profile, at the XLA level.
    b = q.shape[0]
    h, dh = q.shape[2], q.shape[3]
    qs_all = q.reshape(b, n, qc, h, dh).swapaxes(0, 1)  # [n,B,qc,H,Dh]

    def body(_, xs):
        i, qs = xs
        return None, jax.checkpoint(one_chunk)(i, qs)

    _, outs = jax.lax.scan(body, None, (jnp.arange(n), qs_all))
    return outs.swapaxes(0, 1).reshape(b, s, h * dh)


def init_kv_cache(
    cfg: ArchConfig, batch: int, window: int, dtype
) -> Params:
    dh = cfg.resolved_head_dim
    if cfg.kv_cache_dtype == "int8":
        # per-(position, head) symmetric int8 with bf16 scales: halves the
        # dominant decode byte stream (beyond-paper perf lever, §Perf)
        return {
            "k_q": jnp.zeros((batch, window, cfg.num_kv_heads, dh), jnp.int8),
            "k_s": jnp.zeros((batch, window, cfg.num_kv_heads), jnp.bfloat16),
            "v_q": jnp.zeros((batch, window, cfg.num_kv_heads, dh), jnp.int8),
            "v_s": jnp.zeros((batch, window, cfg.num_kv_heads), jnp.bfloat16),
        }
    return {
        "k": jnp.zeros((batch, window, cfg.num_kv_heads, dh), dtype),
        "v": jnp.zeros((batch, window, cfg.num_kv_heads, dh), dtype),
    }


def _quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: [..., Dh] -> (int8 values, bf16 scale over the last dim)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) + 1e-8
    scale = (amax / 127.0).astype(jnp.bfloat16)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale.astype(jnp.float32)[..., None]),
        -127, 127,
    ).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    # dequantize directly in the compute dtype: int8 -> bf16 converts are
    # exact (|q| <= 127) and skipping the f32 intermediate saves a full
    # cache-sized f32 round trip per layer (§Perf)
    return q.astype(dtype) * scale.astype(dtype)[..., None]


def attention_decode(
    params: Params,
    x: jax.Array,  # [B, 1, d]
    cfg: ArchConfig,
    cache: Params,
    position: jax.Array,  # [B] absolute position of the new token
) -> tuple[jax.Array, Params]:
    """One decode step against a (possibly sliding-window) ring cache."""
    b = x.shape[0]
    window = (cache["k_q"] if "k_q" in cache else cache["k"]).shape[1]
    q, k_new, v_new = _project_qkv(params, x, cfg)  # S = 1
    pos_b = position[:, None]  # [B,1]
    if cfg.mrope_sections:
        pos_rope = jnp.broadcast_to(pos_b[:, None], (b, 3, 1))
    else:
        pos_rope = pos_b
    cos, sin = rope_angles(
        pos_rope, cfg.resolved_head_dim, cfg.rope_theta, cfg.mrope_sections
    )
    q = apply_rope(q, cos, sin)
    k_new = apply_rope(k_new, cos, sin)
    slot = (position % window)[:, None]  # ring-buffer slot
    bidx = jnp.arange(b)[:, None]
    quantized = "k_q" in cache
    if quantized:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        new_cache = {
            "k_q": cache["k_q"].at[bidx, slot].set(kq),
            "k_s": cache["k_s"].at[bidx, slot].set(ks),
            "v_q": cache["v_q"].at[bidx, slot].set(vq),
            "v_s": cache["v_s"].at[bidx, slot].set(vs),
        }
        k_cache = _dequantize_kv(new_cache["k_q"], new_cache["k_s"], x.dtype)
        v_cache = _dequantize_kv(new_cache["v_q"], new_cache["v_s"], x.dtype)
    else:
        k_cache = cache["k"].at[bidx, slot].set(k_new)
        v_cache = cache["v"].at[bidx, slot].set(v_new)
        new_cache = {"k": k_cache, "v": v_cache}
    # Valid entries: absolute index of slot j is <= position and within
    # the last `window` tokens.
    slots = jnp.arange(window)[None, :]  # [1, W]
    written = jnp.minimum(position[:, None] + 1, window)  # entries present
    # For a ring buffer the valid set is simply "slot has been written",
    # i.e. slot < written when position < window, else all.
    valid = slots < written
    with jax.named_scope("attn_core"):
        scores = _gqa_scores(q, k_cache)  # [B,Hkv,G,1,W]
        scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = _gqa_out(probs, v_cache)  # [B,1,H*Dh]
    out = out @ params["wo"].astype(x.dtype)
    return out, new_cache


# ---------------------------------------------------------------------------
# FFNs
# ---------------------------------------------------------------------------


def init_swiglu(key: jax.Array, cfg: ArchConfig, d: int, d_ff: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    dt = pdtype(cfg)
    return {
        "wg": jax.random.normal(k1, (d, d_ff), dt) / np.sqrt(d),
        "wu": jax.random.normal(k2, (d, d_ff), dt) / np.sqrt(d),
        "wd": jax.random.normal(k3, (d_ff, d), dt) / np.sqrt(d_ff),
    }


def swiglu(params: Params, x: jax.Array) -> jax.Array:
    ct = x.dtype
    g = jax.nn.silu(x @ params["wg"].astype(ct))
    u = x @ params["wu"].astype(ct)
    return (g * u) @ params["wd"].astype(ct)


def init_gelu_mlp(key: jax.Array, cfg: ArchConfig, d: int, d_ff: int) -> Params:
    k1, k2 = jax.random.split(key)
    dt = pdtype(cfg)
    return {
        "w1": jax.random.normal(k1, (d, d_ff), dt) / np.sqrt(d),
        "b1": jnp.zeros((d_ff,), dt),
        "w2": jax.random.normal(k2, (d_ff, d), dt) / np.sqrt(d_ff),
        "b2": jnp.zeros((d,), dt),
    }


def gelu_mlp(params: Params, x: jax.Array) -> jax.Array:
    ct = x.dtype
    h = jax.nn.gelu(x @ params["w1"].astype(ct) + params["b1"].astype(ct))
    return h @ params["w2"].astype(ct) + params["b2"].astype(ct)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def init_embed(key: jax.Array, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    dt = pdtype(cfg)
    p = {
        "embedding": jax.random.normal(
            k1, (cfg.vocab_size, cfg.d_model), dt
        ) * 0.02
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(
            k2, (cfg.d_model, cfg.vocab_size), dt
        ) / np.sqrt(cfg.d_model)
    return p


def embed(params: Params, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    return params["embedding"].astype(cdtype(cfg))[tokens]


def unembed(params: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embedding"].astype(x.dtype).T
    else:
        w = params["lm_head"].astype(x.dtype)
    return x @ w
