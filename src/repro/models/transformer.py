"""Unified model assembly for all assigned architecture families.

One :class:`Model` object per :class:`ArchConfig` exposes:

* ``init(key)``                     — parameter pytree (stacked layers);
* ``forward(params, batch)``        — full-sequence logits (train/prefill);
* ``loss(params, batch)``           — scalar loss + metrics;
* ``init_cache(batch, window)``     — decode cache pytree;
* ``prefill(params, batch, window)``— populate cache from a prompt;
* ``decode_step(params, cache, tokens, pos)`` — one serve step;
* ``input_specs(shape)``            — ShapeDtypeStruct stand-ins per shape.

Layer stacks are homogeneous and scanned (``lax.scan``) so graphs stay
small; heterogeneous structure (MoE leading dense layers, xLSTM block
patterns, Zamba2's shared attention) is expressed as stacked groups.
Activation checkpointing is selected by ``remat`` (none | full | dots).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property, partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec

from . import layers as L
from . import mamba2 as M
from . import moe as MOE
from . import shard_ctx
from . import xlstm as X

Params = dict
PyTree = Any


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "full":
        return jax.checkpoint(fn)
    if mode == "dots":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )
    raise ValueError(f"unknown remat mode {mode!r}")


# ---------------------------------------------------------------------------
# Per-kind blocks (full-sequence / prefill / decode)
# ---------------------------------------------------------------------------


def init_attn_block(key, cfg: ArchConfig, moe_layer: bool) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "attn": L.init_attention(k1, cfg),
        "ln2": L.init_norm(cfg, cfg.d_model),
    }
    if moe_layer:
        p["moe"] = MOE.init_moe(k2, cfg)
    elif cfg.norm == "ln":
        p["mlp"] = L.init_gelu_mlp(k3, cfg, cfg.d_model, cfg.d_ff)
    else:
        d_ff = cfg.dense_d_ff if (cfg.family == "moe") else cfg.d_ff
        p["mlp"] = L.init_swiglu(k3, cfg, cfg.d_model, d_ff)
    return p


def attn_block(p, h, cfg, positions, seg_mask=None, use_flash=False):
    aux = {}
    h = h + L.attention(
        p["attn"], L.norm(p["ln1"], h, cfg), cfg, positions,
        seg_mask=seg_mask, use_flash=use_flash,
    )
    hn = L.norm(p["ln2"], h, cfg)
    if "moe" in p:
        y, aux = MOE.moe_ffn(p["moe"], hn, cfg)
    elif cfg.norm == "ln":
        y = L.gelu_mlp(p["mlp"], hn)
    else:
        y = L.swiglu(p["mlp"], hn)
    return h + y, aux


def attn_block_decode(p, h, cfg, cache, pos):
    out, cache = L.attention_decode(
        p["attn"], L.norm(p["ln1"], h, cfg), cfg, cache, pos
    )
    h = h + out
    hn = L.norm(p["ln2"], h, cfg)
    if "moe" in p:
        y, _ = MOE.moe_ffn(p["moe"], hn, cfg)
    elif cfg.norm == "ln":
        y = L.gelu_mlp(p["mlp"], hn)
    else:
        y = L.swiglu(p["mlp"], hn)
    return h + y, cache


# ---------------------------------------------------------------------------
# The Model
# ---------------------------------------------------------------------------


@dataclass
class Model:
    cfg: ArchConfig
    use_flash: bool = False
    # Unroll layer stacks into a python loop instead of lax.scan.  Used by
    # the dry-run so per-layer collectives appear explicitly in the HLO
    # (exact static roofline accounting); scan is the production default
    # (small graphs, fast compiles).
    unroll: bool = False
    # Activation sharding rules, set by the launcher when running under a
    # mesh: {"batch": ("pod","data"), "tp": "model",
    #        "sizes": {axis: size}}.  Explicit with_sharding_constraint on
    # the residual stream / logits keeps the batch data-parallel (SPMD
    # propagation alone can resolve gather conflicts by replicating the
    # batch — catastrophic at scale).  None => no constraints (tests).
    axis_rules: Optional[dict] = None

    def _wsc(self, x, logical: tuple):
        """Constrain ``x`` to the logical spec (see shard_ctx.constrain)."""
        if self.axis_rules is None:
            return x
        return shard_ctx.constrain(x, logical)

    # parameter leaves that are matmul weights (safe to stream as bf16);
    # norms/biases/gates stay in param_dtype (f32) - tiny and numerically
    # sensitive.
    _MATRIX_KEYS = (
        "wq", "wk", "wv", "wo", "wg", "wu", "wd", "w1", "w2",
        "w_in", "w_out", "w_up", "w_down", "w_if", "w_gates", "r_gates",
        "embedding", "lm_head", "router", "conv_w",
    )

    def cast_for_compute(self, params: Params) -> Params:
        """One bf16 copy of the matmul weights, made once per step.

        Streaming weights at 2 bytes (instead of casting f32 slices at
        every use) halves FSDP all-gather bytes and the per-layer weight
        traffic; AdamW still updates the f32 masters (mixed precision).
        """
        cd = L.cdtype(self.cfg)
        if cd == L.pdtype(self.cfg):
            return params
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        out = []
        for path, leaf in flat:
            last = None
            for pp in reversed(path):
                if hasattr(pp, "key"):
                    last = str(pp.key)
                    break
            if last in self._MATRIX_KEYS and jnp.issubdtype(
                leaf.dtype, jnp.floating
            ):
                out.append(leaf.astype(cd))
            else:
                out.append(leaf)
        return jax.tree_util.tree_unflatten(treedef, out)

    def _scan(self, body, carry, xs, length: Optional[int] = None):
        """lax.scan or an unrolled python loop (dry-run accounting mode)."""
        if not self.unroll:
            return jax.lax.scan(body, carry, xs)
        n = length
        if n is None:
            n = len(jax.tree_util.tree_leaves(xs)[0])
        ys = []
        for i in range(n):
            x_i = jax.tree.map(lambda t: t[i], xs)
            carry, y = body(carry, x_i)
            ys.append(y)
        if ys and all(y is not None for y in ys):
            try:
                ys = jax.tree.map(lambda *t: jnp.stack(t), *ys)
            except (TypeError, ValueError):
                ys = None
        else:
            ys = None
        return carry, ys

    # ------------------------------------------------------------- init

    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        k_embed, k_layers, k_extra = jax.random.split(key, 3)
        params: Params = {"final_norm": L.init_norm(cfg, cfg.d_model)}
        if cfg.family == "audio":
            # stub frontend supplies embeddings; keep head + pos-free encoder
            params["head"] = L.init_gelu_mlp(
                k_embed, cfg, cfg.d_model, cfg.d_model
            )
            params["lm_head"] = jax.random.normal(
                k_extra, (cfg.d_model, cfg.vocab_size), L.pdtype(cfg)
            ) / np.sqrt(cfg.d_model)
        else:
            params["embed"] = L.init_embed(k_embed, cfg)

        if cfg.family in ("dense", "audio", "vlm"):
            keys = jax.random.split(k_layers, cfg.num_layers)
            params["layers"] = jax.vmap(
                lambda k: init_attn_block(k, cfg, moe_layer=False)
            )(keys)
        elif cfg.family == "moe":
            fd = cfg.first_dense_layers
            params["dense_layers"] = [
                init_attn_block(k, cfg, moe_layer=False)
                for k in jax.random.split(k_extra, fd)
            ] if fd else []
            keys = jax.random.split(k_layers, cfg.num_layers - fd)
            params["layers"] = jax.vmap(
                lambda k: init_attn_block(k, cfg, moe_layer=True)
            )(keys)
        elif cfg.family == "ssm":
            pattern = cfg.xlstm_pattern
            n_groups = cfg.num_layers // len(pattern)
            n_m = sum(1 for k in pattern if k == "mlstm")

            def init_group(k):
                km, ks = jax.random.split(k)
                g: Params = {}
                if n_m:
                    g["mlstm"] = jax.vmap(
                        lambda kk: {
                            "ln": L.init_norm(cfg, cfg.d_model),
                            "cell": X.init_mlstm(kk, cfg),
                        }
                    )(jax.random.split(km, n_m))
                if "slstm" in pattern:
                    g["slstm"] = {
                        "ln": L.init_norm(cfg, cfg.d_model),
                        "cell": X.init_slstm(ks, cfg),
                    }
                return g

            params["groups"] = jax.vmap(init_group)(
                jax.random.split(k_layers, n_groups)
            )
        elif cfg.family == "hybrid":
            keys = jax.random.split(k_layers, cfg.num_layers)
            params["layers"] = jax.vmap(
                lambda k: {
                    "ln": L.init_norm(cfg, cfg.d_model),
                    "mamba": M.init_mamba2(k, cfg),
                }
            )(keys)
            params["shared"] = init_attn_block(k_extra, cfg, moe_layer=False)
        else:
            raise ValueError(cfg.family)
        return params

    # ------------------------------------------------------------- fwd

    def _embed_batch(self, params, batch):
        """Returns (h [B,S,d], positions, loss_mask, labels)."""
        cfg = self.cfg
        if cfg.family == "audio":
            frames = batch["frames"].astype(L.cdtype(cfg))
            h = L.gelu_mlp(params["head"], frames)
            b, s, _ = h.shape
            pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            return h, pos, jnp.ones((b, s)), batch.get("labels")
        if cfg.family == "vlm":
            tokens = batch["tokens"]
            patches = batch["patch_embeds"].astype(L.cdtype(cfg))
            text = L.embed(params["embed"], tokens, cfg)
            h = jnp.concatenate([patches, text], axis=1)
            b, s, _ = h.shape
            positions = batch["positions"]  # [B, 3, S]
            si = patches.shape[1]
            mask = jnp.concatenate(
                [jnp.zeros((b, si)), jnp.ones((b, tokens.shape[1]))], axis=1
            )
            pad_img = jnp.zeros((b, si), tokens.dtype)
            labels_full = jnp.concatenate([pad_img, tokens], axis=1)
            return h, positions, mask, labels_full
        tokens = batch["tokens"]
        h = L.embed(params["embed"], tokens, cfg)
        b, s = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        return h, pos, batch.get("loss_mask", jnp.ones((b, s))), tokens

    def backbone(self, params, h, positions, remat: str = "none"):
        """Run the layer stack. Returns (h, aux)."""
        cfg = self.cfg
        aux_sum = {"moe_aux_loss": jnp.zeros((), jnp.float32)}

        if cfg.family in ("dense", "audio", "vlm"):
            def body(carry, lp):
                out, aux = attn_block(
                    lp, carry, cfg, positions, use_flash=self.use_flash
                )
                out = self._wsc(out, ("batch", None, None))
                return out, aux.get("moe_aux_loss", 0.0)

            h, _ = self._scan(_remat(body, remat), h, params["layers"])
        elif cfg.family == "moe":
            for lp in params["dense_layers"]:
                h, _ = attn_block(lp, h, cfg, positions,
                                  use_flash=self.use_flash)
                h = self._wsc(h, ("batch", None, None))

            def body(carry, lp):
                out, aux = attn_block(
                    lp, carry, cfg, positions, use_flash=self.use_flash
                )
                out = self._wsc(out, ("batch", None, None))
                return out, aux["moe_aux_loss"]

            h, auxl = self._scan(_remat(body, remat), h, params["layers"])
            aux_sum["moe_aux_loss"] = jnp.sum(auxl)
        elif cfg.family == "ssm":
            def body(carry, gp):
                out = carry
                if "mlstm" in gp:
                    def mbody(c, mp):
                        return c + X.mlstm_forward(
                            mp["cell"], L.norm(mp["ln"], c, cfg), cfg
                        ), None
                    out, _ = self._scan(mbody, out, gp["mlstm"])
                if "slstm" in gp:
                    sp = gp["slstm"]
                    out = out + X.slstm_forward(
                        sp["cell"], L.norm(sp["ln"], out, cfg), cfg
                    )
                out = self._wsc(out, ("batch", None, None))
                return out, None

            h, _ = self._scan(_remat(body, remat), h, params["groups"])
        elif cfg.family == "hybrid":
            # Zamba2: groups of `every` mamba layers, each followed by the
            # single shared attention block (one weight copy, reapplied).
            every = cfg.shared_attn_every
            shared = params["shared"]
            n_groups = cfg.num_layers // every
            tail = cfg.num_layers - n_groups * every

            def mamba_body(carry, lp):
                out = carry + M.mamba2_forward(
                    lp["mamba"], L.norm(lp["ln"], carry, cfg), cfg
                )
                return self._wsc(out, ("batch", None, None)), None

            def group_body(carry, gp):
                out, _ = self._scan(mamba_body, carry, gp)
                out, _ = attn_block(shared, out, cfg, positions,
                                    use_flash=self.use_flash)
                return self._wsc(out, ("batch", None, None)), None

            grouped = jax.tree.map(
                lambda t: t[: n_groups * every].reshape(
                    (n_groups, every) + t.shape[1:]
                ),
                params["layers"],
            )
            h, _ = self._scan(_remat(group_body, remat), h, grouped)
            if tail:
                tail_p = jax.tree.map(
                    lambda t: t[n_groups * every :], params["layers"]
                )
                h, _ = self._scan(_remat(mamba_body, remat), h, tail_p)
        return h, aux_sum

    def forward(self, params, batch, remat: str = "none"):
        """Full-sequence logits. Returns (logits [B,S,V], aux)."""
        with shard_ctx.use_rules(self.axis_rules):
            return self._forward(params, batch, remat)

    def _forward(self, params, batch, remat: str = "none"):
        cfg = self.cfg
        h, positions, mask, _ = self._embed_batch(params, batch)
        h = self._wsc(h, ("batch", None, None))
        h, aux = self.backbone(params, h, positions, remat)
        h = L.norm(params["final_norm"], h, cfg)
        if cfg.family == "audio":
            logits = h @ params["lm_head"].astype(h.dtype)
        else:
            logits = L.unembed(params["embed"], h, cfg)
        logits = self._wsc(logits, ("batch", None, "tp"))
        return logits, aux

    # ------------------------------------------------------------- loss

    def loss(self, params, batch, remat: str = "none"):
        cfg = self.cfg
        logits, aux = self.forward(params, batch, remat)
        _, _, mask, labels = self._embed_batch(params, batch)
        if cfg.is_encoder:
            # frame-level classification (HuBERT-style masked prediction)
            tgt, m = labels, mask
        else:
            # next-token prediction
            tgt = jnp.roll(labels, -1, axis=1)
            m = mask * jnp.roll(mask, -1, axis=1)
            m = m.at[:, -1].set(0.0)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, tgt[..., None], axis=-1
        )[..., 0]
        nll = (logz - gold) * m
        denom = jnp.maximum(jnp.sum(m), 1.0)
        ce = jnp.sum(nll) / denom
        total = ce + 0.01 * aux.get("moe_aux_loss", 0.0)
        return total, {"ce": ce, **aux}

    # ------------------------------------------------------------- serve

    def init_cache(self, batch: int, window: int) -> Params:
        cfg = self.cfg
        dt = L.cdtype(cfg)
        if cfg.family in ("dense", "vlm"):
            return {
                "kv": jax.vmap(
                    lambda _: L.init_kv_cache(cfg, batch, window, dt)
                )(jnp.arange(cfg.num_layers))
            }
        if cfg.family == "moe":
            fd = cfg.first_dense_layers
            return {
                "dense_kv": [
                    L.init_kv_cache(cfg, batch, window, dt) for _ in range(fd)
                ],
                "kv": jax.vmap(
                    lambda _: L.init_kv_cache(cfg, batch, window, dt)
                )(jnp.arange(cfg.num_layers - fd)),
            }
        if cfg.family == "ssm":
            pattern = cfg.xlstm_pattern
            n_groups = cfg.num_layers // len(pattern)
            n_m = sum(1 for k in pattern if k == "mlstm")
            cache: Params = {}
            if n_m:
                cache["mlstm"] = jax.vmap(
                    lambda _: jax.vmap(
                        lambda __: X.init_mlstm_cache(cfg, batch, dt)
                    )(jnp.arange(n_m))
                )(jnp.arange(n_groups))
            if "slstm" in pattern:
                cache["slstm"] = jax.vmap(
                    lambda _: X.init_slstm_cache(cfg, batch, dt)
                )(jnp.arange(n_groups))
            return cache
        if cfg.family == "hybrid":
            n_sites = cfg.num_layers // cfg.shared_attn_every
            return {
                "mamba": jax.vmap(
                    lambda _: M.init_mamba2_cache(cfg, batch, dt)
                )(jnp.arange(cfg.num_layers)),
                "shared_kv": jax.vmap(
                    lambda _: L.init_kv_cache(cfg, batch, window, dt)
                )(jnp.arange(n_sites)),
            }
        raise ValueError(f"{cfg.family} has no decode path")

    def decode_step(self, params, cache, tokens: jax.Array, pos: jax.Array):
        """One token per sequence. tokens [B] i32, pos [B] i32.
        Returns (logits [B, V], new_cache)."""
        with shard_ctx.use_rules(self.axis_rules):
            return self._decode_step(params, cache, tokens, pos)

    def _decode_step(self, params, cache, tokens: jax.Array, pos: jax.Array):
        cfg = self.cfg
        h = L.embed(params["embed"], tokens[:, None], cfg)  # [B,1,d]
        h = self._wsc(h, ("batch", None, None))

        # The KV/state cache rides in the scan CARRY and is updated with
        # dynamic-update-slice: XLA aliases while-loop carry buffers in
        # place, so decode holds ONE cache copy.  (Passing the cache as
        # scan xs/ys allocates a second full cache for the stacked
        # outputs — measured +11 GB/device on qwen1.5-32b decode_32k.)
        def _indexed(tree_, i):
            return jax.tree.map(
                lambda t: jax.lax.dynamic_index_in_dim(t, i, 0,
                                                       keepdims=False),
                tree_,
            )

        def _written(tree_, new, i):
            return jax.tree.map(
                lambda full, n_: jax.lax.dynamic_update_index_in_dim(
                    full, n_, i, 0
                ),
                tree_, new,
            )

        if cfg.family in ("dense", "vlm"):
            def body(carry, xs):
                out, kv = carry
                i, lp = xs
                lc = _indexed(kv, i)
                out, lc = attn_block_decode(lp, out, cfg, lc, pos)
                return (out, _written(kv, lc, i)), None

            n = cfg.num_layers
            (h, kv), _ = self._scan(
                body, (h, cache["kv"]), (jnp.arange(n), params["layers"])
            )
            cache = {"kv": kv}
        elif cfg.family == "moe":
            new_dense = []
            for lp, lc in zip(params["dense_layers"], cache["dense_kv"]):
                h, lc = attn_block_decode(lp, h, cfg, lc, pos)
                new_dense.append(lc)

            def body(carry, xs):
                out, kv = carry
                i, lp = xs
                lc = _indexed(kv, i)
                out, lc = attn_block_decode(lp, out, cfg, lc, pos)
                return (out, _written(kv, lc, i)), None

            n = cfg.num_layers - cfg.first_dense_layers
            (h, kv), _ = self._scan(
                body, (h, cache["kv"]), (jnp.arange(n), params["layers"])
            )
            cache = {"dense_kv": new_dense, "kv": kv}
        elif cfg.family == "ssm":
            def gbody(carry, xs):
                gp, gc = xs
                out = carry
                new_gc = dict(gc)
                if "mlstm" in gp:
                    def mbody(c, mxs):
                        mp, mc = mxs
                        y, mc = X.mlstm_decode_step(
                            mp["cell"], L.norm(mp["ln"], c, cfg), cfg, mc
                        )
                        return c + y, mc
                    out, mcache = self._scan(
                        mbody, out, (gp["mlstm"], gc["mlstm"])
                    )
                    new_gc["mlstm"] = mcache
                if "slstm" in gp:
                    sp = gp["slstm"]
                    y, sc = X.slstm_decode_step(
                        sp["cell"], L.norm(sp["ln"], out, cfg), cfg,
                        gc["slstm"],
                    )
                    out = out + y
                    new_gc["slstm"] = sc
                return out, new_gc

            h, gcache = self._scan(gbody, h, (params["groups"], cache))
            cache = gcache
        elif cfg.family == "hybrid":
            every = cfg.shared_attn_every
            shared = params["shared"]
            n_groups = cfg.num_layers // every
            tail = cfg.num_layers - n_groups * every

            def mamba_body(carry, xs):
                lp, lc = xs
                y, lc = M.mamba2_decode_step(
                    lp["mamba"], L.norm(lp["ln"], carry, cfg), cfg, lc
                )
                return carry + y, lc

            def group_body(carry, xs):
                gp, gc, skv = xs
                out, mcache = self._scan(mamba_body, carry, (gp, gc))
                out, skv = attn_block_decode(shared, out, cfg, skv, pos)
                return out, (mcache, skv)

            group = lambda t: t[: n_groups * every].reshape(
                (n_groups, every) + t.shape[1:]
            )
            grouped_p = jax.tree.map(group, params["layers"])
            grouped_c = jax.tree.map(group, cache["mamba"])
            h, (mcache_g, shared_kv) = self._scan(
                group_body, h, (grouped_p, grouped_c, cache["shared_kv"])
            )
            mcache = jax.tree.map(
                lambda t: t.reshape((n_groups * every,) + t.shape[2:]),
                mcache_g,
            )
            if tail:
                tail_p = jax.tree.map(
                    lambda t: t[n_groups * every :], params["layers"]
                )
                tail_c = jax.tree.map(
                    lambda t: t[n_groups * every :], cache["mamba"]
                )
                h, tcache = self._scan(mamba_body, h, (tail_p, tail_c))
                mcache = jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b], axis=0),
                    mcache, tcache,
                )
            cache = {"mamba": mcache, "shared_kv": shared_kv}
        else:
            raise ValueError(f"{cfg.family} has no decode path")

        h = L.norm(params["final_norm"], h, cfg)
        logits = L.unembed(params["embed"], h, cfg)[:, 0]
        logits = self._wsc(logits, ("batch", "tp"))
        return logits.astype(jnp.float32), cache

    # ------------------------------------------------------------- specs

    def input_specs(self, shape: ShapeSpec) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this shape.

        Modality frontends are STUBS: audio supplies precomputed frame
        embeddings, vlm supplies precomputed patch embeddings + M-RoPE ids.
        """
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        cd = L.cdtype(cfg)
        if shape.kind == "decode":
            return {
                "tokens": jax.ShapeDtypeStruct((b,), i32),
                "pos": jax.ShapeDtypeStruct((b,), i32),
            }
        if cfg.family == "audio":
            return {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), cd),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        if cfg.family == "vlm":
            si = s // 8  # image patches occupy 1/8 of the sequence
            st = s - si
            return {
                "tokens": jax.ShapeDtypeStruct((b, st), i32),
                "patch_embeds": jax.ShapeDtypeStruct((b, si, cfg.d_model), cd),
                "positions": jax.ShapeDtypeStruct((b, 3, s), i32),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}

    def make_batch(self, key: jax.Array, shape: ShapeSpec) -> dict:
        """Concrete random inputs matching ``input_specs`` (for smoke runs)."""
        cfg = self.cfg
        specs = self.input_specs(shape)
        out = {}
        for name, spec in specs.items():
            key, k = jax.random.split(key)
            if jnp.issubdtype(spec.dtype, jnp.integer):
                if name == "tokens":
                    out[name] = jax.random.randint(
                        k, spec.shape, 0, cfg.vocab_size, spec.dtype
                    )
                elif name == "labels":
                    out[name] = jax.random.randint(
                        k, spec.shape, 0, cfg.vocab_size, spec.dtype
                    )
                elif name == "positions":
                    b, _, s = spec.shape
                    base = jnp.broadcast_to(jnp.arange(s)[None, None], spec.shape)
                    out[name] = base.astype(spec.dtype)
                elif name == "pos":
                    out[name] = jnp.zeros(spec.shape, spec.dtype)
                else:
                    out[name] = jnp.zeros(spec.shape, spec.dtype)
            else:
                out[name] = jax.random.normal(k, spec.shape, spec.dtype)
        return out


def get_model(cfg: ArchConfig, use_flash: bool = False) -> Model:
    return Model(cfg, use_flash=use_flash)
