"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM (scalar
memory, sequential) — arXiv:2405.04517.

TPU adaptation: the mLSTM recurrence admits the same chunked-parallel
treatment as SSD — intra-chunk terms become masked ``[L, L]`` einsums on the
MXU, inter-chunk state ``(C, n, m)`` is carried by ``lax.scan``; the
exponential gating is max-stabilized in log space (float32).  The sLSTM is
inherently sequential (its recurrence mixes hidden state into the gates), so
it runs as a ``lax.scan`` over time with block-diagonal per-head recurrent
weights — this is the honest cost of sLSTM on any accelerator.

Cell equations (stabilized) follow the paper's Appendix A.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

from .layers import Params, pdtype, rms_norm_simple


def mlstm_dims(cfg: ArchConfig) -> tuple[int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    h = cfg.num_heads
    p = d_in // h
    return d_in, h, p


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key: jax.Array, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    d_in, h, p = mlstm_dims(cfg)
    ks = jax.random.split(key, 6)
    dt = pdtype(cfg)
    return {
        "w_up": jax.random.normal(ks[0], (d, 2 * d_in), dt) / np.sqrt(d),
        "conv_w": jax.random.normal(
            ks[1], (cfg.ssm_conv_width, d_in), dt
        ) / np.sqrt(cfg.ssm_conv_width),
        "conv_b": jnp.zeros((d_in,), dt),
        "wq": jax.random.normal(ks[2], (d_in, d_in), dt) / np.sqrt(d_in),
        "wk": jax.random.normal(ks[3], (d_in, d_in), dt) / np.sqrt(d_in),
        "wv": jax.random.normal(ks[4], (d_in, d_in), dt) / np.sqrt(d_in),
        "w_if": jax.random.normal(ks[5], (d_in, 2 * h), dt) / np.sqrt(d_in),
        # bias init: forget gates start open (+3), input gates mild (-1)
        "b_if": jnp.concatenate(
            [jnp.full((h,), -1.0), jnp.full((h,), 3.0)]
        ).astype(dt),
        "head_norm": jnp.ones((d_in,), dt),
        "w_down": jax.random.normal(ks[0], (d_in, d), dt) / np.sqrt(d_in),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1]] * w[i] for i in range(width))
    return jax.nn.silu(out + b)


def _mlstm_qkv_gates(params: Params, x: jax.Array, cfg: ArchConfig):
    d_in, h, p = mlstm_dims(cfg)
    bsz, s, _ = x.shape
    up = x @ params["w_up"].astype(x.dtype)
    x_part, z_part = up[..., :d_in], up[..., d_in:]
    x_conv = _causal_conv(
        x_part, params["conv_w"].astype(x.dtype),
        params["conv_b"].astype(x.dtype),
    )
    q = (x_conv @ params["wq"].astype(x.dtype)).reshape(bsz, s, h, p)
    k = (x_conv @ params["wk"].astype(x.dtype)).reshape(bsz, s, h, p)
    k = k / np.sqrt(p)
    v = (x_part @ params["wv"].astype(x.dtype)).reshape(bsz, s, h, p)
    if_pre = (
        x_conv @ params["w_if"].astype(x.dtype)
        + params["b_if"].astype(x.dtype)
    ).astype(jnp.float32)
    log_i = if_pre[..., :h]  # [B,S,H]
    log_f = -jax.nn.softplus(-if_pre[..., h:])  # log sigmoid
    return q, k, v, z_part, log_i, log_f, x_conv


def _mlstm_chunked(q, k, v, log_i, log_f, chunk: int, state=None):
    """Stabilized chunk-parallel mLSTM.

    q,k,v: [B,S,H,P]; log_i/log_f: [B,S,H] (f32).
    Returns (h_out [B,S,H,P], state=(C [B,H,P,P], n [B,H,P], m [B,H])).
    """
    bsz, s, h, p = q.shape
    nc = s // chunk
    assert nc * chunk == s
    swap = lambda t: t.reshape(bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    qc, kc, vc = swap(q), swap(k), swap(v)
    lic, lfc = swap(log_i), swap(log_f)
    if state is None:
        state = (
            jnp.zeros((bsz, h, p, p), jnp.float32),
            jnp.zeros((bsz, h, p), jnp.float32),
            jnp.full((bsz, h), -1e30, jnp.float32),
        )
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(carry, inp):
        c_prev, n_prev, m_prev = carry
        qk_, kk, vk, li, lf = inp
        fcum = jnp.cumsum(lf, axis=1)  # [B,L,H] inclusive
        # b[l,j] = Fcum_l - Fcum_j + log i_j   (j <= l)
        bmat = fcum[:, :, None, :] - fcum[:, None, :, :] + li[:, None, :, :]
        bmat = jnp.where(tri[None, :, :, None], bmat, -jnp.inf)
        m_intra = jnp.max(bmat, axis=2)  # [B,L,H]
        m_inter = fcum + m_prev[:, None, :]
        m = jnp.maximum(m_intra, m_inter)  # [B,L,H]
        m = jnp.maximum(m, -1e30)  # keep finite
        # intra-chunk attention-like term
        qkt = jnp.einsum("blhp,bjhp->blhj", qk_.astype(jnp.float32),
                         kk.astype(jnp.float32))
        # bmat is already -inf outside the causal triangle -> exp gives 0
        w_ = qkt * jnp.exp(bmat.swapaxes(2, 3) - m[:, :, :, None])  # [B,l,h,j]
        num_intra = jnp.einsum("blhj,bjhp->blhp", w_, vk.astype(jnp.float32))
        den_intra = jnp.sum(w_, axis=-1)  # [B,l,h]
        # inter-chunk contribution
        scale_inter = jnp.exp(m_inter - m)  # [B,L,H]
        q32 = qk_.astype(jnp.float32)
        num_inter = jnp.einsum("blhp,bhpq->blhq", q32, c_prev) * scale_inter[
            ..., None
        ]
        den_inter = jnp.einsum("blhp,bhp->blh", q32, n_prev) * scale_inter
        num = num_intra + num_inter
        den = den_intra + den_inter
        h_out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
        # ---- state update at chunk end ----
        f_tail = fcum[:, -1:, :] - fcum + li  # [B,L,H] log weight per j
        m_new = jnp.maximum(
            jnp.max(f_tail, axis=1), fcum[:, -1] + m_prev
        )  # [B,H]
        w_state = jnp.exp(f_tail - m_new[:, None, :])  # [B,L,H]
        kv = jnp.einsum(
            "blhp,blhq->bhpq",
            (kc_ := kk.astype(jnp.float32)) * w_state[..., None],
            vk.astype(jnp.float32),
        )
        c_new = (
            jnp.exp(fcum[:, -1] + m_prev - m_new)[:, :, None, None] * c_prev
            + kv
        )
        ksum = jnp.einsum("blhp->bhp", kc_ * w_state[..., None])
        n_new = jnp.exp(fcum[:, -1] + m_prev - m_new)[:, :, None] * n_prev + ksum
        return (c_new, n_new, m_new), h_out.astype(q.dtype)

    state_f, hs = jax.lax.scan(body, state, (qc, kc, vc, lic, lfc))
    h_out = hs.swapaxes(0, 1).reshape(bsz, s, h, p)
    return h_out, state_f


def mlstm_forward(params: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    d_in, h, p = mlstm_dims(cfg)
    bsz, s, _ = x.shape
    q, k, v, z_part, log_i, log_f, _ = _mlstm_qkv_gates(params, x, cfg)
    h_out, _ = _mlstm_chunked(q, k, v, log_i, log_f, cfg.ssm_chunk)
    y = h_out.reshape(bsz, s, d_in)
    y = rms_norm_simple(y, params["head_norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z_part)
    return y @ params["w_down"].astype(x.dtype)


def init_mlstm_cache(cfg: ArchConfig, batch: int, dtype) -> Params:
    d_in, h, p = mlstm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, d_in), dtype),
        "c": jnp.zeros((batch, h, p, p), jnp.float32),
        "n": jnp.zeros((batch, h, p), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_decode_step(
    params: Params, x: jax.Array, cfg: ArchConfig, cache: Params
) -> tuple[jax.Array, Params]:
    """x: [B, 1, d]. O(1) per token."""
    d_in, h, p = mlstm_dims(cfg)
    bsz = x.shape[0]
    up = x @ params["w_up"].astype(x.dtype)
    x_part, z_part = up[..., :d_in], up[..., d_in:]
    hist = jnp.concatenate([cache["conv"], x_part], axis=1)
    w = params["conv_w"].astype(x.dtype)
    x_conv = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", hist, w) + params["conv_b"].astype(x.dtype)
    )
    q = (x_conv @ params["wq"].astype(x.dtype)).reshape(bsz, h, p)
    k = (x_conv @ params["wk"].astype(x.dtype)).reshape(bsz, h, p) / np.sqrt(p)
    v = (x_part[:, 0] @ params["wv"].astype(x.dtype)).reshape(bsz, h, p)
    if_pre = (
        x_conv @ params["w_if"].astype(x.dtype)
        + params["b_if"].astype(x.dtype)
    ).astype(jnp.float32)
    log_i, log_f = if_pre[..., :h], -jax.nn.softplus(-if_pre[..., h:])
    m_new = jnp.maximum(log_f + cache["m"], log_i)  # [B,H]
    f_s = jnp.exp(log_f + cache["m"] - m_new)[..., None]
    i_s = jnp.exp(log_i - m_new)[..., None]
    k32, v32, q32 = (t.astype(jnp.float32) for t in (k, v, q))
    c_new = f_s[..., None] * cache["c"] + i_s[..., None] * (
        k32[..., :, None] * v32[..., None, :]
    )
    n_new = f_s * cache["n"] + i_s * k32
    num = jnp.einsum("bhp,bhpq->bhq", q32, c_new)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhp,bhp->bh", q32, n_new)), jnp.exp(-m_new)
    )
    h_out = (num / den[..., None]).astype(x.dtype).reshape(bsz, 1, d_in)
    y = rms_norm_simple(h_out, params["head_norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z_part)
    y = y @ params["w_down"].astype(x.dtype)
    return y, {
        "conv": hist[:, 1:], "c": c_new, "n": n_new, "m": m_new,
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key: jax.Array, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    hidden = int(round(4.0 / 3.0 * d))
    ks = jax.random.split(key, 5)
    dt = pdtype(cfg)
    return {
        "conv_w": jax.random.normal(
            ks[0], (cfg.ssm_conv_width, d), dt
        ) / np.sqrt(cfg.ssm_conv_width),
        "conv_b": jnp.zeros((d,), dt),
        # gate input projections: z, i, f, o stacked
        "w_gates": jax.random.normal(ks[1], (d, 4 * d), dt) / np.sqrt(d),
        "b_gates": jnp.concatenate(
            [jnp.zeros((2 * d,)), jnp.full((d,), 3.0), jnp.zeros((d,))]
        ).astype(dt),
        # block-diagonal recurrent weights per head: [4, H, Dh, Dh]
        "r_gates": jax.random.normal(ks[2], (4, h, dh, dh), dt) / np.sqrt(dh),
        "head_norm": jnp.ones((d,), dt),
        "w_up": jax.random.normal(ks[3], (d, 2 * hidden), dt) / np.sqrt(d),
        "w_down": jax.random.normal(ks[4], (hidden, d), dt) / np.sqrt(hidden),
    }


def _slstm_cell(params: Params, cfg: ArchConfig, x_t, x_conv_t, state):
    """One sLSTM step. x_t, x_conv_t: [B, d]."""
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    c, n, hid, m = state  # each [B, d] except m [B, d]
    ct = x_t.dtype
    wg = params["w_gates"].astype(ct)
    bg = params["b_gates"].astype(ct)
    # recurrent block-diagonal contribution from previous hidden state
    hid_h = hid.reshape(-1, h, dh)
    rec = jnp.einsum(
        "bhp,ghpq->gbhq", hid_h.astype(ct), params["r_gates"].astype(ct)
    ).reshape(4, -1, d)
    # z/o read the raw input; i/f read the conv-smoothed input (per paper)
    z_pre = x_t @ wg[:, :d] + bg[:d] + rec[0]
    i_pre = x_conv_t @ wg[:, d : 2 * d] + bg[d : 2 * d] + rec[1]
    f_pre = x_conv_t @ wg[:, 2 * d : 3 * d] + bg[2 * d : 3 * d] + rec[2]
    o_pre = x_t @ wg[:, 3 * d :] + bg[3 * d :] + rec[3]
    z = jnp.tanh(z_pre.astype(jnp.float32))
    log_i = i_pre.astype(jnp.float32)
    log_f = -jax.nn.softplus(-f_pre.astype(jnp.float32))
    o = jax.nn.sigmoid(o_pre.astype(jnp.float32))
    m_new = jnp.maximum(log_f + m, log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = f_s * n + i_s
    h_tilde = c_new / jnp.maximum(n_new, 1.0)
    hid_new = o * h_tilde
    return (c_new, n_new, hid_new.astype(jnp.float32), m_new), hid_new


def slstm_forward(params: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    bsz, s, d = x.shape
    x_conv = _causal_conv(
        x, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype)
    )
    state = init_slstm_state(cfg, bsz)

    def body(st, inp):
        x_t, xc_t = inp
        st, hid = _slstm_cell(params, cfg, x_t, xc_t, st)
        return st, hid

    _, hs = jax.lax.scan(
        body, state, (x.swapaxes(0, 1), x_conv.swapaxes(0, 1))
    )
    y = hs.swapaxes(0, 1).astype(x.dtype)  # [B,S,d]
    y = rms_norm_simple(y, params["head_norm"], cfg.norm_eps)
    # GeGLU up/down projection (proj factor 4/3)
    up = y @ params["w_up"].astype(x.dtype)
    half = up.shape[-1] // 2
    y = jax.nn.gelu(up[..., :half]) * up[..., half:]
    return y @ params["w_down"].astype(x.dtype)


def init_slstm_state(cfg: ArchConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, z, z, jnp.full((batch, d), -1e30, jnp.float32))


def init_slstm_cache(cfg: ArchConfig, batch: int, dtype) -> Params:
    c, n, hid, m = init_slstm_state(cfg, batch)
    return {
        "conv": jnp.zeros(
            (batch, cfg.ssm_conv_width - 1, cfg.d_model), dtype
        ),
        "c": c, "n": n, "h": hid, "m": m,
    }


def slstm_decode_step(
    params: Params, x: jax.Array, cfg: ArchConfig, cache: Params
) -> tuple[jax.Array, Params]:
    bsz = x.shape[0]
    hist = jnp.concatenate([cache["conv"], x], axis=1)
    w = params["conv_w"].astype(x.dtype)
    xc_t = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", hist, w) + params["conv_b"].astype(x.dtype)
    )
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    state, hid = _slstm_cell(params, cfg, x[:, 0], xc_t, state)
    y = hid[:, None, :].astype(x.dtype)
    y = rms_norm_simple(y, params["head_norm"], cfg.norm_eps)
    up = y @ params["w_up"].astype(x.dtype)
    half = up.shape[-1] // 2
    y = jax.nn.gelu(up[..., :half]) * up[..., half:]
    y = y @ params["w_down"].astype(x.dtype)
    c, n, hid_f, m = state
    return y, {"conv": hist[:, 1:], "c": c, "n": n, "h": hid_f, "m": m}
