"""Model definitions for all assigned architecture families."""
from .transformer import Model, get_model  # noqa: F401
