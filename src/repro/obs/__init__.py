"""Observability layer: dispatch traces, Perfetto timelines, serve metrics.

The VM's scheduling decisions are the whole ballgame for throughput —
which block ran, how many lanes rode along, how much SIMD capacity was
wasted — yet by default only post-hoc scalars survive a run.  This
package turns the dispatch stream into first-class data:

* :mod:`trace`     — the typed :class:`~repro.obs.trace.DispatchTrace`
  drained from the VM's on-device ring buffer (``VMConfig.trace=``);
* :mod:`timeline`  — Chrome/Perfetto trace-event JSON export;
* :mod:`blockprof` — per-block profiles (dispatch counts, mean residents,
  wasted-slot attribution), the block-frequency input for trace-driven
  superblock formation;
* :mod:`metrics`   — a counter/gauge/histogram registry with Prometheus
  text exposition, populated by the serve engine.

Everything here is strictly *observational*: enabling a trace never
changes outputs, step counts, or dispatch choices (property-tested).
"""
from . import blockprof, metrics, timeline, trace
from .blockprof import BlockProfile, block_profile, format_profile
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .timeline import to_perfetto, validate_perfetto, write_perfetto
from .trace import DEFAULT_TRACE_CAPACITY, DispatchTrace

__all__ = [
    "BlockProfile",
    "Counter",
    "DEFAULT_TRACE_CAPACITY",
    "DispatchTrace",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "block_profile",
    "blockprof",
    "format_profile",
    "metrics",
    "timeline",
    "to_perfetto",
    "trace",
    "validate_perfetto",
    "write_perfetto",
]
