"""Typed dispatch traces drained from the VM's on-device ring buffer.

With ``VMConfig.trace=`` set, the VM loop carries a fixed-capacity ring
buffer of per-dispatch records (see ``pc_vm``): the chosen block id, the
per-block resident histogram, active/live/quarantined lane counts, the
occupied-tile capacity, and compaction/fault markers.  Recording is
strictly *write-only* with respect to the scheduler — no traced value
ever feeds back into ``cond``, ``_pick_block`` or a block body — so a
traced run is bit-exact with an untraced one.

This module is the host side: :func:`drain` unwraps the ring order into
a :class:`DispatchTrace` of plain ``numpy`` arrays (oldest event first),
with overflow accounted explicitly (``dropped`` oldest events when the
run outlived the capacity).  It deliberately imports no jax so the obs
package stays importable anywhere.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import numpy as np

#: Ring-buffer capacity used for ``trace=True`` (events).  Each event
#: costs ``8 + num_blocks`` i32 slots on device, so the default is a few
#: hundred KB for typical programs — raise it (``trace=65536``) for long
#: runs where the tail matters.
DEFAULT_TRACE_CAPACITY = 4096

#: The ``block`` value recorded for a ``schedule="sweep"`` loop iteration
#: (a sweep runs *every* resident block once; there is no single chosen
#: block to name).
SWEEP_BLOCK = -1


def resolve_capacity(trace: Any) -> Optional[int]:
    """Normalize a ``VMConfig.trace`` value to a capacity (or ``None``).

    ``None``/``False`` disable tracing; ``True`` selects
    :data:`DEFAULT_TRACE_CAPACITY`; an int >= 1 is the capacity in
    events.  Anything else raises.
    """
    if trace is None or trace is False:
        return None
    if trace is True:
        return DEFAULT_TRACE_CAPACITY
    cap = int(trace)
    if cap < 1:
        raise ValueError(
            f"trace must be None/False, True, or a capacity >= 1; got "
            f"{trace!r}"
        )
    return cap


@dataclass(frozen=True)
class DispatchTrace:
    """One VM run's dispatch stream, oldest event first (host numpy).

    All per-event arrays share length ``len(self)``; when the run
    outlived the ring capacity only the newest ``capacity`` events
    survive and ``dropped`` counts the lost oldest ones.  ``steps`` holds
    each event's global dispatch ordinal, so traces drained mid-run (or
    across ``Stepper`` segments) line up on an absolute axis.
    """

    schedule: str
    num_blocks: int
    batch_size: int
    capacity: int
    #: Total dispatches the run recorded (>= len(self) on overflow).
    total_dispatches: int
    #: Oldest events lost to ring overflow (total_dispatches - len).
    dropped: int
    #: [N] global dispatch ordinal of each event (0-based).
    steps: np.ndarray
    #: [N] chosen block id; :data:`SWEEP_BLOCK` for "sweep" iterations.
    block: np.ndarray
    #: [N, num_blocks] live residents per block *before* the dispatch.
    resident: np.ndarray
    #: [N] lanes the dispatch actually touched (residents of `block`).
    active: np.ndarray
    #: [N] live (dispatchable) lanes before the dispatch.
    live: np.ndarray
    #: [N] quarantined lanes before the dispatch.
    quarantined: np.ndarray
    #: [N] capacity of the SIMD tiles holding >= 1 dispatched lane.
    tile_capacity: np.ndarray
    #: [N] bool: lane compaction ran at the end of this iteration.
    compacted: np.ndarray
    #: [N] total faulted lanes *after* the dispatch.
    faults: np.ndarray

    def __len__(self) -> int:
        return int(self.block.shape[0])

    @property
    def occupancy(self) -> np.ndarray:
        """[N] per-dispatch tile occupancy (active / occupied-tile cap)."""
        cap = self.tile_capacity.astype(np.float64)
        return np.divide(
            self.active.astype(np.float64), cap,
            out=np.zeros_like(cap), where=cap > 0,
        )

    @property
    def fault_events(self) -> np.ndarray:
        """[N] newly-faulted lane count at each event (diff of faults)."""
        if len(self) == 0:
            return np.zeros((0,), np.int64)
        prev = np.concatenate(([0], self.faults[:-1]))
        return np.maximum(self.faults - prev, 0)


def drain(
    buffers: dict[str, Any],
    *,
    total: int,
    schedule: str,
    num_blocks: int,
    batch_size: int,
) -> DispatchTrace:
    """Ring buffers (+ total event count) -> a :class:`DispatchTrace`.

    ``buffers`` holds the device ring arrays (any array-likes; converted
    to host numpy here); ``total`` is the VM's global step counter — one
    event was written per loop iteration, so it is also the event count.
    """
    block = np.asarray(buffers["block"])
    cap = int(block.shape[0])
    n = min(int(total), cap)
    if total > cap:
        # Oldest surviving event has ordinal total - cap; the ring index
        # of ordinal k is k % cap.
        ordinals = np.arange(total - cap, total)
        idx = ordinals % cap
    else:
        ordinals = np.arange(n)
        idx = ordinals

    def take(name: str) -> np.ndarray:
        return np.asarray(buffers[name])[idx]

    return DispatchTrace(
        schedule=schedule,
        num_blocks=num_blocks,
        batch_size=batch_size,
        capacity=cap,
        total_dispatches=int(total),
        dropped=max(int(total) - cap, 0),
        steps=ordinals.astype(np.int64),
        block=take("block").astype(np.int64),
        resident=take("resident").astype(np.int64),
        active=take("active").astype(np.int64),
        live=take("live").astype(np.int64),
        quarantined=take("quarantined").astype(np.int64),
        tile_capacity=take("tile").astype(np.int64),
        compacted=take("compacted").astype(bool),
        faults=take("faults").astype(np.int64),
    )
