"""Per-block profiles from a :class:`DispatchTrace`.

A :class:`BlockProfile` attributes the run's dispatch stream to blocks:
how often each block ran, how many lanes rode along on average, and how
much SIMD capacity was *wasted* (occupied-tile slots that carried no
active lane — the quantity compaction and better schedules reclaim).

``to_json()`` is the **block-frequency profile format** that the
trace-driven superblock formation pass (ROADMAP item 5) consumes:
per-block dispatch counts plus the observed block->block transition
counts, which together say which block chains are hot enough to fuse.
The format is versioned so saved profiles stay readable as the pass
lands.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass

import numpy as np

from .trace import SWEEP_BLOCK, DispatchTrace

#: Version tag of the block-frequency profile JSON format.  Version 2
#: added the exact ``total_active`` integer per block (version 1 only
#: stored the rounded ``mean_residents``, so ``load()`` reconstructs the
#: totals approximately for old artifacts).
PROFILE_VERSION = 2


@dataclass(frozen=True)
class BlockProfile:
    """Dispatch-stream aggregates, one row per block (host numpy)."""

    schedule: str
    num_blocks: int
    batch_size: int
    #: Events this profile aggregates (post ring-overflow).
    events: int
    #: Oldest events lost to ring overflow before aggregation.
    dropped: int
    #: [B] dispatches of each block (sweep iterations count no block).
    dispatches: np.ndarray
    #: [B] total active lanes over those dispatches.
    total_active: np.ndarray
    #: [B] total occupied-tile capacity over those dispatches.
    total_tile_capacity: np.ndarray
    #: [B, B] observed dispatch transitions: t[i, j] = times block j was
    #: dispatched immediately after block i (sweep iterations excluded).
    transitions: np.ndarray

    @property
    def mean_residents(self) -> np.ndarray:
        """[B] mean active lanes per dispatch of each block."""
        d = self.dispatches.astype(np.float64)
        return np.divide(
            self.total_active.astype(np.float64), d,
            out=np.zeros_like(d), where=d > 0,
        )

    @property
    def wasted_slots(self) -> np.ndarray:
        """[B] occupied-tile lane slots that carried no active lane."""
        return self.total_tile_capacity - self.total_active

    @property
    def occupancy(self) -> np.ndarray:
        """[B] per-block tile occupancy (active / occupied capacity)."""
        cap = self.total_tile_capacity.astype(np.float64)
        return np.divide(
            self.total_active.astype(np.float64), cap,
            out=np.zeros_like(cap), where=cap > 0,
        )

    def to_json(self) -> dict:
        """The block-frequency profile (superblock-pass input format)."""
        mean_res = self.mean_residents
        occ = self.occupancy
        return {
            "version": PROFILE_VERSION,
            "schedule": self.schedule,
            "num_blocks": self.num_blocks,
            "batch_size": self.batch_size,
            "events": self.events,
            "dropped": self.dropped,
            "blocks": [
                {
                    "block": b,
                    "dispatches": int(self.dispatches[b]),
                    "total_active": int(self.total_active[b]),
                    "mean_residents": round(float(mean_res[b]), 6),
                    "occupancy": round(float(occ[b]), 6),
                    "wasted_slots": int(self.wasted_slots[b]),
                }
                for b in range(self.num_blocks)
            ],
            "transitions": [
                {"src": int(i), "dst": int(j),
                 "count": int(self.transitions[i, j])}
                for i, j in zip(*np.nonzero(self.transitions))
            ],
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, allow_nan=False)

    @classmethod
    def from_json(cls, data: dict) -> "BlockProfile":
        """Inverse of :meth:`to_json`, with a schema-version check.

        Accepts the current format and version 1 (which lacked the exact
        ``total_active`` integer; it is reconstructed from the rounded
        ``mean_residents``, so v1 round-trips are approximate).  Rejects
        missing or newer versions so a profile written by a later format
        never silently misguides the PGO pipeline.
        """
        version = data.get("version")
        if version is None:
            raise ValueError(
                "block profile JSON has no 'version' field "
                "(not a saved BlockProfile?)"
            )
        if not 1 <= int(version) <= PROFILE_VERSION:
            raise ValueError(
                f"unsupported block profile version {version} "
                f"(this build reads versions 1..{PROFILE_VERSION})"
            )
        nb = int(data["num_blocks"])
        dispatches = np.zeros((nb,), np.int64)
        total_active = np.zeros((nb,), np.int64)
        total_tile = np.zeros((nb,), np.int64)
        transitions = np.zeros((nb, nb), np.int64)
        for row in data["blocks"]:
            b = int(row["block"])
            dispatches[b] = int(row["dispatches"])
            if "total_active" in row:
                total_active[b] = int(row["total_active"])
            else:  # v1: reconstruct from the rounded per-dispatch mean
                total_active[b] = round(
                    float(row["mean_residents"]) * dispatches[b]
                )
            total_tile[b] = total_active[b] + int(row["wasted_slots"])
        for t in data["transitions"]:
            transitions[int(t["src"]), int(t["dst"])] = int(t["count"])
        return cls(
            schedule=str(data["schedule"]),
            num_blocks=nb,
            batch_size=int(data["batch_size"]),
            events=int(data["events"]),
            dropped=int(data["dropped"]),
            dispatches=dispatches,
            total_active=total_active,
            total_tile_capacity=total_tile,
            transitions=transitions,
        )

    @classmethod
    def load(cls, path: str) -> "BlockProfile":
        """Read a profile saved by :meth:`save` (see :meth:`from_json`)."""
        with open(path) as f:
            return cls.from_json(json.load(f))

    def digest(self) -> str:
        """Stable content hash (the executor-cache key component)."""
        payload = json.dumps(self.to_json(), sort_keys=True,
                             separators=(",", ":"), allow_nan=False)
        return hashlib.sha256(payload.encode()).hexdigest()[:16]


def block_profile(trace: DispatchTrace) -> BlockProfile:
    """Aggregate a :class:`DispatchTrace` into a :class:`BlockProfile`."""
    nb = trace.num_blocks
    dispatches = np.zeros((nb,), np.int64)
    total_active = np.zeros((nb,), np.int64)
    total_tile = np.zeros((nb,), np.int64)
    transitions = np.zeros((nb, nb), np.int64)
    scheduled = trace.block != SWEEP_BLOCK
    blocks = trace.block[scheduled]
    np.add.at(dispatches, blocks, 1)
    np.add.at(total_active, blocks, trace.active[scheduled])
    np.add.at(total_tile, blocks, trace.tile_capacity[scheduled])
    if len(blocks) > 1:
        np.add.at(transitions, (blocks[:-1], blocks[1:]), 1)
    return BlockProfile(
        schedule=trace.schedule,
        num_blocks=nb,
        batch_size=trace.batch_size,
        events=len(trace),
        dropped=trace.dropped,
        dispatches=dispatches,
        total_active=total_active,
        total_tile_capacity=total_tile,
        transitions=transitions,
    )


def format_profile(prof: BlockProfile) -> str:
    """Human-readable block-profile table (the vmtrace CLI summary)."""
    lines = [
        f"block profile: schedule={prof.schedule} "
        f"batch={prof.batch_size} events={prof.events}"
        + (f" (dropped {prof.dropped} oldest)" if prof.dropped else ""),
        f"{'block':>6} {'dispatches':>10} {'mean_res':>9} "
        f"{'occupancy':>9} {'wasted':>8}",
    ]
    mean_res = prof.mean_residents
    occ = prof.occupancy
    order = np.argsort(-prof.dispatches, kind="stable")
    for b in order:
        if prof.dispatches[b] == 0:
            continue
        lines.append(
            f"{int(b):>6} {int(prof.dispatches[b]):>10} "
            f"{float(mean_res[b]):>9.2f} {float(occ[b]):>9.3f} "
            f"{int(prof.wasted_slots[b]):>8}"
        )
    hot = [
        (int(i), int(j), int(prof.transitions[i, j]))
        for i, j in zip(*np.nonzero(prof.transitions))
    ]
    hot.sort(key=lambda t: -t[2])
    if hot:
        lines.append("hot transitions:")
        for i, j, c in hot[:8]:
            lines.append(f"  block{i} -> block{j}: {c}")
    return "\n".join(lines)


__all__ = [
    "PROFILE_VERSION",
    "BlockProfile",
    "block_profile",
    "format_profile",
]
