"""Minimal counter/gauge/histogram registry with Prometheus exposition.

The serve engine populates a :class:`MetricsRegistry` as it runs
(admissions, retirements by status, queue depth, segment latency, token
throughput — see ``repro/serve/engine.py``) and ``serve_bench
--metrics-out`` dumps it in the Prometheus text exposition format
(version 0.0.4), so a scrape target or offline diff tooling can consume
serve runs without bespoke parsing.

Deliberately dependency-free and tiny: label support is a dict per
instrument call, histograms use fixed upper-bound buckets (cumulative,
with ``+Inf``), and everything is process-local — this is bench/serving
introspection, not a distributed metrics pipeline.
"""
from __future__ import annotations

from typing import Optional, Sequence

#: Default histogram buckets (seconds), tuned for segment/request
#: latencies on CPU test rigs through real accelerator serving.
DEFAULT_BUCKETS = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0,
    2.5, 5.0, 10.0, 30.0, 60.0,
)

_TYPES = ("counter", "gauge", "histogram")


def _validate_name(name: str) -> str:
    if not name or not all(c.isalnum() or c in "_:" for c in name):
        raise ValueError(f"invalid metric name {name!r}")
    if name[0].isdigit():
        raise ValueError(f"metric name must not start with a digit: {name!r}")
    return name


def _label_key(labels: dict[str, str]) -> tuple[tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render_labels(key: tuple[tuple[str, str], ...]) -> str:
    if not key:
        return ""
    def esc(v: str) -> str:
        return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")
    return "{" + ",".join(f'{k}="{esc(v)}"' for k, v in key) + "}"


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class Counter:
    """Monotonically increasing value, keyed by a label set."""

    type = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = _validate_name(name)
        self.help = help
        self._values: dict[tuple, float] = {}

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a Gauge")
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels: str) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def samples(self) -> list[tuple[str, str, float]]:
        return [
            (self.name, _render_labels(k), v)
            for k, v in sorted(self._values.items())
        ] or [(self.name, "", 0.0)]


class Gauge:
    """Point-in-time value (queue depth, active lanes), set/inc/dec."""

    type = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = _validate_name(name)
        self.help = help
        self._values: dict[tuple, float] = {}

    def set(self, value: float, **labels: str) -> None:
        self._values[_label_key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels: str) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels: str) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def samples(self) -> list[tuple[str, str, float]]:
        return [
            (self.name, _render_labels(k), v)
            for k, v in sorted(self._values.items())
        ] or [(self.name, "", 0.0)]


class Histogram:
    """Cumulative-bucket histogram with sum/count, keyed by label set.

    ``observe()`` also retains raw observations so tests and the serve
    engine can compute exact percentiles (``percentile``) without
    bucket-interpolation error; the exposition format stays standard
    Prometheus (``_bucket``/``_sum``/``_count`` with ``le`` labels).
    """

    type = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.name = _validate_name(name)
        self.help = help
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs:
            raise ValueError("histogram needs at least one bucket bound")
        self.buckets = bs
        self._counts: dict[tuple, list[int]] = {}
        self._sums: dict[tuple, float] = {}
        self._raw: dict[tuple, list[float]] = {}

    def observe(self, value: float, **labels: str) -> None:
        key = _label_key(labels)
        counts = self._counts.setdefault(key, [0] * (len(self.buckets) + 1))
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                counts[i] += 1
                break
        else:
            counts[-1] += 1  # +Inf bucket
        self._sums[key] = self._sums.get(key, 0.0) + float(value)
        self._raw.setdefault(key, []).append(float(value))

    def count(self, **labels: str) -> int:
        return sum(self._counts.get(_label_key(labels), []))

    def sum(self, **labels: str) -> float:
        return self._sums.get(_label_key(labels), 0.0)

    def percentile(self, q: float, **labels: str) -> float:
        """Exact q-th percentile (0-100) of raw observations, nan if none."""
        raw = self._raw.get(_label_key(labels))
        if not raw:
            return float("nan")
        xs = sorted(raw)
        if len(xs) == 1:
            return xs[0]
        pos = (q / 100.0) * (len(xs) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(xs) - 1)
        frac = pos - lo
        return xs[lo] * (1 - frac) + xs[hi] * frac

    def samples(self) -> list[tuple[str, str, float]]:
        out: list[tuple[str, str, float]] = []
        for key in sorted(self._counts):
            counts = self._counts[key]
            cum = 0
            for ub, c in zip(self.buckets, counts[:-1]):
                cum += c
                out.append((
                    f"{self.name}_bucket",
                    _render_labels(key + (("le", _fmt(ub)),)),
                    float(cum),
                ))
            cum += counts[-1]
            out.append((
                f"{self.name}_bucket",
                _render_labels(key + (("le", "+Inf"),)),
                float(cum),
            ))
            out.append((f"{self.name}_sum", _render_labels(key),
                        self._sums[key]))
            out.append((f"{self.name}_count", _render_labels(key),
                        float(cum)))
        if not out:
            out = [
                (f"{self.name}_bucket", '{le="+Inf"}', 0.0),
                (f"{self.name}_sum", "", 0.0),
                (f"{self.name}_count", "", 0.0),
            ]
        return out


class MetricsRegistry:
    """A named set of instruments with Prometheus text exposition.

    ``counter``/``gauge``/``histogram`` get-or-create by name (re-asking
    for an existing name returns the same instrument; a type clash
    raises), so populating code never needs registration boilerplate.
    """

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _get(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.type}"
                )
            return m
        m = cls(name, help, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        kw = {} if buckets is None else {"buckets": buckets}
        return self._get(Histogram, name, help, **kw)

    def get(self, name: str):
        """The instrument registered under ``name``, or ``None``."""
        return self._metrics.get(name)

    def render_prometheus(self) -> str:
        """The registry in Prometheus text exposition format 0.0.4."""
        lines: list[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.type}")
            for sample_name, labels, value in m.samples():
                lines.append(f"{sample_name}{labels} {_fmt(value)}")
        return "\n".join(lines) + "\n"


__all__ = [
    "DEFAULT_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
]
