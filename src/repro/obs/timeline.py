"""Chrome/Perfetto trace-event JSON export for :class:`DispatchTrace`.

The emitted object follows the Trace Event Format (the ``traceEvents``
JSON array consumed by ``chrome://tracing`` and https://ui.perfetto.dev):

* one *thread track per block* (tid = block id) carrying a complete
  ``"X"`` duration event per dispatch of that block, whose ``args`` hold
  the resident/active counts;
* ``"C"`` counter tracks for live lanes, active lanes, quarantined
  lanes, faulted lanes and per-dispatch tile occupancy;
* ``"i"`` instant events marking lane compactions and new lane faults.

Time is synthetic: one dispatch = :data:`STEP_US` microseconds on the
trace clock, anchored at the event's *global* dispatch ordinal — wall
time per dispatch is not observable from inside one ``lax.while_loop``,
and scheduling analysis wants the dispatch axis anyway.  Traces drained
from different segments of the same run therefore line up exactly.
"""
from __future__ import annotations

import json
from typing import Optional, Union

from .trace import SWEEP_BLOCK, DispatchTrace

#: Synthetic trace-clock width of one dispatch, microseconds.
STEP_US = 10

_PID = 1  # one process track: the VM
_COUNTER_TID = 10_000  # counter rows sort after the per-block tracks


def _block_name(trace: DispatchTrace, b: int) -> str:
    return "sweep(all blocks)" if b == SWEEP_BLOCK else f"block{b}"


def to_perfetto(trace: DispatchTrace) -> dict:
    """Render a :class:`DispatchTrace` to a Trace Event Format dict."""
    ev: list[dict] = [
        {
            "name": "process_name", "ph": "M", "pid": _PID,
            "args": {"name": f"pc VM ({trace.schedule})"},
        },
    ]
    seen_blocks = sorted({int(b) for b in trace.block})
    for b in seen_blocks:
        tid = b if b != SWEEP_BLOCK else _COUNTER_TID - 1
        ev.append({
            "name": "thread_name", "ph": "M", "pid": _PID, "tid": tid,
            "args": {"name": _block_name(trace, b)},
        })
    occ = trace.occupancy
    new_faults = trace.fault_events
    for i in range(len(trace)):
        b = int(trace.block[i])
        ts = int(trace.steps[i]) * STEP_US
        tid = b if b != SWEEP_BLOCK else _COUNTER_TID - 1
        ev.append({
            "name": _block_name(trace, b), "ph": "X", "pid": _PID,
            "tid": tid, "ts": ts, "dur": STEP_US,
            "args": {
                "step": int(trace.steps[i]),
                "active": int(trace.active[i]),
                "live": int(trace.live[i]),
                "tile_capacity": int(trace.tile_capacity[i]),
                "occupancy": round(float(occ[i]), 4),
                "residents": {
                    f"block{j}": int(c)
                    for j, c in enumerate(trace.resident[i]) if c
                },
            },
        })
        ev.append({
            "name": "lanes", "ph": "C", "pid": _PID,
            "tid": _COUNTER_TID, "ts": ts,
            "args": {
                "live": int(trace.live[i]),
                "active": int(trace.active[i]),
                "quarantined": int(trace.quarantined[i]),
                "faulted": int(trace.faults[i]),
            },
        })
        ev.append({
            "name": "tile_occupancy", "ph": "C", "pid": _PID,
            "tid": _COUNTER_TID + 1, "ts": ts,
            "args": {"occupancy": round(float(occ[i]), 4)},
        })
        if bool(trace.compacted[i]):
            ev.append({
                "name": "compaction", "ph": "i", "pid": _PID,
                "tid": tid, "ts": ts + STEP_US, "s": "p",
            })
        if int(new_faults[i]) > 0:
            ev.append({
                "name": "lane_fault", "ph": "i", "pid": _PID,
                "tid": tid, "ts": ts, "s": "p",
                "args": {"new_faults": int(new_faults[i])},
            })
    return {
        "traceEvents": ev,
        "displayTimeUnit": "ms",
        "otherData": {
            "schedule": trace.schedule,
            "num_blocks": trace.num_blocks,
            "batch_size": trace.batch_size,
            "total_dispatches": trace.total_dispatches,
            "dropped": trace.dropped,
        },
    }


def write_perfetto(path: str, trace: DispatchTrace) -> dict:
    """Write the Perfetto JSON for ``trace`` to ``path``; returns it."""
    obj = to_perfetto(trace)
    with open(path, "w") as f:
        json.dump(obj, f, allow_nan=False)
    return obj


def validate_perfetto(obj: Union[dict, str]) -> int:
    """Schema-check a Trace Event Format object (or a path to one).

    Raises ``ValueError`` on the first violation; returns the event
    count.  This is the CI gate for emitted trace artifacts: every event
    must carry the phase-appropriate required fields, and duration /
    counter events must have integer timestamps.
    """
    if isinstance(obj, str):
        with open(obj) as f:
            obj = json.load(f)
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("not a Trace Event Format object "
                         "(missing 'traceEvents')")
    events = obj["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("'traceEvents' must be a non-empty list")
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            raise ValueError(f"event {i}: not an object")
        for k in ("name", "ph", "pid"):
            if k not in e:
                raise ValueError(f"event {i}: missing required field {k!r}")
        ph = e["ph"]
        if ph not in ("X", "C", "i", "M", "B", "E"):
            raise ValueError(f"event {i}: unknown phase {ph!r}")
        if ph in ("X", "C", "i"):
            if not isinstance(e.get("ts"), int):
                raise ValueError(f"event {i}: phase {ph!r} needs int 'ts'")
        if ph == "X" and not isinstance(e.get("dur"), int):
            raise ValueError(f"event {i}: phase 'X' needs int 'dur'")
        if ph == "C" and not isinstance(e.get("args"), dict):
            raise ValueError(f"event {i}: phase 'C' needs 'args' counters")
    return len(events)


def segment_tracks(
    traces: list[DispatchTrace], path: Optional[str] = None
) -> dict:
    """Merge traces drained from successive segments into one timeline.

    Traces share the global dispatch ordinal axis, so merging is pure
    event concatenation (metadata events deduplicated by (name, tid)).
    """
    if not traces:
        raise ValueError("segment_tracks needs at least one trace")
    merged = to_perfetto(traces[0])
    seen_meta = {
        (e["name"], e.get("tid")) for e in merged["traceEvents"]
        if e["ph"] == "M"
    }
    for t in traces[1:]:
        for e in to_perfetto(t)["traceEvents"]:
            if e["ph"] == "M":
                key = (e["name"], e.get("tid"))
                if key in seen_meta:
                    continue
                seen_meta.add(key)
            merged["traceEvents"].append(e)
    merged["otherData"]["total_dispatches"] = max(
        t.total_dispatches for t in traces
    )
    merged["otherData"]["segments"] = len(traces)
    if path is not None:
        with open(path, "w") as f:
            json.dump(merged, f, allow_nan=False)
    return merged


__all__ = [
    "STEP_US",
    "segment_tracks",
    "to_perfetto",
    "validate_perfetto",
    "write_perfetto",
]
