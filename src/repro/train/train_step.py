"""The jittable train step: loss -> grad -> clip -> AdamW, with optional
microbatch gradient accumulation (``lax.scan`` over microbatches keeps one
live activation set, trading steps for memory) and remat policies.

The same function lowers for the production mesh in the dry-run: all
distribution is expressed through in/out shardings at the ``jax.jit``
boundary (see ``repro.launch.sharding``), never inside the step.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import Model

from . import optimizer as opt

PyTree = Any


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: str = "dots"  # none | full | dots
    opt: opt.OptimizerConfig = opt.OptimizerConfig()


def _split_microbatches(batch: dict, n: int) -> dict:
    """[B, ...] -> [n, B/n, ...] along the leading (batch) axis."""
    def re(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by {n} microbatches"
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(re, batch)


def make_loss_fn(model: Model, cfg: TrainConfig) -> Callable:
    def loss_fn(params: PyTree, batch: dict):
        return model.loss(params, batch, remat=cfg.remat)

    return loss_fn


def make_train_step(model: Model, cfg: TrainConfig) -> Callable:
    """Returns ``step(params, opt_state, batch) -> (params, opt_state,
    metrics)`` — pure and jittable."""
    loss_fn = make_loss_fn(model, cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params_master: PyTree, opt_state: PyTree, batch: dict):
        # bf16 weight streams: cast the matmul weights ONCE per step
        # (outside the microbatch loop) — FSDP all-gathers and per-layer
        # reads then move 2-byte tensors.  AdamW updates the f32 masters;
        # grads w.r.t. the bf16 copy equal grads w.r.t. the master (the
        # cast's transpose is a cast).
        params = model.cast_for_compute(params_master)
        if cfg.microbatches > 1:
            mb = _split_microbatches(batch, cfg.microbatches)

            def acc_body(carry, microbatch):
                gsum, lsum = carry
                (loss, aux), g = grad_fn(params, microbatch)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g
                )
                return (gsum, lsum + loss), aux

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), auxs = jax.lax.scan(acc_body, (g0, 0.0), mb)
            grads = jax.tree.map(lambda g: g / cfg.microbatches, gsum)
            loss = lsum / cfg.microbatches
            aux = jax.tree.map(lambda x: x[-1], auxs)
        else:
            (loss, aux), grads = grad_fn(params, batch)
        new_params, opt_state, metrics = opt.apply_updates(
            params_master, grads, opt_state, cfg.opt
        )
        metrics = dict(metrics, loss=loss, **aux)
        return new_params, opt_state, metrics

    return step


def make_eval_step(model: Model, cfg: TrainConfig) -> Callable:
    loss_fn = make_loss_fn(model, cfg)

    def step(params: PyTree, batch: dict):
        loss, aux = loss_fn(params, batch)
        return dict(aux, loss=loss)

    return step
