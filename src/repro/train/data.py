"""Deterministic, resumable synthetic data pipeline.

Batches are pure functions of ``(seed, step)`` via counter-based PRNG
(threefry fold-in), so the pipeline is:

* **resumable** — restart at step k reproduces exactly the batch stream a
  non-failed run would have seen (no state files needed beyond the step);
* **shardable** — each data-parallel host can slice its rows of the global
  batch by index with no coordination;
* **learnable** — token streams follow a fixed random-affine Markov chain,
  so small models show decreasing loss within a few hundred steps.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.transformer import Model


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    # Markov-chain structure: t_{i+1} = (a * t_i + b + eps) % vocab
    mult: int = 6_364_136_223_846_793_005 % 65_521
    noise_levels: int = 4


class SyntheticStream:
    """Deterministic batch source for a (model, shape) pair."""

    def __init__(self, model: Model, shape: ShapeSpec,
                 cfg: DataConfig = DataConfig()):
        self.model = model
        self.shape = shape
        self.cfg = cfg
        self._base_key = jax.random.PRNGKey(cfg.seed)
        self._jitted = jax.jit(self._make, static_argnums=())

    def _markov_tokens(self, key: jax.Array, b: int, s: int, vocab: int
                       ) -> jax.Array:
        k0, k1 = jax.random.split(key)
        t0 = jax.random.randint(k0, (b,), 0, vocab, jnp.int32)
        noise = jax.random.randint(
            k1, (b, s), 0, self.cfg.noise_levels, jnp.int32
        )

        def step(t, eps):
            nxt = (t * self.cfg.mult + 17 + eps) % vocab
            return nxt, nxt

        _, toks = jax.lax.scan(step, t0, noise.T)
        return toks.T  # [B, S]

    def _make(self, step: jax.Array) -> dict:
        mcfg: ArchConfig = self.model.cfg
        key = jax.random.fold_in(self._base_key, step)
        specs = self.model.input_specs(self.shape)
        out = {}
        for name, spec in specs.items():
            key, k = jax.random.split(key)
            if name in ("tokens", "labels"):
                b, s = (spec.shape if len(spec.shape) == 2
                        else (spec.shape[0], 1))
                out[name] = self._markov_tokens(k, b, s, mcfg.vocab_size
                                                ).reshape(spec.shape)
            elif name == "positions":
                base = jnp.broadcast_to(
                    jnp.arange(spec.shape[-1])[None, None], spec.shape
                )
                out[name] = base.astype(spec.dtype)
            elif name == "pos":
                out[name] = jnp.zeros(spec.shape, spec.dtype)
            elif jnp.issubdtype(spec.dtype, jnp.floating):
                out[name] = jax.random.normal(k, spec.shape, spec.dtype)
            else:
                out[name] = jnp.zeros(spec.shape, spec.dtype)
        return out

    def batch(self, step: int) -> dict:
        """The batch for global step ``step`` (pure; resume == replay)."""
        return self._jitted(jnp.asarray(step, jnp.int32))
