"""Atomic, resumable, reshardable checkpointing.

Layout::

    <dir>/step_00001200/manifest.json   # step, keys, shapes, dtypes, digest
    <dir>/step_00001200/arrays.npz      # flattened pytree payload

Guarantees:

* **Atomicity** — payload + manifest are written into a ``.tmp-<pid>``
  directory and ``os.rename``d into place; a crash mid-write leaves no
  half-valid checkpoint (rename is atomic on POSIX).
* **Validity** — the manifest carries a content digest; ``latest_step``
  skips checkpoints whose digest does not verify (torn writes, bit rot).
* **Elasticity** — arrays are stored in *logical* (unsharded) layout with
  the pytree structure, so a restart may use a different mesh shape /
  device count: the loader simply ``device_put``s onto whatever sharding
  the new topology prescribes.
* **Async** — ``save`` can run in a background thread, overlapping the
  host write with accelerator compute; the next save joins the previous.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def flatten_with_paths(tree: PyTree) -> dict[str, np.ndarray]:
    flat: dict[str, np.ndarray] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def _digest(flat: dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for k in sorted(flat):
        h.update(k.encode())
        h.update(np.ascontiguousarray(flat[k]).tobytes()[:65536])
        h.update(str(flat[k].shape).encode())
    return h.hexdigest()


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save

    def save(self, step: int, tree: PyTree, extra: Optional[dict] = None
             ) -> None:
        flat = flatten_with_paths(tree)  # host copy happens synchronously
        self.wait()  # join any in-flight save
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, extra or {})
            )
            self._thread.start()
        else:
            self._write(step, flat, extra or {})

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict, extra: dict) -> None:
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, f".tmp-{os.getpid()}-{name}")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "keys": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in flat.items()
            },
            "digest": _digest(flat),
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- load

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    continue
        return sorted(out)

    def _valid(self, step: int) -> bool:
        path = os.path.join(self.dir, f"step_{step:08d}")
        mpath = os.path.join(path, "manifest.json")
        apath = os.path.join(path, "arrays.npz")
        if not (os.path.exists(mpath) and os.path.exists(apath)):
            return False
        try:
            with open(mpath) as f:
                manifest = json.load(f)
            flat = dict(np.load(apath))
            return manifest["digest"] == _digest(flat)
        except Exception:
            return False

    def latest_step(self) -> Optional[int]:
        """Newest checkpoint that passes digest validation."""
        for s in reversed(self.all_steps()):
            if self._valid(s):
                return s
        return None

    def restore(self, step: int, like: PyTree,
                shardings: Optional[PyTree] = None) -> PyTree:
        """Restore into the structure of ``like``; if ``shardings`` is given
        (a pytree of jax.sharding.Sharding), arrays are placed directly onto
        the (possibly different) current topology — elastic restart."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        flat = dict(np.load(os.path.join(path, "arrays.npz")))
        leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        out_leaves = []
        shard_leaves = (
            jax.tree.leaves(shardings) if shardings is not None
            else [None] * len(leaves_like)
        )
        for (pth, leaf), shd in zip(leaves_like, shard_leaves):
            key = _SEP.join(_path_str(p) for p in pth)
            if key not in flat:
                raise KeyError(f"checkpoint missing {key!r}")
            arr = flat[key].astype(leaf.dtype)
            if arr.shape != leaf.shape:
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != {leaf.shape}"
                )
            if shd is not None:
                out_leaves.append(jax.device_put(arr, shd))
            else:
                out_leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), out_leaves
        )

    def manifest(self, step: int) -> dict:
        with open(os.path.join(
            self.dir, f"step_{step:08d}", "manifest.json"
        )) as f:
            return json.load(f)
