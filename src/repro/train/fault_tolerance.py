"""Fault tolerance: checkpoint/restart driver, straggler detection,
elastic resharding.

On a real 1000+-node fleet, failures arrive as (a) hard node loss — the
coordinator re-gangs the job on surviving pods, every process reloads the
latest valid checkpoint, and the data pipeline replays deterministically
from the restored step; (b) stragglers — persistently slow hosts detected
by per-step latency outliers and drained.  This module implements the
control-plane logic in a topology-agnostic way so it is exercised (and
tested) on CPU and carries unchanged to multi-host deployments.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from .checkpoint import Checkpointer

PyTree = Any


# ---------------------------------------------------------------------------
# Straggler detection
# ---------------------------------------------------------------------------


@dataclass
class StragglerPolicy:
    """EMA-based per-step latency monitor.

    ``observe`` returns True when the step latency exceeds
    ``threshold`` x the EMA — on a fleet this triggers draining the slow
    host (or, for synchronous-with-timeout collectives, dropping its
    contribution for the step).
    """

    threshold: float = 3.0
    decay: float = 0.9
    warmup: int = 5
    _ema: float = field(default=0.0, init=False)
    _n: int = field(default=0, init=False)
    flagged: list = field(default_factory=list, init=False)

    def observe(self, step: int, latency_s: float) -> bool:
        self._n += 1
        if self._n <= self.warmup:
            self._ema = (
                latency_s if self._n == 1
                else self.decay * self._ema + (1 - self.decay) * latency_s
            )
            return False
        is_straggler = latency_s > self.threshold * self._ema
        if is_straggler:
            self.flagged.append((step, latency_s, self._ema))
        else:
            self._ema = self.decay * self._ema + (1 - self.decay) * latency_s
        return is_straggler


# ---------------------------------------------------------------------------
# Elastic resharding
# ---------------------------------------------------------------------------


def reshard(tree: PyTree, shardings: PyTree) -> PyTree:
    """Move a pytree onto new shardings (mesh change on restart)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings
    )


# ---------------------------------------------------------------------------
# The restartable loop
# ---------------------------------------------------------------------------


@dataclass
class RunReport:
    final_step: int
    restarts: int
    losses: list
    straggler_events: int


class ResilientLoop:
    """Checkpoint/restart training driver.

    ``step_fn(state, step) -> (state, metrics)`` is the pure update;
    ``state`` is any pytree (params + opt state).  Failures raised by
    ``step_fn`` (or injected via ``failure_hook`` for tests) trigger a
    restore from the latest valid checkpoint; the deterministic data
    pipeline makes the replay exact.
    """

    def __init__(
        self,
        step_fn: Callable[[PyTree, int], tuple[PyTree, dict]],
        checkpointer: Checkpointer,
        save_every: int = 50,
        max_restarts: int = 10,
        straggler: Optional[StragglerPolicy] = None,
    ):
        self.step_fn = step_fn
        self.ckpt = checkpointer
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.straggler = straggler or StragglerPolicy()

    def run(
        self,
        state: PyTree,
        num_steps: int,
        failure_hook: Optional[Callable[[int], None]] = None,
        log_every: int = 0,
    ) -> tuple[PyTree, RunReport]:
        restarts = 0
        losses: list = []
        init_state = state
        start = 0
        # Resume if a valid checkpoint exists (crash recovery).
        latest = self.ckpt.latest_step()
        if latest is not None:
            state = self.ckpt.restore(latest, like=state)
            start = latest

        step = start
        while step < num_steps:
            try:
                if failure_hook is not None:
                    failure_hook(step)  # may raise (simulated node loss)
                t0 = time.monotonic()
                state, metrics = self.step_fn(state, step)
                if "loss" in metrics:
                    losses.append(float(metrics["loss"]))
                self.straggler.observe(step, time.monotonic() - t0)
                step += 1
                if step % self.save_every == 0 or step == num_steps:
                    self.ckpt.save(step, state)
                if log_every and step % log_every == 0:
                    loss = metrics.get("loss", float("nan"))
                    print(f"  step {step:6d}  loss {float(loss):.4f}")
            except KeyboardInterrupt:
                raise
            except Exception:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    state, step = init_state, 0
                else:
                    state = self.ckpt.restore(latest, like=state)
                    step = latest
        self.ckpt.wait()
        return state, RunReport(
            final_step=step,
            restarts=restarts,
            losses=losses,
            straggler_events=len(self.straggler.flagged),
        )
