"""AdamW with cosine schedule, global-norm clipping and optional int8
gradient compression with error feedback (for the cross-pod all-reduce).

Self-contained (no optax dependency): state is a plain pytree so it
checkpoints, shards (ZeRO: optimizer state follows FSDP param sharding),
and reshards elastically like everything else.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # int8 gradient compression w/ error feedback (cross-pod all-reduce)
    compress_grads: bool = False


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params: PyTree, cfg: OptimizerConfig) -> PyTree:
    zeros = lambda p: jnp.zeros_like(p, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
    }
    if cfg.compress_grads:
        state["error"] = jax.tree.map(zeros, params)
    return state


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


# ---------------------------------------------------------------------------
# int8 compression with error feedback
# ---------------------------------------------------------------------------


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads: PyTree, error: PyTree
                           ) -> tuple[PyTree, PyTree]:
    """Quantize (grad + carried error); the residual becomes the new error.

    The compressed representation is what would cross the pod link; error
    feedback keeps the optimizer unbiased over time.
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = compress_int8(target)
        restored = decompress_int8(q, scale)
        return restored, target - restored

    flat = jax.tree.map(one, grads, error)
    restored = jax.tree.map(lambda t: t[0], flat,
                            is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda t: isinstance(t, tuple))
    return restored, new_err


# ---------------------------------------------------------------------------
# The update
# ---------------------------------------------------------------------------


def _is_matrix(path: tuple, leaf: jax.Array) -> bool:
    return leaf.ndim >= 2


def apply_updates(
    params: PyTree,
    grads: PyTree,
    state: PyTree,
    cfg: OptimizerConfig,
) -> tuple[PyTree, PyTree, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    metrics: dict = {}
    if cfg.compress_grads:
        grads, new_error = compress_with_feedback(grads, state["error"])
        metrics["compress_error_norm"] = global_norm(new_error)
    gnorm = global_norm(grads)
    metrics["grad_norm"] = gnorm
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    metrics["lr"] = lr
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        vhat = nu / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"step": step, "mu": new_mu, "nu": new_nu}
    if cfg.compress_grads:
        new_state["error"] = new_error
    return new_params, new_state, metrics
