"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; smoke tests and benches see the 1 real CPU device.

Topology: TPU v5e, 16x16 = 256 chips per pod.  ``model`` is the fast
(intra-pod ICI) axis used for tensor/expert parallelism; ``data`` carries
FSDP + data parallelism; the leading ``pod`` axis extends data parallelism
across pods (lowest inter-pod traffic: one gradient all-reduce per step).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Axes the global batch shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def num_chips(mesh: jax.sharding.Mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
