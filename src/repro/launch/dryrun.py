import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first init, and the dry-run needs 512 placeholder host devices
# to build the production meshes.  (Only this entry point does this —
# tests and benches see the single real CPU device.)

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x applicable input shape) cell and both production
meshes (single-pod 16x16, multi-pod 2x16x16), this driver:

  1. builds the jittable step (train_step / prefill_step / serve_step),
  2. ``.lower()``s it with ShapeDtypeStruct stand-ins (no allocation) and
     explicit in/out shardings from ``repro.launch.sharding``,
  3. ``.compile()``s it — sharding mismatches, unsupported collectives and
     compile-time OOMs surface here as hard failures,
  4. records ``memory_analysis()`` (fits-per-device proof),
     ``cost_analysis()`` (FLOPs / bytes) and the collective schedule
     parsed from the compiled HLO (op kind -> bytes moved per device),
     into a JSON artifact consumed by the roofline report.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all --out-dir benchmarks/artifacts
"""
import argparse
import json
import re
import sys
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import SHAPES, applicable_shapes
from repro.launch import hlo_cost
from repro.launch import sharding as sh
from repro.launch.mesh import make_production_mesh, num_chips
from repro.models import get_model
from repro.serve.steps import decode_cache_window, make_prefill_step, \
    make_serve_step
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts

# ---------------------------------------------------------------------------
# TPU v5e hardware model (roofline constants)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# effective bytes-on-the-wire multiplier per collective kind (ring algos)
_WIRE_FACTOR = {
    "all-reduce": 2.0,  # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict[str, dict[str, float]]:
    """Sum result bytes of every collective op in the (post-SPMD, hence
    per-device-shaped) HLO.  Returns kind -> {count, bytes}."""
    out: dict[str, dict[str, float]] = {
        k: {"count": 0, "bytes": 0.0} for k in _COLLECTIVES
    }
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        rhs = ls.split("=", 1)[1].lstrip()
        # result type(s) precede the op name:  f32[8,128]{1,0} all-reduce(
        m = re.match(r"^(\(?[\w\[\],{}\s/]*?)\s*(all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(-start|-done)?\(", rhs)
        if not m:
            continue
        kind = m.group(2)
        if m.group(3) == "-done":
            continue  # counted at -start
        restype = m.group(1)
        nbytes = sum(
            _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(restype)
        )
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
    return {k: v for k, v in out.items() if v["count"]}


def collective_seconds(coll: dict[str, dict[str, float]]) -> float:
    return sum(
        v["bytes"] * _WIRE_FACTOR[k] / ICI_BW for k, v in coll.items()
    )


# ---------------------------------------------------------------------------
# Case construction
# ---------------------------------------------------------------------------


def default_microbatches(arch: str, shape_name: str, mesh) -> int:
    """Gradient-accumulation factor targeting ~8k local tokens per
    microbatch (the production memory lever; recorded per cell)."""
    shape = SHAPES[shape_name]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    local_tokens = shape.global_batch * shape.seq_len // dp
    local_seqs = max(1, shape.global_batch // dp)
    mb = max(1, local_tokens // 8192)
    return min(mb, local_seqs)  # cannot split below 1 sequence


def build_case(arch: str, shape_name: str, mesh, *, unroll: bool = True,
               remat: str = "full", compress_grads: bool = False,
               use_flash: bool = False, microbatches: int = 1,
               cfg_overrides: dict | None = None):
    """Returns (name, fn, arg_specs, in_shardings)."""
    import dataclasses as _dc

    cfg = configs.get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    model = get_model(cfg, use_flash=use_flash)
    model.unroll = unroll
    model.axis_rules = {
        "batch": ("pod", "data") if "pod" in mesh.axis_names else ("data",),
        "tp": "model",
        "ep": "model",
        "sizes": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "mesh": mesh,
    }

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = sh.param_shardings(params_shape, mesh)

    if shape.kind == "train":
        tcfg = ts.TrainConfig(
            microbatches=microbatches, remat=remat,
            opt=opt_lib.OptimizerConfig(compress_grads=compress_grads),
        )
        step = ts.make_train_step(model, tcfg)
        opt_shape = jax.eval_shape(
            lambda p: opt_lib.init_opt_state(p, tcfg.opt), params_shape
        )
        oshard = sh.opt_state_shardings(opt_shape, params_shape, mesh)
        batch_shape = model.input_specs(shape)
        bshard = sh.batch_shardings(batch_shape, mesh)
        in_shardings = (pshard, oshard, bshard)
        out_shardings = (pshard, oshard, sh.replicated(mesh))
        args = (params_shape, opt_shape, batch_shape)
        return "train_step", step, args, in_shardings, out_shardings

    if shape.kind == "prefill":
        step = make_prefill_step(model)
        batch_shape = model.input_specs(shape)
        bshard = sh.batch_shardings(batch_shape, mesh)
        in_shardings = (pshard, bshard)
        # logits replicated-batch-sharded output
        out_shardings = None
        args = (params_shape, batch_shape)
        return "prefill_step", step, args, in_shardings, out_shardings

    # decode
    window = decode_cache_window(cfg, shape)
    b = shape.global_batch
    serve = make_serve_step(model)
    cache_shape = jax.eval_shape(lambda: model.init_cache(b, window))
    cshard = sh.cache_shardings(cache_shape, b, mesh)
    tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    bshard = sh.batch_shardings({"t": tok, "p": pos}, mesh)
    in_shardings = (pshard, cshard, bshard["t"], bshard["p"],
                    sh.replicated(mesh))
    out_shardings = (bshard["t"], cshard)
    args = (params_shape, cache_shape, tok, pos, key)
    return "serve_step", serve, args, in_shardings, out_shardings


def model_flops_per_chip(arch: str, shape_name: str, chips: int) -> float:
    """Useful model FLOPs per chip per step: 6·N_active·tokens for train
    (fwd+bwd), 2·N_active·tokens for inference steps."""
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:  # decode: one token per sequence per step
        tokens = shape.global_batch
        mult = 2.0
    return mult * n * tokens / chips


def bytes_floor_per_chip(arch: str, shape_name: str, chips: int) -> float:
    """Lower bound on HBM traffic per chip per step.

    train:   3 bf16 weight streams (fwd, bwd-dgrad, bwd-wgrad) + AdamW
             state read/write (f32 mu, nu, params);
    prefill: one bf16 weight stream;
    decode:  one bf16 weight stream + one pass over the KV/state cache.
    """
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        return (3 * 2 * n + 3 * 2 * 4 * n) / chips
    if shape.kind == "prefill":
        return 2 * n / chips
    # decode: cache bytes from the abstract cache pytree
    from repro.serve.steps import decode_cache_window

    model = get_model(cfg)
    window = decode_cache_window(cfg, shape)
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, window)
    )
    cache_bytes = sum(
        int(np.prod(l.shape)) * l.dtype.itemsize
        for l in jax.tree_util.tree_leaves(cache_shape)
    )
    return (2 * n + cache_bytes) / chips


def _lower_compile(arch, shape_name, mesh, **kw):
    name, fn, args, in_sh, out_sh = build_case(arch, shape_name, mesh, **kw)
    # donate params/opt-state (train) or the cache (decode): the compiled
    # step aliases them in place, so memory_analysis reflects production.
    donate = (0, 1) if name == "train_step" else (
        (1,) if name == "serve_step" else ()
    )
    t0 = time.time()
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return name, compiled, t_lower, t_compile


def attn_flash_io_bytes(arch: str, shape_name: str, chips: int,
                        cfg_overrides: dict | None = None) -> float:
    """Per-chip HBM traffic of attention if the Pallas flash kernel ran
    instead of XLA-blocked attention: q,k,v read + o written per
    application (x3 passes for training: fwd, bwd reads + dq/dk/dv)."""
    import dataclasses as _dc

    cfg = configs.get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    if cfg.family == "ssm":
        return 0.0
    if cfg.family == "hybrid":
        n_apps = cfg.num_layers // cfg.shared_attn_every
    else:
        n_apps = cfg.num_layers
    dh = cfg.resolved_head_dim
    if shape.kind == "decode":
        tokens = shape.global_batch  # one new token each; cache bytes are
        # already part of the floor — flash-decode reads the cache once.
        passes = 1
    else:
        tokens = shape.global_batch * shape.seq_len
        passes = 3 if shape.kind == "train" else 1
    io = tokens * dh * (2 * cfg.num_heads + 2 * cfg.num_kv_heads) * 2
    return passes * n_apps * io / chips


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             unroll: bool = False, remat: str = "full",
             compress_grads: bool = False, use_flash: bool = False,
             cfg_overrides: dict | None = None,
             microbatches: int | None = None,
             mesh_shape: tuple | None = None,
             verbose: bool = True) -> dict[str, Any]:
    """Lower+compile the production configuration (lax.scan layer
    stacks, gradient accumulation, remat) and derive the roofline terms.

    FLOPs/bytes/collectives come from the loop-aware HLO parser
    (repro.launch.hlo_cost), which multiplies while-loop bodies by their
    recovered trip counts — XLA's own cost_analysis counts each loop body
    once.  ``unroll=True`` instead unrolls every layer into the HLO and
    uses XLA's analysis directly (slow; the validation path).
    """
    if mesh_shape is not None:
        # logical remesh over the same chips (e.g. (32, 8) when an arch's
        # head count does not divide 16) — a per-arch deployment choice;
        # the canonical 16x16 dry-run proof is separate.
        axes = (("pod", "data", "model") if len(mesh_shape) == 3
                else ("data", "model"))
        mesh = jax.make_mesh(tuple(mesh_shape), axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = num_chips(mesh)
    is_train = SHAPES[shape_name].kind == "train"
    if microbatches is None:
        microbatches = (
            default_microbatches(arch, shape_name, mesh) if is_train else 1
        )
    name, compiled, t_lower, t_compile = _lower_compile(
        arch, shape_name, mesh, unroll=unroll, remat=remat,
        compress_grads=compress_grads, use_flash=use_flash,
        microbatches=microbatches, cfg_overrides=cfg_overrides,
    )
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    if unroll:
        # every layer explicit in the HLO: use XLA's own cost analysis
        cost = compiled.cost_analysis()
        flops = float(cost.get("flops", 0.0))
        bytes_accessed = float(cost.get("bytes accessed", 0.0))
        coll = parse_collectives(hlo)
        scope_bytes: dict = {}
    else:
        # production scan config: loop-aware static accounting
        lac = hlo_cost.analyze(hlo)
        flops = lac.flops
        bytes_accessed = lac.bytes_accessed
        coll = lac.collectives
        scope_bytes = lac.scope_bytes
    result = {
        "arch": arch,
        "shape": shape_name,
        "step": name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "unroll": unroll,
        "remat": remat,
        "microbatches": microbatches,
        # memory (per device)
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "peak_bytes": int(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
        ),
        # cost (per device, post-partition)
        "hlo_flops": flops,
        "hlo_bytes": bytes_accessed,
        "model_flops": model_flops_per_chip(arch, shape_name, chips),
        "collectives": coll,
        "collective_bytes": sum(v["bytes"] for v in coll.values()),
        # roofline terms (seconds)
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": bytes_accessed / HBM_BW,
        "t_collective": collective_seconds(coll),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
    }
    terms = {
        "compute": result["t_compute"],
        "memory": result["t_memory"],
        "collective": result["t_collective"],
    }
    result["bottleneck"] = max(terms, key=terms.get)
    result["useful_flops_ratio"] = (
        result["model_flops"] / flops if flops else 0.0
    )
    # roofline fraction: ideal step time (the larger of the useful-FLOPs
    # bound and the bytes-floor bound) over the dominant achieved term
    floor = bytes_floor_per_chip(arch, shape_name, chips)
    result["bytes_floor"] = floor
    t_bound = max(terms.values())
    t_ideal = max(result["model_flops"] / PEAK_FLOPS, floor / HBM_BW)
    result["t_ideal"] = t_ideal
    result["roofline_fraction"] = t_ideal / t_bound if t_bound else 0.0
    # ---- Pallas-flash-kernel modeling (validated in interpret mode; the
    # kernel keeps score blocks in VMEM, so the attn_core scope's HBM
    # traffic collapses to the q/k/v/o streams) ----
    result["scope_bytes"] = scope_bytes
    attn_scope = scope_bytes.get("attn_core", 0.0)
    if attn_scope:
        flash_io = attn_flash_io_bytes(arch, shape_name, chips,
                                       cfg_overrides)
        bytes_flash = bytes_accessed - attn_scope + flash_io
        t_mem_flash = bytes_flash / HBM_BW
        result["t_memory_flash"] = t_mem_flash
        terms_f = dict(terms, memory=t_mem_flash)
        tb_f = max(terms_f.values())
        result["bottleneck_flash"] = max(terms_f, key=terms_f.get)
        result["roofline_fraction_flash"] = (
            t_ideal / tb_f if tb_f else 0.0
        )
    if verbose:
        print(json.dumps(result, indent=2, default=float))
    return result


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def all_cells() -> list[tuple[str, str]]:
    cells = []
    for arch in configs.list_archs():
        for shape in applicable_shapes(configs.get_config(arch)):
            cells.append((arch, shape.name))
    return cells


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=configs.list_archs())
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="sweep every applicable cell on this mesh")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer stacks (slow compile; used to "
                         "validate the loop-aware accounting)")
    ap.add_argument("--remat", default="full",
                    choices=["none", "full", "dots"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--use-flash", action="store_true")
    ap.add_argument("--out", help="write JSON result(s) to this path")
    args = ap.parse_args(argv)

    unroll = args.unroll
    results = []
    if args.all:
        for arch, shape in all_cells():
            print(f"=== {arch} x {shape} ({'2x16x16' if args.multi_pod else '16x16'}) ===",
                  flush=True)
            results.append(run_cell(
                arch, shape, multi_pod=args.multi_pod, unroll=unroll,
                remat=args.remat, compress_grads=args.compress_grads,
                use_flash=args.use_flash,
            ))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        results.append(run_cell(
            args.arch, args.shape, multi_pod=args.multi_pod, unroll=unroll,
            remat=args.remat, compress_grads=args.compress_grads,
            use_flash=args.use_flash,
        ))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2, default=float)
    return 0


if __name__ == "__main__":
    sys.exit(main())
