"""Loop-aware static cost analysis over compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` visits every computation ONCE — a ``while``
body (every ``lax.scan``/``fori_loop``) is counted a single time no
matter its trip count, so scanned production graphs under-report FLOPs,
bytes and collectives by ~the layer count.  This module re-derives the
three roofline inputs with loop awareness:

1. parse the HLO module into computations and instructions, recording
   each instruction's result shape (operand references are resolved
   through a per-computation name -> shape map, since post-optimization
   HLO does not print operand types inline);
2. build the call graph (``while`` body/cond, fusions, ``to_apply``,
   branches) and recover each while loop's trip count from the integer
   constant in its condition computation (counted ``lax`` loops lower to
   ``iv < N`` with ``N`` materialized as an ``s32[] constant`` there);
3. propagate execution multipliers from ENTRY;
4. account per executed instruction:
   * FLOPs: ``dot`` ops (2 x prod(result dims) x prod(lhs contraction
     dims)), wherever they live (fusion bodies included);
   * HBM bytes: result + operand bytes of top-level instructions of
     executed computations (fusion internals excluded — fused
     intermediates stay on-chip);
   * collective bytes: result bytes of all-reduce / all-gather /
     reduce-scatter / all-to-all / collective-permute, times the
     multiplier.

Shapes in post-partitioning HLO are per-device, so all totals are
per-chip.  Validated against fully-unrolled lowerings in
tests/test_dryrun.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(
    r"^(\(?[\w\[\],{}\s/\*=]*?\)?)\s*([a-z][\w\-]*)\("
)
_CALLED_RE = re.compile(
    r"(?:condition|body|to_apply|calls|branch_computations)="
    r"(?:%([\w\.\-]+)|\{([^}]*)\})"
)
_CONST_INT_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "copy-start", "copy-done", "iota", "partition-id",
    "replica-id",
}


def _parse_shapes(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append(
                (dt, tuple(int(x) for x in dims.split(",")) if dims else ())
            )
    return out


def _bytes_of(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instruction:
    name: str
    opcode: str
    result_shapes: list
    operand_names: list
    called: list
    meta: str


@dataclass
class Computation:
    name: str
    instructions: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)  # var name -> shape list
    int_constants: list = field(default_factory=list)
    is_fusion_body: bool = False


def parse_module(text: str) -> tuple[dict[str, "Computation"], Optional[str]]:
    comps: dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line or line.startswith("//"):
            continue
        stripped = line.strip()
        # computation header: [ENTRY] %name (...) -> ... {
        if not line.startswith("  ") and "->" in line and line.endswith("{"):
            is_entry = stripped.startswith("ENTRY")
            header = stripped[5:].strip() if is_entry else stripped
            m = re.match(r"^%?([\w\.\-]+)\s*\(", header)
            if m:
                cur = Computation(name=m.group(1))
                comps[cur.name] = cur
                if is_entry:
                    entry = cur.name
            continue
        if stripped == "}":
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, rhs = mi.group(1), mi.group(2)
        mo = _OPCODE_RE.match(rhs)
        if not mo:
            continue
        restype, opcode = mo.group(1), mo.group(2)
        # operands: between the op's '(' and its matching ')'
        paren = rhs[mo.end() - 1:]
        depth, end = 0, len(paren) - 1
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = paren[1:end]
        meta = paren[end + 1:]
        called = []
        for m1, m2 in _CALLED_RE.findall(meta):
            if m1:
                called.append(m1)
            elif m2:
                called.extend(
                    c.strip().lstrip("%") for c in m2.split(",") if c.strip()
                )
        result_shapes = _parse_shapes(restype)
        ins = Instruction(
            name=name,
            opcode=opcode,
            result_shapes=result_shapes,
            operand_names=re.findall(r"%([\w\.\-]+)", operands),
            called=called,
            meta=meta,
        )
        cur.instructions.append(ins)
        cur.shapes[name] = result_shapes
        cm = _CONST_INT_RE.search(line)
        if cm:
            cur.int_constants.append(int(cm.group(1)))
    return comps, entry


def _trip_count(cond: Computation) -> Optional[int]:
    """Counted jax loops put the bound as the sole s32 constant in the
    condition computation (``iv < N``)."""
    if cond.int_constants:
        return max(cond.int_constants)
    return None


_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _dot_flops(ins: Instruction, comp: Computation) -> float:
    result = 1.0
    if ins.result_shapes:
        for d in ins.result_shapes[0][1]:
            result *= d
    contract = 1.0
    m = _DOT_CONTRACT_RE.search(ins.meta)
    if m and ins.operand_names:
        lhs_shapes = comp.shapes.get(ins.operand_names[0])
        if lhs_shapes:
            lhs_dims = lhs_shapes[0][1]
            for idx in m.group(1).split(","):
                if idx != "" and int(idx) < len(lhs_dims):
                    contract *= lhs_dims[int(idx)]
    return 2.0 * result * contract


@dataclass
class LoopAwareCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collectives: dict = field(default_factory=dict)
    unknown_trip_loops: int = 0
    dot_count: int = 0
    # bytes attributed to jax named_scope labels (substring of op_name)
    scope_bytes: dict = field(default_factory=dict)
    scope_flops: dict = field(default_factory=dict)

    @property
    def collective_bytes(self) -> float:
        return sum(v["bytes"] for v in self.collectives.values())


def _fusion_operand_bytes(ins: Instruction, comp: Computation,
                          comps: dict) -> float:
    """Operand traffic of a fusion, window-aware.

    A fusion that internally dynamic-slices one of its operands (the
    per-layer weight slice inside a scanned stack, the cache window in
    decode) only reads the WINDOW from HBM, not the whole buffer; charging
    the full operand over-counts stacked-parameter traffic by ~num_layers.
    For each fusion parameter whose every in-body consumer is a
    (dynamic-)slice/gather, charge the consumers' result sizes instead.
    """
    total = 0.0
    body = None
    for c in ins.called:
        if c in comps:
            body = comps[c]
            break
    body_params = (
        [bi.name for bi in body.instructions if bi.opcode == "parameter"]
        if body is not None else []
    )
    for idx, opname in enumerate(ins.operand_names):
        full = _bytes_of(comp.shapes.get(opname, ()))
        if body is None or idx >= len(body_params):
            total += full
            continue
        pname = body_params[idx]
        consumers = [
            bi for bi in body.instructions if pname in bi.operand_names
        ]
        if consumers and all(
            c.opcode in ("dynamic-slice", "slice", "gather",
                         "dynamic-update-slice")
            for c in consumers
        ):
            total += sum(_bytes_of(c.result_shapes) for c in consumers)
        else:
            total += full
    return total


def _instr_bytes(ins: Instruction, comp: Computation,
                 comps: Optional[dict] = None) -> float:
    """HBM traffic model per op.

    Slicing/indexed ops move only the slice, not the buffer they index
    into (dynamic-slice reads its window; dynamic-update-slice writes its
    window in place — XLA aliases the big operand).  Everything else uses
    the standard result + operands convention.
    """
    res = _bytes_of(ins.result_shapes)
    op = ins.opcode
    if op == "fusion" and comps is not None:
        return res + _fusion_operand_bytes(ins, comp, comps)
    if op in ("dynamic-slice", "slice"):
        return 2.0 * res  # read window + write result
    if op == "dynamic-update-slice":
        # update operand (index 1) read + window write
        upd = 0
        if len(ins.operand_names) > 1:
            upd = _bytes_of(comp.shapes.get(ins.operand_names[1], ()))
        return 2.0 * upd
    if op == "gather":
        idx = 0
        if len(ins.operand_names) > 1:
            idx = _bytes_of(comp.shapes.get(ins.operand_names[1], ()))
        return 2.0 * res + idx
    if op == "scatter":
        upd = 0
        if len(ins.operand_names) > 2:
            upd = _bytes_of(comp.shapes.get(ins.operand_names[2], ()))
        return 3.0 * upd  # read update + read/write target windows
    if op == "broadcast":
        return res  # operand is tiny by construction
    if op == "while":
        return 0.0  # carry traffic belongs to the body's ops
    ops_bytes = sum(
        _bytes_of(comp.shapes.get(o, ())) for o in ins.operand_names
    )
    return res + ops_bytes


_SCOPE_RE = re.compile(r'op_name="[^"]*?([\w\-]+_core|moe_dispatch)[^"]*"')


def analyze(hlo_text: str, default_trips: int = 1) -> LoopAwareCost:
    comps, entry = parse_module(hlo_text)
    cost = LoopAwareCost()
    if entry is None:
        entry = next(iter(comps), None)
        if entry is None:
            return cost

    for comp in comps.values():
        for ins in comp.instructions:
            if ins.opcode == "fusion":
                for c in ins.called:
                    if c in comps:
                        comps[c].is_fusion_body = True

    # propagate execution multipliers from ENTRY through the call graph
    mult: dict[str, float] = {entry: 1.0}
    order = [entry]
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult.get(cname, 1.0)
        for ins in comp.instructions:
            if ins.opcode == "while":
                trips = None
                for c in ins.called:
                    if c in comps:
                        t = _trip_count(comps[c])
                        if t is not None:
                            trips = t
                            break
                if trips is None:
                    trips = default_trips
                    cost.unknown_trip_loops += 1
                child_mult = m * max(trips, 1)
            else:
                child_mult = m
            for c in ins.called:
                if c not in comps:
                    continue
                prev = mult.get(c)
                if prev is None or child_mult > prev:
                    mult[c] = child_mult
                    if c not in order[i:]:
                        order.append(c)
    # account
    for cname, comp in comps.items():
        m = mult.get(cname)
        if m is None:
            continue
        for ins in comp.instructions:
            scope = None
            sm = _SCOPE_RE.search(ins.meta)
            if sm:
                scope = sm.group(1)
            if ins.opcode == "dot":
                fl = _dot_flops(ins, comp) * m
                cost.flops += fl
                cost.dot_count += 1
                if scope:
                    cost.scope_flops[scope] = (
                        cost.scope_flops.get(scope, 0.0) + fl
                    )
            if comp.is_fusion_body:
                continue  # fused intermediates never touch HBM
            if ins.opcode in _FREE_OPS:
                continue
            kind = next(
                (k for k in _COLLECTIVES if ins.opcode.startswith(k)), None
            )
            nbytes = _bytes_of(ins.result_shapes)
            if kind and not ins.opcode.endswith("-done"):
                e = cost.collectives.setdefault(
                    kind, {"count": 0, "bytes": 0.0}
                )
                e["count"] += m
                e["bytes"] += nbytes * m
            traffic = _instr_bytes(ins, comp, comps) * m
            cost.bytes_accessed += traffic
            if scope:
                cost.scope_bytes[scope] = (
                    cost.scope_bytes.get(scope, 0.0) + traffic
                )
    return cost
