"""Sharding rules: parameter/optimizer/batch/cache PartitionSpecs.

Scheme (Megatron-style TP x FSDP, EP for MoE, pure DP across pods):

* logical axis ``tp``   -> mesh ``model``: attention head / FFN column /
  expert-hidden dimensions (column-parallel in, row-parallel out — two
  collectives per block);
* logical axis ``fsdp`` -> mesh ``data``: every parameter's long
  non-TP dimension (ZeRO-3: params, grads and optimizer state all shard
  here and all-gather per layer inside the scan);
* logical axis ``ep``   -> mesh ``model``: the expert axis of MoE weights
  (expert parallelism; dispatch/combine lower to all-to-alls);
* batch dims            -> ``("pod", "data")`` when multi-pod else
  ``("data",)``;
* decode KV caches      -> window axis over ``model`` (split-K decode),
  batch axis over ``data``.

Rules are regex -> logical template, right-aligned onto the trailing dims
of each leaf (stacked layer axes lead and stay replicated).  Every
proposed mesh axis is validated for divisibility and dropped (replicated)
if it does not divide — small archs (e.g. 4-head xLSTM) degrade gracefully
instead of failing to lower.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# logical -> mesh axes (tuples shard over multiple axes; axes missing
# from the mesh are dropped, so "fsdp" is ZeRO across pods when the pod
# axis exists and plain data-sharding on the single-pod mesh)
LOGICAL = {"tp": ("model",), "fsdp": ("pod", "data"), "ep": ("model",)}

# (regex over the flattened path, right-aligned logical template)
PARAM_RULES: list[tuple[str, tuple]] = [
    # Embedding/unembedding shard the VOCAB dim only: sharding d_model over
    # `data` here would put the gather indices (batch over `data`) in
    # conflict with the table and make SPMD all-gather the *batch* — the
    # one resolution that destroys data parallelism.
    (r"embed/embedding$", ("tp", None)),
    (r"embed/lm_head$", (None, "tp")),
    (r"^lm_head$", (None, "tp")),  # audio head
    # attention
    (r"attn/w[qkv]$", ("fsdp", "tp")),
    (r"attn/wo$", ("tp", "fsdp")),
    (r"attn/b[qkv]$", ("tp",)),
    (r"attn/[qk]_norm$", (None,)),
    # dense FFN (swiglu / gelu)
    (r"mlp/w[gu1]$", ("fsdp", "tp")),
    (r"mlp/w[d2]$", ("tp", "fsdp")),
    (r"mlp/b1$", ("tp",)),
    (r"mlp/b2$", (None,)),
    # MoE
    (r"moe/router$", ("fsdp", None)),
    (r"moe/w[gu]$", ("ep", "fsdp", None)),
    (r"moe/wd$", ("ep", None, "fsdp")),
    (r"moe/shared/w[gu]$", ("fsdp", "tp")),
    (r"moe/shared/wd$", ("tp", "fsdp")),
    # mamba2
    (r"mamba/w_in$", ("fsdp", "tp")),
    (r"mamba/w_out$", ("tp", "fsdp")),
    (r"mamba/conv_w$", (None, "tp")),
    (r"mamba/conv_b$", ("tp",)),
    (r"mamba/(dt_bias|a_log|d_skip)$", ("tp",)),
    (r"mamba/gate_norm$", ("tp",)),
    # xlstm mLSTM
    (r"cell/w_up$", ("fsdp", "tp")),
    (r"cell/w[qkv]$", (None, "tp")),
    (r"cell/w_if$", (None, "tp")),
    (r"cell/b_if$", ("tp",)),
    (r"cell/conv_w$", (None, "tp")),
    (r"cell/conv_b$", ("tp",)),
    (r"cell/head_norm$", ("tp",)),
    (r"cell/w_down$", ("tp", "fsdp")),
    # xlstm sLSTM
    (r"cell/w_gates$", ("fsdp", "tp")),
    (r"cell/b_gates$", ("tp",)),
    (r"cell/r_gates$", (None, None, None, None)),
    # norms
    (r"(ln1|ln2|ln|final_norm)/(scale|bias)$", (None,)),
    # audio stub head adapter
    (r"head/w[12]$", ("fsdp", "tp")),
    (r"head/b[12]$", (None,)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _fit(template: tuple, shape: tuple, mesh: Mesh) -> P:
    """Right-align the logical template onto the trailing dims; drop axes
    that do not divide the corresponding dim."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ndim = len(shape)
    spec: list = [None] * ndim
    k = len(template)
    if k > ndim:
        template = template[k - ndim:]
        k = ndim
    for i, logical in enumerate(template):
        dim = ndim - k + i
        if logical is None:
            continue
        axes = tuple(a for a in LOGICAL[logical] if a in sizes)
        if not axes:
            continue
        total = 1
        for a in axes:
            total *= sizes[a]
        if shape[dim] % total == 0 and shape[dim] >= total:
            spec[dim] = axes if len(axes) > 1 else axes[0]
    return P(*spec)


def param_spec(path_str: str, shape: tuple, mesh: Mesh) -> P:
    for pattern, template in PARAM_RULES:
        if re.search(pattern, path_str):
            return _fit(template, shape, mesh)
    # default: FSDP-shard the largest dim if divisible
    if shape:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        big = int(np.argmax(shape))
        if shape[big] % sizes["data"] == 0 and shape[big] >= sizes["data"]:
            spec = [None] * len(shape)
            spec[big] = "data"
            return P(*spec)
    return P()


def param_shardings(params_shape: PyTree, mesh: Mesh) -> PyTree:
    """NamedShardings for a parameter pytree (of arrays or SDS)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    out = []
    for path, leaf in flat:
        spec = param_spec(_path_str(path), tuple(leaf.shape), mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_state_shardings(opt_shape: PyTree, params_shape: PyTree, mesh: Mesh
                        ) -> PyTree:
    """ZeRO: mu/nu/error follow the param shardings; step is replicated."""
    pshard = param_shardings(params_shape, mesh)
    out = {"step": NamedSharding(mesh, P())}
    for key in opt_shape:
        if key == "step":
            continue
        out[key] = pshard
    return out


# ---------------------------------------------------------------------------
# Batches and caches
# ---------------------------------------------------------------------------


def batch_shardings(batch_shape: PyTree, mesh: Mesh) -> PyTree:
    """Shard the leading (batch) dim of every input over (pod, data)."""
    daxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    total = 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for a in daxes:
        total *= sizes[a]

    def one(leaf):
        if leaf.shape and leaf.shape[0] % total == 0 and leaf.shape[0] >= total:
            return NamedSharding(
                mesh, P(daxes, *([None] * (len(leaf.shape) - 1)))
            )
        return NamedSharding(mesh, P())

    return jax.tree.map(one, batch_shape)


def cache_shardings(cache_shape: PyTree, batch_size: int, mesh: Mesh
                    ) -> PyTree:
    """Decode caches: batch axis -> data, window/long axis -> model.

    The batch axis is identified by size; the ``model`` axis goes to the
    largest remaining dim that divides (the KV window / state heads),
    giving split-K decode attention and head-parallel state updates.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    d_ax = "data"

    def one(leaf):
        shape = leaf.shape
        spec: list = [None] * len(shape)
        used_batch = False
        for i, s in enumerate(shape):
            if not used_batch and s == batch_size and (
                batch_size % sizes[d_ax] == 0 and batch_size >= sizes[d_ax]
            ):
                spec[i] = d_ax
                used_batch = True
                break
        # model axis on the largest remaining divisible dim
        cand, best = None, 0
        for i, s in enumerate(shape):
            if spec[i] is None and s % sizes["model"] == 0 \
                    and s >= sizes["model"] and s > best:
                cand, best = i, s
        if cand is not None:
            spec[cand] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, cache_shape)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Autobatching-VM lane state
# ---------------------------------------------------------------------------


def lane_shardings(
    mesh: Mesh, axis: Optional[str] = None
) -> tuple[NamedSharding, NamedSharding, NamedSharding]:
    """``(lane, stack, replicated)`` NamedShardings for pc-VM lane state.

    The VM's state is lane-major: ``[batch, ...]`` tops/pointers/masks
    shard their leading axis, ``[depth, batch, ...]`` stacks shard axis 1
    (depth is addressed per lane, never across lanes), and scalars /
    ``[num_blocks]`` counters replicate.  One source of truth shared by
    ``repro.core.pc_vm`` and the sharded stack-kernel tests, so a layout
    change cannot silently diverge between them.
    """
    if len(mesh.axis_names) != 1 and axis is None:
        raise ValueError(
            "lane_shardings needs a 1-D mesh or an explicit axis; got axes "
            f"{mesh.axis_names}"
        )
    axis = axis if axis is not None else mesh.axis_names[0]
    return (
        NamedSharding(mesh, P(axis)),
        NamedSharding(mesh, P(None, axis)),
        NamedSharding(mesh, P()),
    )
