"""Training launcher: ``python -m repro.launch.train --arch smollm-135m``.

Wires the full production path on whatever devices exist: config registry
-> model -> sharded train step (pjit) -> deterministic data stream ->
AdamW -> atomic checkpointing -> resilient restart loop.  On a pod you'd
run the same file under multi-host jax.distributed; on CPU it trains small
models end-to-end (see examples/train_lm.py for the 100M-class example).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import ShapeSpec, reduce_for_smoke
from repro.launch import sharding as sh
from repro.models import get_model
from repro.train import checkpoint as ckpt_lib
from repro.train import data as data_lib
from repro.train import fault_tolerance as ft
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts


def build_trainer(arch: str, *, seq_len: int, global_batch: int,
                  steps: int, lr: float, microbatches: int, remat: str,
                  smoke: bool, mesh=None, compress_grads: bool = False):
    cfg = configs.get_smoke_config(arch) if smoke else configs.get_config(arch)
    model = get_model(cfg)
    shape = ShapeSpec("cli_train", seq_len, global_batch, "train")
    tcfg = ts.TrainConfig(
        microbatches=microbatches, remat=remat,
        opt=opt_lib.OptimizerConfig(
            peak_lr=lr, warmup_steps=max(10, steps // 20),
            total_steps=steps, compress_grads=compress_grads,
        ),
    )
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt_lib.init_opt_state(params, tcfg.opt)
    step = ts.make_train_step(model, tcfg)
    if mesh is not None:
        model.axis_rules = {
            "batch": ("pod", "data") if "pod" in mesh.axis_names
            else ("data",),
            "tp": "model",
            "ep": "model",
            "sizes": dict(zip(mesh.axis_names, mesh.devices.shape)),
            "mesh": mesh,
        }
        pshard = sh.param_shardings(params, mesh)
        oshard = sh.opt_state_shardings(opt_state, params, mesh)
        bshard = sh.batch_shardings(model.input_specs(shape), mesh)
        step = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                       donate_argnums=(0, 1))
        params = jax.device_put(params, pshard)
        opt_state = jax.device_put(opt_state, oshard)
    else:
        step = jax.jit(step, donate_argnums=(0, 1))
    stream = data_lib.SyntheticStream(model, shape)
    return model, params, opt_state, step, stream


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=configs.list_archs())
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none",
                    choices=["none", "full", "dots"])
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (default: reduced smoke)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    model, params, opt_state, step, stream = build_trainer(
        args.arch, seq_len=args.seq_len, global_batch=args.global_batch,
        steps=args.steps, lr=args.lr, microbatches=args.microbatches,
        remat=args.remat, smoke=not args.full_size,
    )
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={model.cfg.name} params={n_params/1e6:.1f}M "
          f"steps={args.steps} batch={args.global_batch}x{args.seq_len}")

    def step_fn(state, i):
        p, o = state
        p, o, metrics = step(p, o, stream.batch(i))
        return (p, o), metrics

    ckpt = ckpt_lib.Checkpointer(args.ckpt_dir)
    loop = ft.ResilientLoop(step_fn, ckpt, save_every=args.save_every)
    (_, _), report = loop.run(
        (params, opt_state), args.steps, log_every=args.log_every
    )
    print(f"done: final_step={report.final_step} "
          f"restarts={report.restarts} "
          f"loss: {report.losses[0]:.4f} -> {report.losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
