"""Differentiable target densities for the NUTS experiments.

Both of the paper's test problems:

* a ``dim``-dimensional correlated Gaussian (Section 4.2's utilization
  study), and
* Bayesian logistic regression with synthetic data (Section 4.1's
  throughput study: 10,000 data points x 100 regressors at full scale).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Target:
    """A log-density with its gradient and ground-truth moments (if known)."""

    name: str
    dim: int
    logp: Callable[[jax.Array], jax.Array]
    # Ground-truth mean/marginal-std for moment tests (None if unknown).
    true_mean: np.ndarray | None = None
    true_std: np.ndarray | None = None

    def grad(self) -> Callable[[jax.Array], jax.Array]:
        return jax.grad(self.logp)

    def value_and_grad(self) -> Callable:
        return jax.value_and_grad(self.logp)


def correlated_gaussian(dim: int = 100, rho: float = 0.95) -> Target:
    """N(0, Sigma) with AR(1)-style correlation ``rho`` between neighbours.

    The precision matrix of an AR(1) process is tridiagonal, which keeps
    ``logp`` cheap (O(dim)) while the distribution is strongly correlated —
    exactly the regime where NUTS trajectory lengths vary a lot between
    chains, stressing batch utilization (paper Fig. 6).
    """
    # Tridiagonal precision of a stationary AR(1) with coefficient rho.
    s = 1.0 / (1.0 - rho * rho)
    main = np.full((dim,), s * (1 + rho * rho))
    main[0] = main[-1] = s
    off = np.full((dim - 1,), -s * rho)
    prec_main = jnp.asarray(main, jnp.float32)
    prec_off = jnp.asarray(off, jnp.float32)

    def logp(x: jax.Array) -> jax.Array:
        quad = jnp.sum(prec_main * x * x) + 2.0 * jnp.sum(
            prec_off * x[:-1] * x[1:]
        )
        return -0.5 * quad

    # Marginal variances of the AR(1) process are all 1.
    return Target(
        name=f"correlated_gaussian(dim={dim},rho={rho})",
        dim=dim,
        logp=logp,
        true_mean=np.zeros(dim),
        true_std=np.ones(dim),
    )


def isotropic_gaussian(dim: int = 10) -> Target:
    def logp(x: jax.Array) -> jax.Array:
        return -0.5 * jnp.sum(x * x)

    return Target(
        name=f"isotropic_gaussian(dim={dim})",
        dim=dim,
        logp=logp,
        true_mean=np.zeros(dim),
        true_std=np.ones(dim),
    )


def logistic_regression(
    num_data: int = 10_000, dim: int = 100, seed: int = 0
) -> Target:
    """Bayesian logistic regression on synthetic data (paper Section 4.1).

    Standard-normal prior on weights; features drawn N(0, 1); labels drawn
    from the model at a ground-truth weight vector.  The gradient costs
    O(num_data * dim) — an expensive leaf, as in the paper.
    """
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(num_data, dim)).astype(np.float32)
    w_true = (rng.normal(size=(dim,)) / np.sqrt(dim)).astype(np.float32)
    logits = x @ w_true
    y = (rng.uniform(size=(num_data,)) < 1.0 / (1.0 + np.exp(-logits))).astype(
        np.float32
    )
    xj = jnp.asarray(x)
    # y in {-1, +1} lets us write the likelihood as log_sigmoid(y * logits).
    y_pm = jnp.asarray(2.0 * y - 1.0)

    def logp(w: jax.Array) -> jax.Array:
        logits = xj @ w
        loglik = jnp.sum(jax.nn.log_sigmoid(y_pm * logits))
        logprior = -0.5 * jnp.sum(w * w)
        return loglik + logprior

    return Target(
        name=f"logistic_regression(n={num_data},d={dim})",
        dim=dim,
        logp=logp,
    )
