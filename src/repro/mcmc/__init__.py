"""MCMC substrate: the paper's evaluation workload.

``targets``   — differentiable log-densities (correlated Gaussian, Bayesian
                logistic regression — the paper's two test problems).
``nuts``      — the recursive No-U-Turn Sampler expressed in the autobatch
                IR (Fig. 2), exactly the shape of program the paper batches.
``iterative`` — a hand-rewritten, stack-free iterative NUTS in pure JAX
                (the Phan/Pradhan-style baseline the paper cites).
"""
from . import targets, nuts, iterative  # noqa: F401
