"""The No-U-Turn Sampler as an autobatchable program (paper Section 4).

This is the paper's headline workload: NUTS's standard presentation is a
*recursive* tree-building procedure (Hoffman & Gelman 2014, Algorithm 3)
with data-dependent control flow at every level — "prohibitively difficult
to batch by hand".  Here it is written against the Fig-2 IR exactly as a
user would write it: plain recursion (``build_tree`` calls itself), plain
``if``/``while`` control flow, and per-member primitives.  The autobatching
backends in :mod:`repro.core` then execute thousands of chains in lockstep.

Per the paper's experimental setup, each leaf of the NUTS tree takes
``steps_per_leaf`` (default 4) leapfrog steps, to amortize control overhead;
this does not affect soundness.

The leaf integrator primitive is tagged ``"grad"`` so the runtimes report
gradient-evaluation counts and batch utilization (paper Figs. 5 & 6).
Each leaf execution costs ``steps_per_leaf + 1`` gradient evaluations.

Public entry point: :func:`make_nuts_kernel` — the decorator-first pytree
API.  ``kernel(theta0, eps, key)`` takes per-chain ``theta0``/``key`` and a
``Shared`` scalar step size, and returns the pytree state ``{"theta",
"sum_theta", "sum_sq"}``; one kernel object serves every chain count
(compiled executors are cached per batch size over a shared lowering).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batching, frontend, ir
from repro.core.batching import Batched, Shared
from repro.core.frontend import spec

from .targets import Target

KEY = spec((2,), jnp.uint32)
F32 = spec((), jnp.float32)
I32 = spec((), jnp.int32)

DELTA_MAX = 1000.0  # divergence threshold (standard)


@dataclass(frozen=True)
class NutsSettings:
    max_tree_depth: int = 10
    num_steps: int = 10  # Markov-chain length (trajectories per chain)
    steps_per_leaf: int = 4  # leapfrog steps per tree leaf (paper: 4)

    @property
    def grads_per_leaf(self) -> int:
        return self.steps_per_leaf + 1


def make_primitives(target: Target, settings: NutsSettings):
    """Per-member JAX functions used as IR primitives."""
    logp = target.logp
    grad = jax.grad(logp)
    spl = settings.steps_per_leaf

    def leapfrog(theta, r, v, eps):
        """``steps_per_leaf`` leapfrog steps with step size ``v * eps``."""
        step = v * eps

        def body(_, carry):
            theta, r, g = carry
            r_half = r + 0.5 * step * g
            theta = theta + step * r_half
            g = grad(theta)
            r = r_half + 0.5 * step * g
            return theta, r, g

        theta, r, _ = jax.lax.fori_loop(0, spl, body, (theta, r, grad(theta)))
        return theta, r

    def joint(theta, r):
        return logp(theta) - 0.5 * jnp.sum(r * r)

    def uturn_ok(tm, rm, tp, rp):
        """1 if the (tm..tp) trajectory has NOT made a U-turn."""
        d = tp - tm
        ok = jnp.logical_and(jnp.dot(d, rm) >= 0.0, jnp.dot(d, rp) >= 0.0)
        return ok.astype(jnp.int32)

    def split3(key):
        ks = jax.random.split(key, 3)
        return ks[0], ks[1], ks[2]

    def split4(key):
        ks = jax.random.split(key, 4)
        return ks[0], ks[1], ks[2], ks[3]

    def momentum(key):
        return jax.random.normal(key, (target.dim,), jnp.float32)

    def slice_log_u(key, joint0):
        # log of the slice variable u ~ Uniform(0, exp(joint0)).
        return joint0 + jnp.log1p(-jax.random.uniform(key))

    def direction(key):
        return jnp.where(jax.random.bernoulli(key), 1.0, -1.0).astype(
            jnp.float32
        )

    return dict(
        leapfrog=leapfrog,
        joint=joint,
        uturn_ok=uturn_ok,
        split3=split3,
        split4=split4,
        momentum=momentum,
        slice_log_u=slice_log_u,
        direction=direction,
    )


def build_nuts_program(
    target: Target, settings: NutsSettings = NutsSettings()
) -> ir.Program:
    """The full multi-trajectory NUTS chain as a Fig-2 IR program.

    Functions:
      * ``build_tree(theta, r, log_u, v, j, eps, key)`` — the recursive
        doubling procedure (Hoffman & Gelman Algorithm 3's BuildTree);
      * ``nuts_step(theta, eps, key)`` — one trajectory (one draw);
      * ``nuts_chain(theta0, eps, key)`` — ``num_steps`` draws, accumulating
        running first/second moments (main function).
    """
    p = make_primitives(target, settings)
    vec = spec((target.dim,), jnp.float32)
    pb = frontend.ProgramBuilder(main="nuts_chain")

    # ------------------------------------------------------------------
    # build_tree — the recursive core
    # ------------------------------------------------------------------
    bt = pb.function(
        "build_tree",
        params=["theta", "r", "log_u", "v", "j", "eps", "key"],
        outputs=["tm", "rm", "tp", "rp", "th1", "n1", "s1", "key_out"],
        param_specs={
            "theta": vec, "r": vec, "log_u": F32, "v": F32,
            "j": I32, "eps": F32, "key": KEY,
        },
        output_specs={
            "tm": vec, "rm": vec, "tp": vec, "rp": vec,
            "th1": vec, "n1": I32, "s1": I32, "key_out": KEY,
        },
    )
    is_leaf = bt.prim(lambda j: j == 0, ["j"], name="is_leaf")
    with bt.if_(is_leaf):
        # Base case: one leaf = steps_per_leaf leapfrog steps (tag: grad).
        bt.prim(
            p["leapfrog"], ["theta", "r", "v", "eps"],
            out=("th_new", "r_new"), n_out=2, name="leapfrog", tag="grad",
        )
        bt.prim(p["joint"], ["th_new", "r_new"], out="jnt", name="joint")
        bt.assign(
            "n1",
            lambda lu, jt: (lu <= jt).astype(jnp.int32),
            ["log_u", "jnt"], name="slice_ind",
        )
        bt.assign(
            "s1",
            lambda lu, jt: (jt > lu - DELTA_MAX).astype(jnp.int32),
            ["log_u", "jnt"], name="not_divergent",
        )
        bt.copy("th_new", out="tm")
        bt.copy("r_new", out="rm")
        bt.copy("th_new", out="tp")
        bt.copy("r_new", out="rp")
        bt.copy("th_new", out="th1")
        bt.copy("key", out="key_out")
        bt.return_()
    # Recursive case: build left half, then (if still going) the right half.
    bt.assign("jm1", lambda j: j - 1, ["j"])
    bt.prim(p["split3"], ["key"], out=("k2", "k3", "key_out"), n_out=3,
            name="split3")
    bt.call(
        "build_tree",
        ["theta", "r", "log_u", "v", "jm1", "eps", "k2"],
        out=("tm", "rm", "tp", "rp", "th1", "n1", "s1", "kd0"), n_out=8,
    )
    going = bt.prim(lambda s: s == 1, ["s1"], name="still_going")
    with bt.if_(going):
        is_neg = bt.prim(lambda v: v < 0.0, ["v"], name="is_neg")
        with bt.if_(is_neg):
            bt.call(
                "build_tree",
                ["tm", "rm", "log_u", "v", "jm1", "eps", "k3"],
                out=("tm", "rm", "d0", "d1", "th2", "n2", "s2", "kd1"),
                n_out=8,
            )
        with bt.orelse():
            bt.call(
                "build_tree",
                ["tp", "rp", "log_u", "v", "jm1", "eps", "k3"],
                out=("d0", "d1", "tp", "rp", "th2", "n2", "s2", "kd1"),
                n_out=8,
            )
        # Accept the right-half proposal with prob n2 / (n1 + n2).
        bt.prim(
            lambda k, n1, n2: jax.random.uniform(k) * (n1 + n2) < n2,
            ["kd1", "n1", "n2"], out="acc", name="subtree_accept",
        )
        bt.assign(
            "th1",
            lambda a, t1, t2: jnp.where(a, t2, t1),
            ["acc", "th1", "th2"], name="select_proposal",
        )
        bt.prim(p["uturn_ok"], ["tm", "rm", "tp", "rp"], out="ut",
                name="uturn_ok")
        bt.assign("s1", lambda s2, ut: s2 * ut, ["s2", "ut"])
        bt.assign("n1", lambda n1, n2: n1 + n2, ["n1", "n2"])
    bt.return_()
    pb.add(bt)

    # ------------------------------------------------------------------
    # nuts_step — one trajectory (the doubling loop)
    # ------------------------------------------------------------------
    st = pb.function(
        "nuts_step",
        params=["theta", "eps", "key"],
        outputs=["theta_out", "key_run"],
        param_specs={"theta": vec, "eps": F32, "key": KEY},
        output_specs={"theta_out": vec, "key_run": KEY},
    )
    st.prim(p["split3"], ["key"], out=("k_mom", "k_slice", "key_run"),
            n_out=3, name="split3")
    st.prim(p["momentum"], ["k_mom"], out="r0", name="momentum")
    st.prim(p["joint"], ["theta", "r0"], out="joint0", name="joint0")
    st.prim(p["slice_log_u"], ["k_slice", "joint0"], out="log_u",
            name="slice_log_u")
    st.copy("theta", out="tm")
    st.copy("r0", out="rm")
    st.copy("theta", out="tp")
    st.copy("r0", out="rp")
    st.copy("theta", out="theta_out")
    st.const(1, jnp.int32, out="n")
    st.const(1, jnp.int32, out="s")
    st.const(0, jnp.int32, out="j")
    with st.while_(
        lambda s, j: jnp.logical_and(s == 1, j < settings.max_tree_depth),
        ["s", "j"],
    ):
        st.prim(p["split4"], ["key_run"],
                out=("k_dir", "k_tree", "k_acc", "key_run"), n_out=4,
                name="split4")
        st.prim(p["direction"], ["k_dir"], out="v", name="direction")
        is_neg = st.prim(lambda v: v < 0.0, ["v"], name="is_neg")
        with st.if_(is_neg):
            st.call(
                "build_tree",
                ["tm", "rm", "log_u", "v", "j", "eps", "k_tree"],
                out=("tm", "rm", "d0", "d1", "th1", "n1", "s1", "kd"),
                n_out=8,
            )
        with st.orelse():
            st.call(
                "build_tree",
                ["tp", "rp", "log_u", "v", "j", "eps", "k_tree"],
                out=("d0", "d1", "tp", "rp", "th1", "n1", "s1", "kd"),
                n_out=8,
            )
        # Metropolis-within-slice: accept with prob min(1, n1/n).
        st.prim(
            lambda k, s1, n1, n: jnp.logical_and(
                s1 == 1, jax.random.uniform(k) * n < n1
            ),
            ["k_acc", "s1", "n1", "n"], out="acc", name="trajectory_accept",
        )
        st.assign(
            "theta_out",
            lambda a, to, t1: jnp.where(a, t1, to),
            ["acc", "theta_out", "th1"], name="select_sample",
        )
        st.prim(p["uturn_ok"], ["tm", "rm", "tp", "rp"], out="ut",
                name="uturn_ok")
        st.assign("s", lambda s1, ut: s1 * ut, ["s1", "ut"])
        st.assign("n", lambda n, n1: n + n1, ["n", "n1"])
        st.assign("j", lambda j: j + 1, ["j"])
    st.return_()
    pb.add(st)

    # ------------------------------------------------------------------
    # nuts_chain — num_steps trajectories with running moments (main)
    # ------------------------------------------------------------------
    ch = pb.function(
        "nuts_chain",
        params=["theta0", "eps", "key"],
        outputs=["theta", "sum_theta", "sum_sq"],
        param_specs={"theta0": vec, "eps": F32, "key": KEY},
        output_specs={"theta": vec, "sum_theta": vec, "sum_sq": vec},
    )
    ch.copy("theta0", out="theta")
    ch.copy("key", out="key_run")
    ch.const(np.zeros(target.dim, np.float32), out="sum_theta")
    ch.const(np.zeros(target.dim, np.float32), out="sum_sq")
    ch.const(0, jnp.int32, out="it")
    with ch.while_(lambda it: it < settings.num_steps, ["it"]):
        ch.call("nuts_step", ["theta", "eps", "key_run"],
                out=("theta", "key_run"), n_out=2)
        ch.assign("sum_theta", lambda s, t: s + t, ["sum_theta", "theta"])
        ch.assign("sum_sq", lambda s, t: s + t * t, ["sum_sq", "theta"])
        ch.assign("it", lambda i: i + 1, ["it"])
    ch.return_()
    pb.add(ch)

    return pb.build()


def make_nuts_kernel(
    target: Target,
    settings: NutsSettings = NutsSettings(),
    *,
    backend: str = "pc",
    batch_size: Optional[int] = None,
    max_steps: int = 1_000_000,
    use_kernel: bool = False,
    schedule: str = "earliest",
    fuse: bool = True,
    mesh=None,
    verify: bool = False,
    compact_every: Optional[int] = None,
    pgo=None,
) -> batching.AutobatchedFunction:
    """The public NUTS entry point, on the decorator-first pytree API.

    Returns a batched callable ``kernel(theta0, eps, key) -> state`` where

    * ``theta0`` is per-chain (``Batched``): ``[chains, dim]`` float32,
    * ``eps`` is the step size shared by every chain (``Shared``): a scalar,
    * ``key`` is per-chain (``Batched``): ``[chains, 2]`` uint32,

    and ``state`` is the pytree ``{"theta": [chains, dim], "sum_theta":
    [chains, dim], "sum_sq": [chains, dim]}`` of final positions and running
    moments.  With ``batch_size=None`` the chain count is inferred from
    ``theta0`` on each call; compiled artifacts are cached per batch size
    (the stack-explicit lowering is shared across all of them).

    ``schedule`` and ``fuse`` are the pc backend's dispatch knobs (see
    :mod:`repro.core.pc_vm` / :mod:`repro.core.fusion`); both are bit-exact,
    so every combination samples identical chains.  ``mesh`` (``None``, a
    device count, or a 1-D ``jax.sharding.Mesh``) shards the chain axis
    across devices — chains are embarrassingly parallel, so the only
    cross-device traffic is the VM's scalar dispatch reductions, and the
    sampled chains are bit-identical to the unsharded run.
    ``compact_every=k`` turns on occupancy-aware lane compaction every
    ``k`` VM dispatches — tree-depth divergence between chains is exactly
    the fragmentation compaction recovers; chains stay bit-identical.
    ``pgo=`` re-lowers through the profile-guided pipeline from a
    :class:`repro.obs.blockprof.BlockProfile` (or a saved profile path)
    collected on a traced run of the same kernel — still bit-exact, fewer
    dispatches (see ``tools/pgo.py``).
    """
    program = build_nuts_program(target, settings)
    vec = spec((target.dim,), jnp.float32)
    return batching.autobatch(
        program,
        in_specs=(Batched(vec), Shared(F32), Batched(KEY)),
        out_spec={"theta": "theta", "sum_theta": "sum_theta", "sum_sq": "sum_sq"},
        backend=backend,
        batch_size=batch_size,
        max_depth=recommended_max_depth(settings),
        max_steps=max_steps,
        use_kernel=use_kernel,
        schedule=schedule,
        fuse=fuse,
        mesh=mesh,
        verify=verify,
        compact_every=compact_every,
        pgo=pgo,
    )


def initial_state(
    target: Target, batch_size: int, *, eps: float, seed: int = 0
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Positional ``(theta0, eps, key)`` arguments for the NUTS kernel.

    ``eps`` is a scalar (a ``Shared`` argument of the kernel); ``theta0``
    and ``key`` carry the leading chain axis.
    """
    rng = np.random.default_rng(seed)
    theta0 = 0.1 * rng.normal(size=(batch_size, target.dim)).astype(np.float32)
    keys = jax.vmap(jax.random.PRNGKey)(
        jnp.arange(seed * 100_000, seed * 100_000 + batch_size)
    )
    return jnp.asarray(theta0), jnp.float32(eps), keys


def recommended_max_depth(settings: NutsSettings) -> int:
    """Stack slots needed: chain -> step -> tree_depth nested build_trees,
    plus one slot for the exit sentinel and one of headroom."""
    return settings.max_tree_depth + 4
