"""Hand-rewritten *iterative* NUTS in pure JAX (the expert-effort baseline).

The paper's related work (Phan & Pradhan 2019; Lao & Dillon 2019) notes that
NUTS has been manually rewritten in non-recursive form specifically so that
accelerators can run it: "One would expect such a manual effort to obtain
better performance, but its labor-intensiveness necessarily limits its
scope."  This module IS that manual effort, for direct comparison against
the mechanical autobatching of :mod:`repro.mcmc.nuts`:

* recursion is replaced by the checkpoint-stack trick: a depth-``j`` subtree
  is built as ``2**j`` consecutive leaves, with U-turn checks of every
  completed sub-subtree reconstructed from O(max_depth) stored checkpoints
  (left-edge states), using the binary structure of the leaf index;
* everything is ``lax.while_loop``/``lax.select`` so the whole multi-chain
  sampler jits into a single XLA program and is batched with ``jax.vmap``.

Semantics match slice-sampling NUTS (Hoffman & Gelman Alg. 3) with the
paper's ``steps_per_leaf`` leapfrog steps per leaf.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .nuts import DELTA_MAX, NutsSettings
from .targets import Target


class _SubtreeState(NamedTuple):
    i: jax.Array  # leaf index within the subtree
    theta: jax.Array
    r: jax.Array
    ckpt_theta: jax.Array  # [max_depth, dim] left-edge checkpoints
    ckpt_r: jax.Array
    prop: jax.Array  # reservoir-sampled proposal
    cnt: jax.Array  # slice-passing leaves seen (reservoir denominator)
    n: jax.Array  # slice count
    s: jax.Array  # 1 while no divergence / no sub-U-turn
    grads: jax.Array  # gradient evaluations (for throughput reporting)
    key: jax.Array


class _TrajState(NamedTuple):
    tm: jax.Array
    rm: jax.Array
    tp: jax.Array
    rp: jax.Array
    theta_out: jax.Array
    n: jax.Array
    s: jax.Array
    j: jax.Array
    grads: jax.Array
    key: jax.Array


def _trailing_ones(i: jax.Array) -> jax.Array:
    # popcount(i ^ (i+1)) == trailing_ones(i) + 1
    return lax.population_count(i ^ (i + 1)) - 1


def make_chain_fn(target: Target, settings: NutsSettings):
    """Returns ``chain(theta0, eps, key) -> (theta, sum, sum_sq, grads)``,
    a single-chain jittable function; ``jax.vmap`` it for batching."""
    logp = target.logp
    grad = jax.grad(logp)
    dim = target.dim
    max_depth = settings.max_tree_depth
    spl = settings.steps_per_leaf

    def leapfrog(theta, r, step):
        def body(_, carry):
            theta, r, g = carry
            r_half = r + 0.5 * step * g
            theta = theta + step * r_half
            g = grad(theta)
            r = r_half + 0.5 * step * g
            return theta, r, g

        theta, r, _ = lax.fori_loop(0, spl, body, (theta, r, grad(theta)))
        return theta, r

    def joint(theta, r):
        return logp(theta) - 0.5 * jnp.sum(r * r)

    def uturn_ok(tm, rm, tp, rp):
        d = tp - tm
        return jnp.logical_and(jnp.dot(d, rm) >= 0.0, jnp.dot(d, rp) >= 0.0)

    # ------------------------------------------------------------------
    # Iterative depth-j subtree via the checkpoint stack
    # ------------------------------------------------------------------

    def build_subtree(theta, r, log_u, v, depth, eps, key):
        num_leaves = jnp.left_shift(jnp.int32(1), depth)

        def cond(st: _SubtreeState):
            return jnp.logical_and(st.i < num_leaves, st.s == 1)

        def body(st: _SubtreeState):
            theta, r = leapfrog(st.theta, st.r, v * eps)
            jnt = joint(theta, r)
            passes = log_u <= jnt
            not_div = jnt > log_u - DELTA_MAX
            # Reservoir-sample uniformly among slice-passing leaves.
            cnt = st.cnt + passes.astype(jnp.int32)
            key, k_res = jax.random.split(st.key)
            take = jnp.logical_and(
                passes, jax.random.uniform(k_res) * cnt < 1.0
            )
            prop = jnp.where(take, theta, st.prop)
            # Checkpoint-stack U-turn checks (binary leaf-index structure).
            i = st.i
            even = (i % 2) == 0
            idx_max = lax.population_count(i >> 1)
            idx_min = idx_max - _trailing_ones(i) + 1
            row = jnp.where(even, idx_max, max_depth)  # dropped when odd
            ckpt_theta = st.ckpt_theta.at[row].set(theta, mode="drop")
            ckpt_r = st.ckpt_r.at[row].set(r, mode="drop")
            ks = jnp.arange(max_depth)
            in_range = jnp.logical_and(ks >= idx_min, ks <= idx_max)
            # d points from the minus-most to the plus-most edge.
            d = v * (theta[None, :] - st.ckpt_theta)
            turn_k = jnp.logical_or(
                jnp.einsum("kd,kd->k", d, st.ckpt_r) < 0.0,
                d @ r < 0.0,
            )
            turned = jnp.logical_and(
                jnp.logical_not(even), jnp.any(in_range & turn_k)
            )
            s = st.s * not_div.astype(jnp.int32) * (1 - turned.astype(jnp.int32))
            return _SubtreeState(
                i=i + 1,
                theta=theta,
                r=r,
                ckpt_theta=ckpt_theta,
                ckpt_r=ckpt_r,
                prop=prop,
                cnt=cnt,
                n=st.n + passes.astype(jnp.int32),
                s=s,
                grads=st.grads + spl + 1,
                key=key,
            )

        init = _SubtreeState(
            i=jnp.int32(0),
            theta=theta,
            r=r,
            ckpt_theta=jnp.zeros((max_depth, dim), jnp.float32),
            ckpt_r=jnp.zeros((max_depth, dim), jnp.float32),
            prop=theta,
            cnt=jnp.int32(0),
            n=jnp.int32(0),
            s=jnp.int32(1),
            grads=jnp.int32(0),
            key=key,
        )
        return lax.while_loop(cond, body, init)

    # ------------------------------------------------------------------
    # One trajectory (the doubling loop)
    # ------------------------------------------------------------------

    def nuts_step(theta, eps, key):
        k_mom, k_slice, key = jax.random.split(key, 3)
        r0 = jax.random.normal(k_mom, (dim,), jnp.float32)
        joint0 = joint(theta, r0)
        log_u = joint0 + jnp.log1p(-jax.random.uniform(k_slice))

        def cond(st: _TrajState):
            return jnp.logical_and(st.s == 1, st.j < max_depth)

        def body(st: _TrajState):
            k_dir, k_tree, k_acc, key = jax.random.split(st.key, 4)
            v = jnp.where(jax.random.bernoulli(k_dir), 1.0, -1.0).astype(
                jnp.float32
            )
            neg = v < 0.0
            edge_t = jnp.where(neg, st.tm, st.tp)
            edge_r = jnp.where(neg, st.rm, st.rp)
            sub = build_subtree(edge_t, edge_r, log_u, v, st.j, eps, k_tree)
            tm = jnp.where(neg, sub.theta, st.tm)
            rm = jnp.where(neg, sub.r, st.rm)
            tp = jnp.where(neg, st.tp, sub.theta)
            rp = jnp.where(neg, st.rp, sub.r)
            acc = jnp.logical_and(
                sub.s == 1, jax.random.uniform(k_acc) * st.n < sub.n
            )
            theta_out = jnp.where(acc, sub.prop, st.theta_out)
            s = sub.s * uturn_ok(tm, rm, tp, rp).astype(jnp.int32)
            return _TrajState(
                tm=tm, rm=rm, tp=tp, rp=rp,
                theta_out=theta_out,
                n=st.n + sub.n,
                s=s,
                j=st.j + 1,
                grads=st.grads + sub.grads,
                key=key,
            )

        init = _TrajState(
            tm=theta, rm=r0, tp=theta, rp=r0,
            theta_out=theta,
            n=jnp.int32(1),
            s=jnp.int32(1),
            j=jnp.int32(0),
            grads=jnp.int32(0),
            key=key,
        )
        final = lax.while_loop(cond, body, init)
        return final.theta_out, final.key, final.grads

    # ------------------------------------------------------------------
    # The chain
    # ------------------------------------------------------------------

    def chain(theta0, eps, key):
        def body(_, carry):
            theta, key, s1, s2, grads = carry
            theta, key, g = nuts_step(theta, eps, key)
            return (theta, key, s1 + theta, s2 + theta * theta, grads + g)

        zero = jnp.zeros((dim,), jnp.float32)
        theta, _, s1, s2, grads = lax.fori_loop(
            0, settings.num_steps, body, (theta0, key, zero, zero, jnp.int32(0))
        )
        return theta, s1, s2, grads

    return chain


def make_batched(target: Target, settings: NutsSettings):
    """Jitted, vmapped multi-chain iterative NUTS runner (build once).

    Mirrors the autobatched kernel's calling convention: ``theta0`` and
    ``keys`` carry the chain axis, ``eps`` is a shared scalar
    (``in_axes=None``, the hand-written analog of ``Shared``).
    """
    chain = make_chain_fn(target, settings)
    run = jax.jit(jax.vmap(chain, in_axes=(0, None, 0)))

    def batched(theta0, eps, keys):
        theta, s1, s2, grads = run(theta0, eps, keys)
        return {
            "theta": theta,
            "sum_theta": s1,
            "sum_sq": s2,
            "grads": grads,
        }

    return batched


def run_batched(
    target: Target,
    settings: NutsSettings,
    theta0: jax.Array,  # [Z, dim]
    eps: jax.Array,  # scalar (shared step size)
    keys: jax.Array,  # [Z, 2] uint32
):
    """One-shot convenience wrapper (re-traces per call; benchmarks should
    use :func:`make_batched`)."""
    return make_batched(target, settings)(theta0, eps, keys)
