"""Registry of assigned architectures (``--arch <id>``) and shapes."""
from __future__ import annotations

from . import (
    deepseek_moe_16b,
    hubert_xlarge,
    qwen1_5_32b,
    qwen2_vl_2b,
    qwen3_0_6b,
    qwen3_14b,
    qwen3_moe_235b_a22b,
    smollm_135m,
    xlstm_350m,
    zamba2_7b,
)
from .base import (
    SHAPES,
    ArchConfig,
    ShapeSpec,
    applicable_shapes,
    reduce_for_smoke,
    skipped_shapes,
)

_MODULES = [
    qwen3_0_6b,
    qwen1_5_32b,
    qwen3_14b,
    smollm_135m,
    deepseek_moe_16b,
    qwen3_moe_235b_a22b,
    xlstm_350m,
    zamba2_7b,
    hubert_xlarge,
    qwen2_vl_2b,
]

REGISTRY: dict[str, ArchConfig] = {}
for _m in _MODULES:
    _cfg = _m.config()
    REGISTRY[_cfg.name] = _cfg


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(REGISTRY)}"
        )
    return REGISTRY[name]


def get_smoke_config(name: str) -> ArchConfig:
    return reduce_for_smoke(get_config(name))


def list_archs() -> list[str]:
    return sorted(REGISTRY)


__all__ = [
    "ArchConfig",
    "ShapeSpec",
    "SHAPES",
    "REGISTRY",
    "applicable_shapes",
    "skipped_shapes",
    "get_config",
    "get_smoke_config",
    "list_archs",
    "reduce_for_smoke",
]
