"""Qwen2-VL-2B [arXiv:2409.12191; hf] — decoder backbone with M-RoPE.

Backbone only: the vision tower is a STUB (``input_specs()`` provides
precomputed patch embeddings and 3-axis M-RoPE position ids)."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-2b",
        family="vlm",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        d_ff=8960,
        vocab_size=151_936,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),  # (t, h, w) in half-head-dim units
        tie_embeddings=True,
    )
