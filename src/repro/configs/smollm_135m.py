"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M; hf] — llama-arch small."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="smollm-135m",
        family="dense",
        num_layers=30,
        d_model=576,
        num_heads=9,
        num_kv_heads=3,
        d_ff=1536,
        vocab_size=49_152,
        rope_theta=10_000.0,
        tie_embeddings=True,
    )
