"""DeepSeekMoE-16B [arXiv:2401.06066; hf] — fine-grained MoE:
2 shared + 64 routed top-6 experts; first layer dense."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b",
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,  # routed-expert hidden size (fine-grained)
        vocab_size=102_400,
        num_experts=64,
        num_shared_experts=2,
        top_k=6,
        moe_d_ff=1408,
        dense_d_ff=10_944,  # layer-0 dense FFN
        first_dense_layers=1,
        moe_renorm_topk=False,  # deepseek scales by raw softmax probs
        rope_theta=10_000.0,
    )
