"""Qwen1.5-32B [hf:Qwen/Qwen1.5 family; hf] — dense, QKV bias, MHA."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=40,
        d_ff=27_392,
        vocab_size=152_064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
    )
