"""HuBERT-XLarge [arXiv:2106.07447; unverified] — encoder-only backbone
(same arch as wav2vec2-large x2); modality frontend is a STUB:
``input_specs()`` supplies precomputed frame embeddings."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="hubert-xlarge",
        family="audio",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        d_ff=5120,
        vocab_size=504,  # HuBERT cluster codebook
        causal=False,
        is_encoder=True,
        norm="ln",
        norm_eps=1e-5,
    )
