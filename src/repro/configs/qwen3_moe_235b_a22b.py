"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-30B-A3B family; hf] —
128 routed experts, top-8, no shared expert, qk_norm, GQA kv=4."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        d_ff=1536,  # routed-expert hidden size
        vocab_size=151_936,
        head_dim=128,
        qk_norm=True,
        num_experts=128,
        num_shared_experts=0,
        top_k=8,
        moe_d_ff=1536,
        moe_renorm_topk=True,
        rope_theta=1_000_000.0,
    )
