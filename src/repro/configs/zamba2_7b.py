"""Zamba2-7B [arXiv:2411.15242; unverified] — Mamba2 backbone + shared
attention blocks (single weight copy applied periodically)."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b",
        family="hybrid",
        num_layers=81,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        d_ff=14_336,  # shared block FFN
        vocab_size=32_000,
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=128,
        shared_attn_every=6,  # shared attn+FFN block applied every 6 layers
        long_context_window=4096,  # sliding-window KV in long-context serve
    )
