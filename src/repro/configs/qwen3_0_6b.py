"""Qwen3-0.6B [hf:Qwen/Qwen3-8B family; hf] — dense, qk_norm, GQA."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-0.6b",
        family="dense",
        num_layers=28,
        d_model=1024,
        num_heads=16,
        num_kv_heads=8,
        d_ff=3072,
        vocab_size=151_936,
        head_dim=128,  # qwen3 uses explicit head_dim=128
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )
