"""xLSTM-350M [arXiv:2405.04517; unverified] — sLSTM + mLSTM blocks.

The public paper gives block ratios rather than a fixed 350M layout; we use
a 3:1 mLSTM:sLSTM cycle over 24 layers (noted in DESIGN.md)."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-350m",
        family="ssm",
        num_layers=24,
        d_model=1024,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,  # xLSTM blocks carry their own up-projection (expand=2)
        vocab_size=50_304,
        ssm_expand=2,
        ssm_head_dim=256,  # d_inner (2048) / num_heads (4) per-head width
        ssm_chunk=128,
        xlstm_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    )
