"""Architecture + shape configuration system.

Every assigned architecture is an :class:`ArchConfig`; every workload shape
is a :class:`ShapeSpec`.  The cross product (with per-family applicability
rules) defines the dry-run / roofline matrix.

Families
--------
``dense``   decoder-only transformer (GQA, RoPE, SwiGLU)
``moe``     dense + mixture-of-experts FFN (shared + routed top-k)
``ssm``     xLSTM (mLSTM + sLSTM blocks)
``hybrid``  Mamba2 backbone + shared attention blocks (Zamba2)
``audio``   encoder-only transformer backbone (HuBERT); stub frame frontend
``vlm``     decoder transformer with M-RoPE (Qwen2-VL); stub patch frontend
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads
    # attention details
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    causal: bool = True
    is_encoder: bool = False
    norm: str = "rms"  # rms | ln
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # M-RoPE (vlm): half-head-dim split into (temporal, height, width)
    mrope_sections: tuple[int, ...] = ()
    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # routed-expert hidden size (fine-grained)
    dense_d_ff: int = 0  # FFN size of the leading dense layers (deepseek)
    first_dense_layers: int = 0
    moe_renorm_topk: bool = True
    capacity_factor: float = 1.25
    # SSM (mamba2 in hybrid; mLSTM/sLSTM in ssm family)
    ssm_state: int = 0  # N (mamba2) — 0 for non-ssm
    ssm_head_dim: int = 64  # P
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 128  # chunked-scan block length
    # hybrid (zamba2): apply the single shared attention block every k layers
    shared_attn_every: int = 0
    # xlstm: per-layer block kinds cycle through this pattern
    xlstm_pattern: tuple[str, ...] = ()  # e.g. ("mlstm","mlstm","mlstm","slstm")
    # long-context serving: sliding window for attention KV in long_500k
    long_context_window: int = 4096
    # query-chunk size for row-blocked attention (memory-bounded softmax)
    attn_q_chunk: int = 512
    # KV-cache storage: "compute" (=compute_dtype) or "int8" (quantized
    # per (position, head) with bf16 scales — halves decode cache bytes)
    kv_cache_dtype: str = "compute"
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def supports_decode(self) -> bool:
        return not self.is_encoder

    @property
    def subquadratic(self) -> bool:
        """Can this arch serve 500k-token contexts (O(1)/O(w) per step)?"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline checks)."""
        d, v = self.d_model, self.vocab_size
        dh = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                attn = d * dh * (self.num_heads + 2 * self.num_kv_heads)
                attn += self.num_heads * dh * d  # out proj
                if self.qkv_bias:
                    attn += dh * (self.num_heads + 2 * self.num_kv_heads)
                total += attn
                total += self.ffn_params(i)
                total += 2 * d  # norms
            elif kind == "mamba":
                d_in = self.ssm_expand * d
                nheads = d_in // self.ssm_head_dim
                # in_proj: d -> [z(d_in), x(d_in), B(N), C(N), dt(H)]
                total += d * (2 * d_in + 2 * self.ssm_state + nheads)
                total += d_in * d  # out proj
                total += self.ssm_conv_width * d_in  # conv
                total += 2 * d
            elif kind in ("mlstm", "slstm"):
                d_in = self.ssm_expand * d
                total += d * d_in * 4 + d_in * d + 2 * d
        if self.family == "hybrid" and self.shared_attn_every:
            dh_s = self.resolved_head_dim
            shared = d * dh_s * (self.num_heads + 2 * self.num_kv_heads)
            shared += self.num_heads * dh_s * d
            shared += d * self.d_ff * 3
            total += shared
        return total

    def ffn_params(self, layer_idx: int) -> int:
        d = self.d_model
        if self.family == "moe" and layer_idx >= self.first_dense_layers:
            routed = self.num_experts * 3 * d * self.moe_d_ff
            shared = self.num_shared_experts * 3 * d * self.moe_d_ff
            router = d * self.num_experts
            return routed + shared + router
        if self.family == "moe":
            return 3 * d * self.dense_d_ff
        if self.norm == "ln":  # hubert-style GELU MLP (2 mats)
            return 2 * d * self.d_ff
        return 3 * d * self.d_ff  # SwiGLU

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed top-k active)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        for i in range(self.first_dense_layers, self.num_layers):
            routed_all = self.num_experts * 3 * d * self.moe_d_ff
            routed_act = self.top_k * 3 * d * self.moe_d_ff
            total -= routed_all - routed_act
        return total

    def layer_kind(self, i: int) -> str:
        if self.family == "ssm":
            return self.xlstm_pattern[i % len(self.xlstm_pattern)]
        if self.family == "hybrid":
            return "mamba"
        return "attn"


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list[ShapeSpec]:
    """Per-instruction applicability: encoders skip decode shapes;
    ``long_500k`` only for sub-quadratic (ssm/hybrid) archs."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"]]
    if cfg.supports_decode:
        out.append(SHAPES["decode_32k"])
        if cfg.subquadratic:
            out.append(SHAPES["long_500k"])
    return out


def skipped_shapes(cfg: ArchConfig) -> dict[str, str]:
    skip: dict[str, str] = {}
    if not cfg.supports_decode:
        skip["decode_32k"] = "encoder-only arch has no decode step"
        skip["long_500k"] = "encoder-only arch has no decode step"
    elif not cfg.subquadratic:
        skip["long_500k"] = (
            "pure full-attention arch; 500k decode needs sub-quadratic mixing"
        )
    return skip


def reduce_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    kw: dict = dict(
        num_layers=min(cfg.num_layers, 4 if cfg.family == "hybrid" else 2),
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        d_ff=128,
        vocab_size=256,
        head_dim=16 if cfg.head_dim else 0,
        compute_dtype="float32",
    )
    if cfg.family == "moe":
        kw.update(
            num_experts=4, top_k=2, moe_d_ff=32,
            dense_d_ff=128 if cfg.dense_d_ff else 0,
            num_shared_experts=min(cfg.num_shared_experts, 1),
            first_dense_layers=min(cfg.first_dense_layers, 1),
        )
    if cfg.ssm_state:
        kw.update(ssm_state=8, ssm_head_dim=8, ssm_chunk=16)
    if cfg.family == "ssm":
        kw.update(
            ssm_chunk=16, ssm_head_dim=8,
            xlstm_pattern=("mlstm", "slstm"), num_layers=2,
        )
    if cfg.family == "hybrid":
        kw.update(shared_attn_every=2)
    if cfg.mrope_sections:
        kw.update(mrope_sections=(2, 3, 3))
    return replace(cfg, name=cfg.name + "-smoke", **kw)
