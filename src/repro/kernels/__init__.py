"""Pallas TPU kernels for the perf-critical hot spots.

``stack_ops``       — the PC VM's batched stack push/peek (the paper's
                      gather/scatter hot spot), driven by scalar-prefetched
                      stack pointers so each lane moves only its own row.
``flash_attention`` — causal GQA attention for train/prefill.
``flash_decode``    — single-token attention over long KV caches (decode).

Each package ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit wrapper with CPU interpret fallback) and ``ref.py`` (pure-jnp oracle);
tests sweep shapes/dtypes and assert allclose in interpret mode.
"""
from . import flash_attention, flash_decode, stack_ops  # noqa: F401
