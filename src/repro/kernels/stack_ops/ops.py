"""Jitted wrappers for the stack kernels, handling arbitrary feature
shapes (the VM pushes values of any rank) and the CPU/interpret fallback.

On CPU we *validate* the Pallas kernels in interpret mode; the VM's
default (`use_kernel=False`) uses the jnp reference, which XLA compiles
to the same scatter/gather it would on TPU.  `use_kernel=True` routes
through `pallas_call` (interpret on CPU, compiled on TPU).

Under lane sharding (`VMConfig.mesh`) stack traffic stays strictly
per-lane, so :func:`shard_local` wraps the same wrappers in `shard_map`:
each device runs one `pallas_call` over its own lane slice — no
cross-device traffic, bit-exact with the unsharded kernel and with the
XLA scatter/gather path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import kernel, ref


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _flatten_features(x: jax.Array, lead: int):
    feat = x.shape[lead:]
    f = 1
    for s in feat:
        f *= s
    return x.reshape(x.shape[:lead] + (max(f, 1),)), feat


def masked_push(stack: jax.Array, ptr: jax.Array, val: jax.Array,
                mask: jax.Array) -> jax.Array:
    """stack: [D, Z, ...]; ptr/mask: [Z]; val: [Z, ...]."""
    d, z = stack.shape[:2]
    s2, feat = _flatten_features(stack, 2)
    v2, _ = _flatten_features(val, 1)
    out = kernel.masked_push(s2, ptr, v2, mask, interpret=not _is_tpu())
    return out.reshape(stack.shape)


def masked_peek(stack: jax.Array, ptr: jax.Array) -> jax.Array:
    """stack: [D, Z, ...]; ptr: [Z] -> [Z, ...]."""
    d, z = stack.shape[:2]
    s2, feat = _flatten_features(stack, 2)
    out = kernel.masked_peek(s2, ptr, interpret=not _is_tpu())
    return out.reshape((z,) + stack.shape[2:])


@functools.lru_cache(maxsize=None)
def shard_local(mesh):
    """Shard-local ``(masked_push, masked_peek)`` for a 1-D lane mesh.

    Each returned callable has the same signature/semantics as the
    module-level wrapper it wraps, but runs one kernel per device over
    that device's lane slice: stacks are ``[depth, lanes, ...]`` with the
    lane axis sharded, pointers/masks/values shard their leading lane
    axis, and feature dims stay unpartitioned.  ``check_rep=False``
    because Pallas calls don't participate in shard_map's replication
    inference.  Cached per mesh so VM instances and tests share the
    wrapped callables (and their jit caches).
    """
    from jax.experimental.shard_map import shard_map

    axis = mesh.axis_names[0]
    push = shard_map(
        masked_push,
        mesh=mesh,
        in_specs=(P(None, axis), P(axis), P(axis), P(axis)),
        out_specs=P(None, axis),
        check_rep=False,
    )
    peek = shard_map(
        masked_peek,
        mesh=mesh,
        in_specs=(P(None, axis), P(axis)),
        out_specs=P(axis),
        check_rep=False,
    )
    return push, peek
