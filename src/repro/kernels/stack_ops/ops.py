"""Jitted wrappers for the stack kernels, handling arbitrary feature
shapes (the VM pushes values of any rank) and the CPU/interpret fallback.

On CPU we *validate* the Pallas kernels in interpret mode; the VM's
default (`use_kernel=False`) uses the jnp reference, which XLA compiles
to the same scatter/gather it would on TPU.  `use_kernel=True` routes
through `pallas_call` (interpret on CPU, compiled on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _flatten_features(x: jax.Array, lead: int):
    feat = x.shape[lead:]
    f = 1
    for s in feat:
        f *= s
    return x.reshape(x.shape[:lead] + (max(f, 1),)), feat


def masked_push(stack: jax.Array, ptr: jax.Array, val: jax.Array,
                mask: jax.Array) -> jax.Array:
    """stack: [D, Z, ...]; ptr/mask: [Z]; val: [Z, ...]."""
    d, z = stack.shape[:2]
    s2, feat = _flatten_features(stack, 2)
    v2, _ = _flatten_features(val, 1)
    out = kernel.masked_push(s2, ptr, v2, mask, interpret=not _is_tpu())
    return out.reshape(stack.shape)


def masked_peek(stack: jax.Array, ptr: jax.Array) -> jax.Array:
    """stack: [D, Z, ...]; ptr: [Z] -> [Z, ...]."""
    d, z = stack.shape[:2]
    s2, feat = _flatten_features(stack, 2)
    out = kernel.masked_peek(s2, ptr, interpret=not _is_tpu())
    return out.reshape((z,) + stack.shape[2:])
