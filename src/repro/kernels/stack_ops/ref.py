"""Pure-jnp oracle for the batched stack operations (paper Alg. 2's
PUSH/POP data movement — the hot spot of the PC VM)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_push(stack: jax.Array, ptr: jax.Array, val: jax.Array,
                mask: jax.Array) -> jax.Array:
    """stack: [D, Z, F...]; ptr, mask: [Z]; val: [Z, F...].

    For active rows z, write ``val[z]`` at depth ``ptr[z]``.
    """
    z = stack.shape[1]
    d = stack.shape[0]
    ok = jnp.logical_and(mask, jnp.logical_and(ptr >= 0, ptr < d))
    rows = jnp.where(ok, ptr, d)  # OOB rows dropped (incl. negatives)
    return stack.at[rows, jnp.arange(z)].set(val, mode="drop")


def masked_peek(stack: jax.Array, ptr: jax.Array) -> jax.Array:
    """stack: [D, Z, F...]; ptr: [Z] -> [Z, F...] (stack[ptr[z], z])."""
    z = stack.shape[1]
    return stack[jnp.clip(ptr, 0, stack.shape[0] - 1), jnp.arange(z)]
