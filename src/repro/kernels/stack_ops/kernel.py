"""Pallas TPU kernels for the PC VM's batched stack traffic.

The paper identifies per-variable stack pushes/pops as the cost of
materializing recursion: a push scatters each active lane's value to its
own depth; a pop/peek gathers from per-lane depths.  XLA lowers these to
generic scatter/gather, which on TPU serializes badly.  The TPU-native
formulation used here drives the data movement from *scalar-prefetched*
stack pointers: the grid iterates over batch lanes, and each lane's
``BlockSpec`` index_map picks exactly the ``[1, 1, F]`` stack row addressed
by ``ptr[z]`` — so each push/peek moves only ``F`` elements per lane
between HBM and VMEM (the minimum), with no scatter at all.

Layout note: the feature axis is last (lane-contiguous, ideally a multiple
of 128); depth × batch are leading so a lane's row is a contiguous stripe.
Masked pushes select between the new value and the resident row inside
VMEM (select is free on the VPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# push
# ---------------------------------------------------------------------------


def _push_kernel(ptr_ref, mask_ref, val_ref, row_in_ref, row_out_ref):
    z = pl.program_id(0)
    active = mask_ref[z]
    # val/row blocks are [1, F] for this lane's target depth.
    new = jnp.where(active, val_ref[...], row_in_ref[...])
    row_out_ref[...] = new


def masked_push(stack: jax.Array, ptr: jax.Array, val: jax.Array,
                mask: jax.Array, *, interpret: bool = True) -> jax.Array:
    """stack: [D, Z, F]; ptr, mask: [Z]; val: [Z, F]."""
    d, z, f = stack.shape
    clipped = jnp.clip(ptr, 0, d - 1).astype(jnp.int32)
    # Drop pushes whose pointer is out of range (VM guards this anyway).
    mask = jnp.logical_and(mask, jnp.logical_and(ptr >= 0, ptr < d))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # ptr, mask
        grid=(z,),
        in_specs=[
            pl.BlockSpec((1, f), lambda i, ptr, mask: (i, 0)),  # val row
            pl.BlockSpec(  # resident stack row at [ptr[i], i]
                (1, 1, f), lambda i, ptr, mask: (ptr[i], i, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, f), lambda i, ptr, mask: (ptr[i], i, 0)
        ),
    )
    fn = pl.pallas_call(
        _push_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(stack.shape, stack.dtype),
        # operand order includes the scalar-prefetch args: the stack (arg 3)
        # aliases the output buffer, so unwritten rows are never copied.
        input_output_aliases={3: 0},
        interpret=interpret,
    )
    return fn(clipped, mask, val.reshape(z, f).astype(stack.dtype), stack)


# ---------------------------------------------------------------------------
# peek (pop's data movement; pointer arithmetic stays in the VM)
# ---------------------------------------------------------------------------


def _peek_kernel(ptr_ref, row_ref, out_ref):
    out_ref[...] = row_ref[0]


def masked_peek(stack: jax.Array, ptr: jax.Array, *,
                interpret: bool = True) -> jax.Array:
    """stack: [D, Z, F]; ptr: [Z] -> [Z, F] = stack[ptr[z], z]."""
    d, z, f = stack.shape
    clipped = jnp.clip(ptr, 0, d - 1).astype(jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(z,),
        in_specs=[
            pl.BlockSpec((1, 1, f), lambda i, ptr: (ptr[i], i, 0)),
        ],
        out_specs=pl.BlockSpec((1, f), lambda i, ptr: (i, 0)),
    )
    fn = pl.pallas_call(
        _peek_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((z, f), stack.dtype),
        interpret=interpret,
    )
    return fn(clipped, stack)
