"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def decode_attention(
    q: jax.Array,  # [B, H, Dh] (one new token per sequence)
    k: jax.Array,  # [B, W, Hkv, Dh]
    v: jax.Array,  # [B, W, Hkv, Dh]
    count: jax.Array,  # [B] number of valid cache entries
) -> jax.Array:
    b, h, dh = q.shape
    w, hk = k.shape[1], k.shape[2]
    g = h // hk
    qg = q.reshape(b, hk, g, dh)
    s = jnp.einsum(
        "bkgd,bwkd->bkgw", qg, k, preferred_element_type=jnp.float32
    ) / np.sqrt(dh)
    valid = jnp.arange(w)[None] < count[:, None]  # [B, W]
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgw,bwkd->bkgd", p.astype(v.dtype), v)
    return out.reshape(b, h, dh)
