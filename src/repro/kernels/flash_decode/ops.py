"""Jitted wrapper for decode attention ([B, H, Dh] query layout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention(
    q: jax.Array,  # [B, H, Dh]
    k: jax.Array,  # [B, W, Hkv, Dh]
    v: jax.Array,
    count: jax.Array,  # [B]
    *,
    block_k: int = 256,
) -> jax.Array:
    b, h, dh = q.shape
    hk = k.shape[2]
    g = h // hk
    qg = q.reshape(b, hk, g, dh)
    kt = jnp.swapaxes(k, 1, 2)  # [B, Hkv, W, Dh]
    vt = jnp.swapaxes(v, 1, 2)
    out = kernel.decode_attention(
        qg, kt, vt, count, block_k=block_k, interpret=not _is_tpu()
    )
    return out.reshape(b, h, dh)
