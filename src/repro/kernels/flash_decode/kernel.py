"""Decode attention (one query token vs a long KV cache), Pallas TPU.

Decode is bandwidth-bound: the whole KV cache streams HBM -> VMEM once per
step while compute is a [G, bk] matvec-like product per group.  The kernel
therefore tiles over ``(B, Hkv, nk)`` — all ``G`` query heads of one KV
group ride along in a single ``[G, Dh]`` tile so each KV byte is read
exactly once per group — and carries the online-softmax running (max, sum,
acc) in VMEM scratch across the kv-block axis.  Per-sequence cache
validity (``count``) arrives via scalar prefetch and masks the tail block.

On a real pod, the ``W`` axis additionally shards over the ``model`` mesh
axis (split-K); partial (m, l, acc) triples then combine with one small
all-gather — the lowering used by ``long_500k``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# jax renamed TPUCompilerParams -> CompilerParams; accept either so the
# kernels import (and run in interpret mode) across the supported range.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                           getattr(pltpu, "TPUCompilerParams", None))


def _decode_kernel(count_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, bk: int, nk: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    count = count_ref[b]
    # Skip blocks entirely past the valid region.
    @pl.when(j * bk < count)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [G, dh]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, dh]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # [G, bk]
        cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < count, s, NEG_INF)
        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = jnp.broadcast_to(
            l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True),
            l_ref.shape,
        )
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(jnp.float32), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30)
        ).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,  # [B, Hkv, G, Dh]
    k: jax.Array,  # [B, Hkv, W, Dh]
    v: jax.Array,
    count: jax.Array,  # [B] int32
    *,
    block_k: int = 256,
    interpret: bool = True,
) -> jax.Array:
    b, hk, g, dh = q.shape
    w = k.shape[2]
    bk = min(block_k, w)
    assert w % bk == 0, (w, bk)
    nk = w // bk
    scale = 1.0 / np.sqrt(dh)
    kernel = functools.partial(_decode_kernel, bk=bk, nk=nk, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # count
        grid=(b, hk, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda b_, h_, j, c: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h_, j, c: (b_, h_, j, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h_, j, c: (b_, h_, j, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, dh), lambda b_, h_, j, c: (b_, h_, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hk, g, dh), q.dtype),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(count.astype(jnp.int32), q, k, v)
