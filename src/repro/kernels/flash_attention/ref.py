"""Pure-jnp oracle for causal GQA flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True) -> jax.Array:
    """q: [B, S, H, Dh]; k, v: [B, T, Hkv, Dh] -> [B, S, H, Dh]."""
    b, s, h, dh = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = h // hk
    qg = q.reshape(b, s, hk, g, dh)
    scores = jnp.einsum(
        "bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32
    ) / np.sqrt(dh)
    if causal:
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, h, dh)
