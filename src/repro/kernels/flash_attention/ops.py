"""Jitted wrapper: [B, S, H, Dh] layout in/out, CPU interpret fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    """q: [B, S, H, Dh]; k, v: [B, T, Hkv, Dh] -> [B, S, H, Dh]."""
    qt = jnp.swapaxes(q, 1, 2)  # [B, H, S, Dh]
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = kernel.flash_attention(
        qt, kt, vt, causal=causal, block_q=block_q, block_k=block_k,
        interpret=not _is_tpu(),
    )
    return jnp.swapaxes(out, 1, 2)
