"""Causal GQA flash attention, Pallas TPU.

Tiling: grid ``(B, H, nq, nk)`` with the kv axis innermost ("arbitrary"
semantics — it carries the online-softmax recurrence through VMEM
scratch).  Each step loads a ``[bq, Dh]`` query tile and a ``[bk, Dh]``
key/value tile into VMEM, runs the ``[bq, bk]`` logit matmul on the MXU in
f32, and maintains running (max, sum, acc) per query row.  GQA is handled
structurally: the key/value ``BlockSpec`` index_map divides the query-head
index by the group size, so KV tiles are fetched once per group from HBM.

Causality is exploited two ways: fully-masked tiles are skipped
(``pl.when`` on the tile coordinates), and the diagonal tile applies the
triangular mask only where needed.  Block sizes default to 128 x 128 —
MXU-aligned (multiples of 128 in both contraction and lane dims).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# jax renamed TPUCompilerParams -> CompilerParams; accept either so the
# kernels import (and run in interpret mode) across the supported range.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                           getattr(pltpu, "TPUCompilerParams", None))


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, nk: int, scale: float, causal: bool):
    iq = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Skip tiles strictly above the diagonal (no valid positions).
    live = jnp.logical_or(not causal, j * bk < (iq + 1) * bq)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, dh]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, dh]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # [bq, bk]
        if causal:
            rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[:, :1]  # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)  # [bq, 1]
        l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == nk - 1)
    def _finish():
        l = l_ref[:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(
            o_ref.dtype
        )


def flash_attention(
    q: jax.Array,  # [B, H, S, Dh]
    k: jax.Array,  # [B, Hkv, T, Dh]
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, h, s, dh = q.shape
    hk, t = k.shape[1], k.shape[2]
    g = h // hk
    bq = min(block_q, s)
    bk = min(block_k, t)
    assert s % bq == 0 and t % bk == 0, (s, t, bq, bk)
    nq, nk = s // bq, t // bk
    scale = 1.0 / np.sqrt(dh)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nk=nk, scale=scale, causal=causal
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec(
                (1, 1, bk, dh), lambda b_, h_, i, j: (b_, h_ // g, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, dh), lambda b_, h_, i, j: (b_, h_ // g, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, dh), lambda b_, h_, i, j: (b_, h_, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),  # running max
            pltpu.VMEM((bq, 128), jnp.float32),  # running sum
            pltpu.VMEM((bq, dh), jnp.float32),  # accumulator
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
