"""Autobatched generation engine: the serving loop IS a program in the
paper's IR, executed by the program-counter VM.

Each batch lane owns a queue of requests.  The per-lane program is plain
control flow::

    for each request in my queue:          # outer while
        reset cache;                        # masked zeroing
        while t < prompt_len: decode(...)   # streaming prefill
        while not EOS and n < max_new:      # generation loop
            emit token; decode(...)

Lanes diverge (different prompt lengths, different stop times, different
request counts) and the VM executes whichever block the earliest lanes
wait on, masking the rest — continuous batching falls out of Algorithm 2
instead of bespoke scheduler code.  Because the whole engine is ONE
``lax.while_loop`` program, it compiles end-to-end with XLA: there are no
host round-trips between tokens (the paper's headline claim, applied to
serving).

The model's ``decode_step`` enters the program as a single *batched*
primitive; its KV/state cache leaves are ordinary VM variables (the
program is loop-only, so the VM allocates no stacks for them — paper
optimization iii).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batching, frontend, ir
from repro.core.frontend import spec
from repro.models.transformer import Model

KEY = spec((2,), jnp.uint32)
I32 = spec((), jnp.int32)
BOOL = spec((), jnp.bool_)


@dataclass(frozen=True)
class EngineConfig:
    lanes: int  # batch width of the VM (concurrent sequences)
    max_context: int  # KV/cache window
    max_prompt_len: int
    max_new_tokens: int
    requests_per_lane: int
    eos_id: int = 0
    temperature: float = 0.0
    backend: str = "pc"  # pc | local | local_eager
    # Lane sharding (pc backend): None, a device count, or a 1-D Mesh.
    # Lanes are independent request queues, so sharding them across devices
    # is multi-device continuous batching — each device serves lanes/n
    # queues, and the VM's dispatch reductions are the only cross-device
    # traffic per token.  ``lanes`` must divide across the mesh.
    mesh: Any = None


def _cache_layout(model: Model, window: int):
    """Find each cache leaf's batch axis by differencing two batch sizes."""
    c1 = jax.eval_shape(lambda: model.init_cache(1, window))
    c2 = jax.eval_shape(lambda: model.init_cache(2, window))
    l1, treedef = jax.tree_util.tree_flatten(c1)
    l2 = jax.tree_util.tree_flatten(c2)[0]
    axes, member_specs = [], []
    for a, b in zip(l1, l2):
        diff = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                if x != y]
        assert len(diff) == 1, f"ambiguous batch axis for {a.shape}"
        ax = diff[0]
        axes.append(ax)
        shape = a.shape[:ax] + a.shape[ax + 1:]
        member_specs.append(jax.ShapeDtypeStruct(shape, a.dtype))
    return treedef, axes, member_specs


class GenerationEngine:
    def __init__(self, model: Model, params: Any, cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.treedef, self.axes, self.member_specs = _cache_layout(
            model, cfg.max_context
        )
        self.program = self._build_program()
        # The engine program is loop-only, so its inputs are all per-lane
        # (Batched) by default; outputs restructure into a result pytree.
        self.batched = batching.autobatch(
            self.program,
            out_spec={"tokens": "out", "lengths": "olens"},
            backend=cfg.backend,
            batch_size=cfg.lanes,
            max_depth=4,
            max_steps=2_000_000,
            mesh=cfg.mesh,
        )

    # ------------------------------------------------------------------

    def _decode_fn(self):
        model, params = self.model, self.params
        axes, treedef = self.axes, self.treedef
        temp = self.cfg.temperature

        def decode(token, pos, key, *leaves):
            """Batched primitive: one model step for the whole batch."""
            cache = jax.tree_util.tree_unflatten(
                treedef, [jnp.moveaxis(l, 0, ax) for l, ax in
                          zip(leaves, axes)]
            )
            logits, new_cache = model.decode_step(params, cache, token, pos)
            keys = jax.vmap(lambda k: tuple(jax.random.split(k)))(key)
            if temp == 0.0:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                tok = jax.vmap(
                    lambda k, lg: jax.random.categorical(k, lg / temp)
                )(keys[1], logits).astype(jnp.int32)
            new_leaves = [
                jnp.moveaxis(l, ax, 0)
                for l, ax in zip(jax.tree_util.tree_flatten(new_cache)[0],
                                 axes)
            ]
            return (tok, keys[0], *new_leaves)

        return decode

    def _build_program(self) -> ir.Program:
        cfg = self.cfg
        n_leaves = len(self.member_specs)
        leaf_vars = [f"cache{i}" for i in range(n_leaves)]
        prompts_spec = spec(
            (cfg.requests_per_lane, cfg.max_prompt_len), jnp.int32
        )
        plens_spec = spec((cfg.requests_per_lane,), jnp.int32)
        out_spec = spec(
            (cfg.requests_per_lane, cfg.max_new_tokens), jnp.int32
        )
        olens_spec = spec((cfg.requests_per_lane,), jnp.int32)

        pb = frontend.ProgramBuilder(main="generate")
        fb = pb.function(
            "generate",
            params=["prompts", "plens", "n_req", "key"],
            outputs=["out", "olens"],
            param_specs={
                "prompts": prompts_spec, "plens": plens_spec,
                "n_req": I32, "key": KEY,
            },
            output_specs={"out": out_spec, "olens": olens_spec},
        )
        decode = self._decode_fn()
        eos = cfg.eos_id

        fb.const(np.zeros((cfg.requests_per_lane, cfg.max_new_tokens),
                          np.int32), out="out")
        fb.const(np.zeros((cfg.requests_per_lane,), np.int32), out="olens")
        fb.const(0, jnp.int32, out="req")
        fb.const(0, jnp.int32, out="tok")
        # ---- outer loop over this lane's request queue ----
        with fb.while_(lambda req, n_req: req < n_req, ["req", "n_req"]):
            # reset per-request state (masked, per-lane)
            for v, sp in zip(leaf_vars, self.member_specs):
                fb.const(np.zeros(sp.shape, sp.dtype), out=v)
            fb.const(0, jnp.int32, out="pos")
            fb.const(0, jnp.int32, out="t")
            fb.assign("plen", lambda plens, req: plens[req],
                      ["plens", "req"], name="plen")
            # ---- streaming prefill ----
            with fb.while_(lambda t, plen: t < plen, ["t", "plen"]):
                fb.assign("ptok",
                          lambda prompts, req, t: prompts[req, t],
                          ["prompts", "req", "t"], name="read_prompt")
                fb.prim(
                    decode, ["ptok", "pos", "key", *leaf_vars],
                    out=("tok", "key", *leaf_vars),
                    n_out=2 + n_leaves,
                    name="decode", batched=True, tag="decode",
                )
                fb.assign("pos", lambda p: p + 1, ["pos"])
                fb.assign("t", lambda t: t + 1, ["t"])
            # ---- generation loop ----
            fb.const(0, jnp.int32, out="n")
            fb.const(False, jnp.bool_, out="done")
            with fb.while_(
                lambda done, n: jnp.logical_and(
                    jnp.logical_not(done), n < cfg.max_new_tokens
                ),
                ["done", "n"],
            ):
                fb.assign(
                    "out",
                    lambda out, req, n, tok: out.at[req, n].set(tok),
                    ["out", "req", "n", "tok"], name="emit",
                )
                fb.assign("n", lambda n: n + 1, ["n"])
                fb.assign("done", lambda tok: tok == eos, ["tok"],
                          name="check_eos")
                fb.prim(
                    decode, ["tok", "pos", "key", *leaf_vars],
                    out=("tok", "key", *leaf_vars),
                    n_out=2 + n_leaves,
                    name="decode", batched=True, tag="decode",
                )
                fb.assign("pos", lambda p: p + 1, ["pos"])
            fb.assign("olens", lambda ol, req, n: ol.at[req].set(n),
                      ["olens", "req", "n"], name="store_len")
            fb.assign("req", lambda r: r + 1, ["req"])
        fb.return_()
        pb.add(fb)
        return pb.build()

    # ------------------------------------------------------------------

    def generate(self, prompts: np.ndarray, prompt_lens: np.ndarray,
                 n_req: Optional[np.ndarray] = None, seed: int = 0) -> dict:
        """prompts: [lanes, R, P] i32; prompt_lens: [lanes, R] i32."""
        cfg = self.cfg
        z = cfg.lanes
        if n_req is None:
            n_req = np.full((z,), cfg.requests_per_lane, np.int32)
        keys = jax.vmap(jax.random.PRNGKey)(
            jnp.arange(seed, seed + z)
        )
        out = self.batched(
            jnp.asarray(prompts, jnp.int32),
            jnp.asarray(prompt_lens, jnp.int32),
            jnp.asarray(n_req, jnp.int32),
            keys,
        )
        return {
            "tokens": np.asarray(out["tokens"]),
            "lengths": np.asarray(out["lengths"]),
            "utilization": self.batched.utilization.get("decode", None),
        }

    # ------------------------------------------------------------------

    def reference_generate(self, prompts, prompt_lens, n_req=None) -> dict:
        """Oracle: plain python loop, one lane at a time (greedy only)."""
        cfg = self.cfg
        assert cfg.temperature == 0.0, "oracle supports greedy only"
        z = cfg.lanes
        if n_req is None:
            n_req = np.full((z,), cfg.requests_per_lane, np.int32)
        step = jax.jit(
            lambda p, c, t, pos: self.model.decode_step(p, c, t, pos)
        )
        out = np.zeros((z, cfg.requests_per_lane, cfg.max_new_tokens),
                       np.int32)
        olens = np.zeros((z, cfg.requests_per_lane), np.int32)
        for lane in range(z):
            for r in range(int(n_req[lane])):
                cache = self.model.init_cache(1, cfg.max_context)
                pos = 0
                tok = None
                for t in range(int(prompt_lens[lane, r])):
                    logits, cache = step(
                        self.params, cache,
                        jnp.asarray([prompts[lane, r, t]], jnp.int32),
                        jnp.asarray([pos], jnp.int32),
                    )
                    pos += 1
                tok = int(jnp.argmax(logits[0]))
                n = 0
                done = False
                while not done and n < cfg.max_new_tokens:
                    out[lane, r, n] = tok
                    n += 1
                    done = tok == cfg.eos_id
                    logits, cache = step(
                        self.params, cache,
                        jnp.asarray([tok], jnp.int32),
                        jnp.asarray([pos], jnp.int32),
                    )
                    pos += 1
                    tok = int(jnp.argmax(logits[0]))
                olens[lane, r] = n
        return {"tokens": out, "lengths": olens}
