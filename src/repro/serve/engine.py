"""Autobatched generation engine: the serving loop IS a program in the
paper's IR, executed by the program-counter VM.

Two serving modes share the model-as-batched-primitive machinery:

**Closed-loop** (:meth:`GenerationEngine.generate`): each batch lane owns
a pre-assigned queue of requests.  The per-lane program is plain control
flow::

    for each request in my queue:          # outer while
        reset cache;                        # masked zeroing
        while t < prompt_len: decode(...)   # streaming prefill
        while not EOS and n < max_new:      # generation loop
            emit token; decode(...)

Lanes diverge (different prompt lengths, different stop times, different
request counts) and the VM executes whichever block the earliest lanes
wait on, masking the rest.  Because the whole engine is ONE
``lax.while_loop`` program, it compiles end-to-end with XLA: there are no
host round-trips between tokens (the paper's headline claim, applied to
serving).

**Open-loop / continuous batching** (:meth:`GenerationEngine.serve`):
each lane runs ONE request at a time through a single-request program,
and the VM executes in *segments* (``Stepper``, ``docs/architecture.md``).
Between segments the host retires finished lanes (streaming their outputs
to the caller), admits newly-arrived requests from an admission queue,
and re-initializes free lanes in place with a masked ``inject`` — no
recompile, no reshape, no loss of in-flight work.  This is
retire-and-refill: SIMD occupancy no longer collapses as early requests
finish, and work may arrive while the batch is mid-flight.

Empty prompts are well-defined in both modes: a request with
``prompt_len == 0`` produces an *empty completion* (zero emitted tokens,
``length == 0``) — there is no prompt token to condition on, so nothing
is generated.  Lanes with ``n_req == 0`` produce all-zero outputs.  The
batched programs and the sequential oracle agree on these semantics.

The model's ``decode_step`` enters the programs as a single *batched*
primitive; its KV/state cache leaves are ordinary VM variables (the
programs are loop-only, so the VM allocates no stacks for them — paper
optimization iii).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batching, frontend, ir, pc_vm
from repro.core.frontend import spec
from repro.models.transformer import Model
from repro.train.checkpoint import Checkpointer
from repro.train.fault_tolerance import StragglerPolicy

KEY = spec((2,), jnp.uint32)
I32 = spec((), jnp.int32)
BOOL = spec((), jnp.bool_)


@dataclass(frozen=True)
class EngineConfig:
    lanes: int  # batch width of the VM (concurrent sequences)
    max_context: int  # KV/cache window
    max_prompt_len: int
    max_new_tokens: int
    requests_per_lane: int
    eos_id: int = 0
    temperature: float = 0.0
    backend: str = "pc"  # pc | local | local_eager
    # Lane sharding (pc backend): None, a device count, or a 1-D Mesh.
    # Lanes are independent request queues, so sharding them across devices
    # is multi-device continuous batching — each device serves lanes/n
    # queues, and the VM's dispatch reductions are the only cross-device
    # traffic per token.  ``lanes`` must divide across the mesh.
    mesh: Any = None
    # Open-loop serving (serve()): VM dispatches per segment between host
    # admission/retire checks.  Smaller = lower admission latency, more
    # host round-trips; larger = the opposite.
    segment_steps: int = 64
    # Occupancy-aware lane compaction cadence (pc backend; see
    # pc_vm.VMConfig.compact_every).  Requests keep their lane identity on
    # every engine surface — retire/inject/outputs invert the permutation
    # — so serving semantics are unchanged; only SIMD occupancy improves.
    compact_every: Optional[int] = None
    # Route VM stack traffic through the Pallas stack_ops kernels
    # (pc backend; composes with mesh — each device runs the kernel over
    # its own lane slice).
    use_kernel: bool = False
    # Dispatch tracing (pc backend; see pc_vm.VMConfig.trace): carry an
    # on-device ring buffer recording every dispatch.  Drain it after
    # serve() with ``engine.serve_batched.stepper(...)`` state or via the
    # VM result; recording never changes serving behavior.
    trace: Any = None
    # ---- fault containment & resilience (serve/generate) ----
    # VM fault policy (see pc_vm.VMConfig.on_fault).  The serving default
    # is "quarantine": one faulted request must never take down the other
    # lanes' dispatch loop.
    on_fault: str = "quarantine"
    # Fault the writing lane on any NaN/Inf entering VM state (e.g. a
    # poisoned KV cache); opt-in, costs an isfinite reduce per write.
    detect_nonfinite: bool = False
    # Per-lane watchdog: fault a lane active for more than this many VM
    # dispatches without finishing its request (livelock guard).  None
    # disables.
    lane_step_budget: Optional[int] = None
    # Per-request deadline, arrival -> finish, checked between segments
    # (granularity = one segment).  A retry's window restarts at its
    # re-enqueue time.  None disables.
    deadline_s: Optional[float] = None
    # Bounded admission queue: max requests arrived-but-not-admitted.  An
    # arrival past the bound is shed with Completion.status="rejected"
    # (explicit backpressure).  None = unbounded.
    queue_capacity: Optional[int] = None
    # Faulted/timed-out requests are re-enqueued with exponential backoff
    # (retry_backoff_s * 2**(attempt-1)) until max_attempts, then resolved
    # terminally as "faulted"/"timeout".
    max_attempts: int = 1
    retry_backoff_s: float = 0.05
    # Host-loop crash-resume: snapshot the live VM segment state (plus the
    # host bookkeeping) through train.Checkpointer every
    # checkpoint_every_segments segments.  serve(resume=True) restores the
    # newest valid snapshot and continues.  None disables.
    checkpoint_dir: Optional[str] = None
    checkpoint_every_segments: int = 8


def _cache_layout(model: Model, window: int):
    """Find each cache leaf's batch axis by differencing two batch sizes."""
    c1 = jax.eval_shape(lambda: model.init_cache(1, window))
    c2 = jax.eval_shape(lambda: model.init_cache(2, window))
    leaves1, treedef = jax.tree_util.tree_flatten_with_path(c1)
    l2 = jax.tree_util.tree_flatten(c2)[0]
    axes, member_specs = [], []
    for (path, a), b in zip(leaves1, l2):
        diff = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                if x != y]
        if len(diff) != 1:
            leaf = jax.tree_util.keystr(path) or "<root>"
            raise ValueError(
                f"ambiguous batch axis for cache leaf {leaf}: shapes "
                f"{a.shape} (batch=1) vs {b.shape} (batch=2) differ on "
                f"axes {diff or 'none'}; init_cache must scale exactly one "
                "axis of every leaf with the batch size"
            )
        ax = diff[0]
        axes.append(ax)
        shape = a.shape[:ax] + a.shape[ax + 1:]
        member_specs.append(jax.ShapeDtypeStruct(shape, a.dtype))
    return treedef, axes, member_specs


@dataclass(frozen=True)
class Request:
    """One generation request for the open-loop serving path."""

    rid: int
    prompt: np.ndarray  # [<= max_prompt_len] int32 token ids
    arrival: float = 0.0  # seconds since serve() start


#: Terminal request outcomes (Completion.status).
COMPLETION_STATUSES = ("ok", "faulted", "timeout", "rejected")


@dataclass(frozen=True)
class Completion:
    """A terminally-resolved request from :meth:`GenerationEngine.serve`.

    Every request resolves to exactly one completion; ``status`` says how:

    * ``"ok"`` — finished normally; ``tokens`` holds the generation.
    * ``"faulted"`` — the lane faulted (``fault`` names the kind: one of
      ``pc_vm.FAULT_NAMES``) and retries were exhausted; tokens are empty.
    * ``"timeout"`` — the deadline passed (queued or in flight) and
      retries were exhausted; tokens are empty.
    * ``"rejected"`` — shed at admission: the bounded queue was full.
    """

    rid: int
    tokens: np.ndarray  # [length] int32 (empty unless status == "ok")
    lane: int  # -1 if never admitted to a lane
    arrival: float  # request arrival time
    admitted: float  # when the request was injected into a lane
    finished: float  # when the terminal outcome was observed
    status: str = "ok"
    attempts: int = 1  # admission attempts consumed (>= 1)
    fault: Optional[str] = None  # fault kind for status == "faulted"

    @property
    def latency(self) -> float:
        """Arrival-to-finish latency (queueing + service), seconds."""
        return self.finished - self.arrival


@dataclass
class ServeStats:
    """Aggregates of one :meth:`GenerationEngine.serve` run."""

    segments: int = 0
    vm_steps: int = 0
    completions: int = 0  # terminal completions, every status
    generated_tokens: int = 0
    wall_time: float = 0.0
    # Mean fraction of lanes busy per segment (occupancy under refill).
    occupancy: float = 0.0
    # Terminal outcomes by status + resilience counters.
    ok: int = 0
    faulted: int = 0
    timeout: int = 0
    rejected: int = 0
    retries: int = 0  # re-enqueues (not counted in the terminal counters)
    straggler_events: int = 0  # segments flagged by StragglerPolicy
    checkpoints: int = 0  # crash-resume snapshots written
    # Arrival->finish latency percentiles over "ok" completions, seconds
    # (nan when the run produced none).
    p50_latency: float = float("nan")
    p99_latency: float = float("nan")
    _occ_acc: float = field(default=0.0, repr=False)


class GenerationEngine:
    def __init__(self, model: Model, params: Any, cfg: EngineConfig,
                 metrics: Optional["MetricsRegistry"] = None):
        from repro.obs.metrics import MetricsRegistry

        self.model = model
        self.params = params
        self.cfg = cfg
        #: Serve-loop instrumentation (obs.metrics).  Pass a shared
        #: registry to aggregate several engines into one scrape target;
        #: serve() populates it and ``serve_bench --metrics-out`` dumps it.
        self.metrics: MetricsRegistry = (
            metrics if metrics is not None else MetricsRegistry()
        )
        self.treedef, self.axes, self.member_specs = _cache_layout(
            model, cfg.max_context
        )
        self.program = self._build_program()
        # The engine program is loop-only, so its inputs are all per-lane
        # (Batched) by default; outputs restructure into a result pytree.
        fault_opts = (
            dict(
                on_fault=cfg.on_fault,
                detect_nonfinite=cfg.detect_nonfinite,
                lane_step_budget=cfg.lane_step_budget,
                compact_every=cfg.compact_every,
                use_kernel=cfg.use_kernel,
                trace=cfg.trace,
            )
            if cfg.backend == "pc"
            else {}
        )
        self.batched = batching.autobatch(
            self.program,
            out_spec={"tokens": "out", "lengths": "olens"},
            backend=cfg.backend,
            batch_size=cfg.lanes,
            max_depth=4,
            max_steps=2_000_000,
            mesh=cfg.mesh,
            **fault_opts,
        )

    # ------------------------------------------------------------------

    def _decode_fn(self):
        model, params = self.model, self.params
        axes, treedef = self.axes, self.treedef
        temp = self.cfg.temperature

        def decode(token, pos, key, *leaves):
            """Batched primitive: one model step for the whole batch."""
            cache = jax.tree_util.tree_unflatten(
                treedef, [jnp.moveaxis(l, 0, ax) for l, ax in
                          zip(leaves, axes)]
            )
            logits, new_cache = model.decode_step(params, cache, token, pos)
            keys = jax.vmap(lambda k: tuple(jax.random.split(k)))(key)
            if temp == 0.0:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                tok = jax.vmap(
                    lambda k, lg: jax.random.categorical(k, lg / temp)
                )(keys[1], logits).astype(jnp.int32)
            new_leaves = [
                jnp.moveaxis(l, ax, 0)
                for l, ax in zip(jax.tree_util.tree_flatten(new_cache)[0],
                                 axes)
            ]
            return (tok, keys[0], *new_leaves)

        return decode

    def _build_program(self) -> ir.Program:
        cfg = self.cfg
        n_leaves = len(self.member_specs)
        leaf_vars = [f"cache{i}" for i in range(n_leaves)]
        prompts_spec = spec(
            (cfg.requests_per_lane, cfg.max_prompt_len), jnp.int32
        )
        plens_spec = spec((cfg.requests_per_lane,), jnp.int32)
        out_spec = spec(
            (cfg.requests_per_lane, cfg.max_new_tokens), jnp.int32
        )
        olens_spec = spec((cfg.requests_per_lane,), jnp.int32)

        pb = frontend.ProgramBuilder(main="generate")
        fb = pb.function(
            "generate",
            params=["prompts", "plens", "n_req", "key"],
            outputs=["out", "olens"],
            param_specs={
                "prompts": prompts_spec, "plens": plens_spec,
                "n_req": I32, "key": KEY,
            },
            output_specs={"out": out_spec, "olens": olens_spec},
        )
        decode = self._decode_fn()

        fb.const(np.zeros((cfg.requests_per_lane, cfg.max_new_tokens),
                          np.int32), out="out")
        fb.const(np.zeros((cfg.requests_per_lane,), np.int32), out="olens")
        fb.const(0, jnp.int32, out="req")
        fb.const(0, jnp.int32, out="tok")
        # ---- outer loop over this lane's request queue ----
        with fb.while_(lambda req, n_req: req < n_req, ["req", "n_req"]):
            fb.assign("plen", lambda plens, req: plens[req],
                      ["plens", "req"], name="plen")
            self._emit_request_body(
                fb, decode, leaf_vars,
                read_prompt=lambda fb: fb.assign(
                    "ptok", lambda prompts, req, t: prompts[req, t],
                    ["prompts", "req", "t"], name="read_prompt",
                ),
                emit_token=lambda fb: fb.assign(
                    "out",
                    lambda out, req, n, tok: out.at[req, n].set(tok),
                    ["out", "req", "n", "tok"], name="emit",
                ),
                store_length=lambda fb: fb.assign(
                    "olens", lambda ol, req, n: ol.at[req].set(n),
                    ["olens", "req", "n"], name="store_len",
                ),
            )
            fb.assign("req", lambda r: r + 1, ["req"])
        fb.return_()
        pb.add(fb)
        return pb.build()

    def _emit_request_body(self, fb, decode, leaf_vars, *,
                           read_prompt, emit_token, store_length) -> None:
        """Emit the shared per-request control flow into ``fb``.

        Cache reset -> streaming prefill -> generation loop, reading the
        current prompt length from the ``plen`` variable.  Empty prompts
        produce empty completions: with no prompt token to condition on,
        generation never starts (the oracle agrees — see
        ``reference_generate``).  The closed- and open-loop programs share
        this body verbatim and differ only in how the prompt is indexed
        and where tokens/lengths are stored, supplied as emitters so the
        two serving modes cannot drift apart semantically.
        """
        cfg = self.cfg
        n_leaves = len(self.member_specs)
        eos = cfg.eos_id
        # reset per-request state (masked, per-lane)
        for v, sp in zip(leaf_vars, self.member_specs):
            fb.const(np.zeros(sp.shape, sp.dtype), out=v)
        fb.const(0, jnp.int32, out="pos")
        fb.const(0, jnp.int32, out="t")
        # ---- streaming prefill ----
        with fb.while_(lambda t, plen: t < plen, ["t", "plen"]):
            read_prompt(fb)  # writes "ptok"
            fb.prim(
                decode, ["ptok", "pos", "key", *leaf_vars],
                out=("tok", "key", *leaf_vars),
                n_out=2 + n_leaves,
                name="decode", batched=True, tag="decode",
            )
            fb.assign("pos", lambda p: p + 1, ["pos"])
            fb.assign("t", lambda t: t + 1, ["t"])
        # ---- generation loop ----
        fb.const(0, jnp.int32, out="n")
        fb.assign("done", lambda plen: plen == 0, ["plen"],
                  name="empty_prompt")
        with fb.while_(
            lambda done, n: jnp.logical_and(
                jnp.logical_not(done), n < cfg.max_new_tokens
            ),
            ["done", "n"],
        ):
            emit_token(fb)  # stores "tok" into the output buffer
            fb.assign("n", lambda n: n + 1, ["n"])
            fb.assign("done", lambda tok: tok == eos, ["tok"],
                      name="check_eos")
            fb.prim(
                decode, ["tok", "pos", "key", *leaf_vars],
                out=("tok", "key", *leaf_vars),
                n_out=2 + n_leaves,
                name="decode", batched=True, tag="decode",
            )
            fb.assign("pos", lambda p: p + 1, ["pos"])
        store_length(fb)  # records "n" as this request's length

    def _build_serve_program(self) -> ir.Program:
        """The open-loop per-lane program: ONE request, start to finish.

        Same prefill + generation control flow as the closed-loop program
        minus the outer queue loop — under retire-and-refill the "queue"
        lives on the host, and a lane that reaches the exit block simply
        waits (parked, masked out of every dispatch) until the host
        injects its next request.
        """
        cfg = self.cfg
        n_leaves = len(self.member_specs)
        leaf_vars = [f"cache{i}" for i in range(n_leaves)]
        pb = frontend.ProgramBuilder(main="serve_one")
        fb = pb.function(
            "serve_one",
            params=["prompt", "plen", "key"],
            outputs=["out", "olen"],
            param_specs={
                "prompt": spec((cfg.max_prompt_len,), jnp.int32),
                "plen": I32, "key": KEY,
            },
            output_specs={
                "out": spec((cfg.max_new_tokens,), jnp.int32),
                "olen": I32,
            },
        )
        decode = self._decode_fn()

        fb.const(np.zeros((cfg.max_new_tokens,), np.int32), out="out")
        fb.const(0, jnp.int32, out="olen")
        fb.const(0, jnp.int32, out="tok")
        self._emit_request_body(
            fb, decode, leaf_vars,
            read_prompt=lambda fb: fb.assign(
                "ptok", lambda prompt, t: prompt[t],
                ["prompt", "t"], name="read_prompt",
            ),
            emit_token=lambda fb: fb.assign(
                "out", lambda out, n, tok: out.at[n].set(tok),
                ["out", "n", "tok"], name="emit",
            ),
            store_length=lambda fb: fb.copy("n", out="olen"),
        )
        fb.return_()
        pb.add(fb)
        return pb.build()

    # ------------------------------------------------------------------

    def generate(self, prompts: np.ndarray, prompt_lens: np.ndarray,
                 n_req: Optional[np.ndarray] = None, seed: int = 0) -> dict:
        """prompts: [lanes, R, P] i32; prompt_lens: [lanes, R] i32."""
        cfg = self.cfg
        z = cfg.lanes
        if n_req is None:
            n_req = np.full((z,), cfg.requests_per_lane, np.int32)
        keys = jax.vmap(jax.random.PRNGKey)(
            jnp.arange(seed, seed + z)
        )
        out = self.batched(
            jnp.asarray(prompts, jnp.int32),
            jnp.asarray(prompt_lens, jnp.int32),
            jnp.asarray(n_req, jnp.int32),
            keys,
        )
        return {
            "tokens": np.asarray(out["tokens"]),
            "lengths": np.asarray(out["lengths"]),
            "utilization": self.batched.utilization.get("decode", None),
        }

    # ------------------------------------------------------------------
    # Open-loop serving: retire-and-refill continuous batching
    # ------------------------------------------------------------------

    @property
    def serve_batched(self) -> batching.AutobatchedFunction:
        """The single-request program, autobatched (built lazily)."""
        if getattr(self, "_serve_batched", None) is None:
            if self.cfg.backend != "pc":
                raise ValueError(
                    "open-loop serving needs the resumable pc backend; "
                    f"got backend={self.cfg.backend!r}"
                )
            self._serve_batched = batching.autobatch(
                self._build_serve_program(),
                out_spec={"tokens": "out", "lengths": "olen"},
                backend="pc",
                batch_size=self.cfg.lanes,
                max_depth=4,
                max_steps=2 ** 31 - 2,  # a server's step count is unbounded
                mesh=self.cfg.mesh,
                on_fault=self.cfg.on_fault,
                detect_nonfinite=self.cfg.detect_nonfinite,
                lane_step_budget=self.cfg.lane_step_budget,
                compact_every=self.cfg.compact_every,
                use_kernel=self.cfg.use_kernel,
                trace=self.cfg.trace,
            )
        return self._serve_batched

    def serve(
        self,
        requests: list[Request],
        *,
        segment_steps: Optional[int] = None,
        seed: int = 0,
        now_fn: Optional[Callable[[], float]] = None,
        on_finish: Optional[Callable[[Completion], None]] = None,
        resume: bool = False,
        straggler: Optional[StragglerPolicy] = None,
    ) -> tuple[list[Completion], ServeStats]:
        """Serve an open-loop request stream with live refill.

        Runs the single-request program in VM segments of
        ``segment_steps`` dispatches.  Between segments the host:

        1. **retires** — reads per-lane halt flags and fault codes,
           streams each finished lane's tokens out as a
           :class:`Completion` (via ``on_finish`` the moment it is
           observed), and returns the lane to the free pool.  A *faulted*
           lane (quarantined NaN / watchdog / overflow) is retired too:
           its request is re-enqueued with exponential backoff while
           attempts remain (``cfg.max_attempts``), else resolved
           terminally with ``status="faulted"``;
        2. **enforces deadlines** — a request whose ``cfg.deadline_s``
           window (arrival -> finish; a retry's window restarts at its
           re-enqueue) has passed is timed out, whether queued or in
           flight (in-flight lanes are parked and freed), and retried or
           resolved as ``status="timeout"``;
        3. **admits** — pops requests whose ``arrival`` time has passed
           off the queue into free lanes with a masked in-place
           re-initialization (in-flight lanes are untouched).  With
           ``cfg.queue_capacity`` set, an arrival that finds the waiting
           queue full is shed immediately as ``status="rejected"``
           (explicit backpressure).

        With ``cfg.checkpoint_dir`` set, the live VM segment state plus
        the host bookkeeping (done rids, in-flight lane assignments) is
        snapshotted through :class:`train.Checkpointer` every
        ``cfg.checkpoint_every_segments`` segments; after a host crash,
        ``serve(requests, resume=True)`` restores the newest valid
        snapshot, skips already-completed requests, and continues the
        in-flight ones from mid-generation.  Delivery is at-least-once: a
        request that finished after the last snapshot is re-served.

        ``now_fn`` supplies the clock (seconds since serve start);
        defaults to wall time, pass ``lambda: 0.0``-style closures for
        deterministic tests.  Completions are returned sorted by request
        id; every request resolves to exactly one terminal
        :class:`Completion` (``ok|faulted|timeout|rejected``).
        Per-segment latencies feed a :class:`StragglerPolicy`
        (``stats.straggler_events``).
        """
        cfg = self.cfg
        z = cfg.lanes
        seg = (cfg.segment_steps if segment_steps is None
               else int(segment_steps))
        if seg < 1:
            raise ValueError(f"segment_steps must be >= 1, got {seg}")
        if cfg.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1, got {cfg.max_attempts}"
            )
        for r in requests:
            if len(r.prompt) > cfg.max_prompt_len:
                raise ValueError(
                    f"request {r.rid}: prompt length {len(r.prompt)} "
                    f"exceeds max_prompt_len={cfg.max_prompt_len}"
                )
        if resume and cfg.checkpoint_dir is None:
            raise ValueError("serve(resume=True) needs cfg.checkpoint_dir")

        st = self.serve_batched.stepper(
            jnp.zeros((z, cfg.max_prompt_len), jnp.int32),
            jnp.zeros((z,), jnp.int32),
            jnp.zeros((z, 2), jnp.uint32),
        )
        state = st.init()
        state = st.park(state, np.ones((z,), bool))

        t0 = time.perf_counter()
        now = now_fn if now_fn is not None else (
            lambda: time.perf_counter() - t0
        )
        pol = straggler if straggler is not None else StragglerPolicy()
        completions: list[Completion] = []
        stats = ServeStats()
        m = self.metrics
        m_admissions = m.counter(
            "serve_admissions_total", "requests injected into a lane")
        m_completions = m.counter(
            "serve_completions_total", "terminal completions by status")
        m_retries = m.counter(
            "serve_retries_total", "faulted/timed-out re-enqueues")
        m_tokens = m.counter(
            "serve_generated_tokens_total", "tokens emitted by ok lanes")
        m_queue = m.gauge(
            "serve_queue_depth", "arrived-but-not-admitted requests")
        m_lanes = m.gauge("serve_active_lanes", "lanes with a request in flight")
        m_seg = m.histogram(
            "serve_segment_seconds", "wall time of one VM segment")
        m_latency = m.histogram(
            "serve_request_latency_seconds",
            "arrival->finish latency by terminal status")
        done_rids: set[int] = set()
        # Queue entries: one admission attempt of one request.
        # {"req", "attempt", "not_before", "anchor", "deadline_at",
        #  "admitted"} — "anchor" is the attempt's deadline start (the
        # request arrival, or the re-enqueue time for retries).
        active: dict[int, dict] = {}

        def _entry(r: Request, attempt: int = 1,
                   not_before: Optional[float] = None) -> dict:
            anchor = r.arrival if not_before is None else not_before
            return {
                "req": r, "attempt": attempt,
                "not_before": anchor, "anchor": anchor,
                "deadline_at": (
                    anchor + cfg.deadline_s
                    if cfg.deadline_s is not None else None
                ),
                "admitted": None,
            }

        # ---- crash-resume restore --------------------------------------
        ckpt = (Checkpointer(cfg.checkpoint_dir, async_save=False)
                if cfg.checkpoint_dir else None)
        ckpt_step = 0
        if resume and ckpt is not None:
            latest = ckpt.latest_step()
            if latest is not None:
                ckpt_step = latest
                state = ckpt.restore(latest, like=state)
                # Re-pin the lane layout under a mesh (park with an empty
                # mask is a sharded identity).
                state = st.park(state, np.zeros((z,), bool))
                meta = ckpt.manifest(latest).get("extra", {})
                done_rids = set(meta.get("done_rids", []))
                by_rid = {r.rid: r for r in requests}
                for lane_s, info in meta.get("active", {}).items():
                    rid = int(info["rid"])
                    r = by_rid.get(rid)
                    if r is None:
                        # The caller did not re-pass this in-flight rid;
                        # serve it from the snapshot anyway (tokens come
                        # from the VM) under a synthetic request record.
                        r = Request(
                            rid=rid,
                            prompt=np.zeros((0,), np.int32),
                            arrival=0.0,
                        )
                    e = _entry(r, attempt=int(info.get("attempt", 1)))
                    # The clock restarted with the host: the resumed
                    # attempt's deadline window restarts at resume time.
                    e["anchor"] = 0.0
                    e["deadline_at"] = (
                        cfg.deadline_s if cfg.deadline_s is not None
                        else None
                    )
                    e["admitted"] = 0.0
                    active[int(lane_s)] = e

        pend = sorted(
            (
                _entry(r) for r in requests
                if r.rid not in done_rids
                and all(e["req"].rid != r.rid for e in active.values())
            ),
            key=lambda e: (e["not_before"], e["req"].rid),
        )
        waiting: list[dict] = []
        free = [lane for lane in range(z) if lane not in active][::-1]

        prompts_buf = np.zeros((z, cfg.max_prompt_len), np.int32)
        plens_buf = np.zeros((z,), np.int32)
        keys_buf = np.zeros((z, 2), np.uint32)
        idle_spins = 0
        max_steps_budget = st.vm.config.max_steps

        def _terminal(e: dict, status: str, lane: int, t_now: float,
                      tokens: Optional[np.ndarray] = None,
                      fault: Optional[str] = None) -> None:
            r = e["req"]
            comp = Completion(
                rid=r.rid,
                tokens=(tokens if tokens is not None
                        else np.zeros((0,), np.int32)),
                lane=lane,
                arrival=r.arrival,
                admitted=(e["admitted"] if e["admitted"] is not None
                          else t_now),
                finished=t_now,
                status=status,
                attempts=e["attempt"],
                fault=fault,
            )
            completions.append(comp)
            done_rids.add(r.rid)
            setattr(stats, status, getattr(stats, status) + 1)
            m_completions.inc(status=status)
            m_latency.observe(comp.latency, status=status)
            if on_finish is not None:
                on_finish(comp)

        def _retry_or_terminal(e: dict, status: str, lane: int,
                               t_now: float,
                               fault: Optional[str] = None) -> None:
            if e["attempt"] < cfg.max_attempts:
                stats.retries += 1
                m_retries.inc(reason=status)
                delay = cfg.retry_backoff_s * (2 ** (e["attempt"] - 1))
                pend.append(
                    _entry(e["req"], attempt=e["attempt"] + 1,
                           not_before=t_now + delay)
                )
                pend.sort(key=lambda x: (x["not_before"], x["req"].rid))
            else:
                _terminal(e, status, lane, t_now, fault=fault)

        def _admit(e: dict, lane: int, mask: np.ndarray,
                   t_now: float) -> None:
            r = e["req"]
            p = np.asarray(r.prompt, np.int32).reshape(-1)
            prompts_buf[lane] = 0
            prompts_buf[lane, : len(p)] = p
            plens_buf[lane] = len(p)
            keys_buf[lane] = np.asarray(
                jax.random.PRNGKey(seed + r.rid), np.uint32
            )
            mask[lane] = True
            e["admitted"] = t_now
            active[lane] = e
            m_admissions.inc()

        def _save_checkpoint() -> None:
            nonlocal ckpt_step
            ckpt_step += 1
            ckpt.save(
                ckpt_step, state,
                extra={
                    "done_rids": sorted(done_rids),
                    "active": {
                        str(lane): {
                            "rid": e["req"].rid, "attempt": e["attempt"]
                        }
                        for lane, e in active.items()
                    },
                },
            )
            stats.checkpoints += 1

        while pend or waiting or active:
            t_now = now()
            # ---- admit: arrivals -> lanes, else bounded queue ----------
            mask = np.zeros((z,), bool)
            while pend and pend[0]["not_before"] <= t_now:
                e = pend.pop(0)
                if free and not waiting:  # FIFO: queued requests go first
                    _admit(e, free.pop(), mask, t_now)
                elif (cfg.queue_capacity is None
                      or len(waiting) < cfg.queue_capacity):
                    waiting.append(e)
                else:
                    _terminal(e, "rejected", -1, t_now)
            # Queued requests whose deadline passed while waiting.
            if cfg.deadline_s is not None:
                for e in [w for w in waiting
                          if w["deadline_at"] is not None
                          and t_now >= w["deadline_at"]]:
                    waiting.remove(e)
                    _retry_or_terminal(e, "timeout", -1, t_now)
            while waiting and free:
                _admit(waiting.pop(0), free.pop(), mask, t_now)
            if mask.any():
                state = st.inject(
                    state, mask,
                    jnp.asarray(prompts_buf), jnp.asarray(plens_buf),
                    jnp.asarray(keys_buf),
                )
            if not active:
                # Every lane idle and the next arrival is in the future:
                # yield the host briefly instead of spinning.
                if pend and now_fn is None:
                    time.sleep(
                        min(max(pend[0]["not_before"] - now(), 0.0), 0.01)
                    )
                elif pend:
                    idle_spins += 1
                    if idle_spins > 1_000_000:
                        raise RuntimeError(
                            "serve(): all lanes idle but the now_fn clock "
                            f"never reaches the next arrival "
                            f"({pend[0]['not_before']}); supply an "
                            "advancing clock"
                        )
                continue
            idle_spins = 0

            # ---- one VM segment -------------------------------------
            m_queue.set(len(waiting))
            m_lanes.set(len(active))
            t_seg = time.perf_counter()
            with jax.profiler.TraceAnnotation("serve.segment"):
                state = st.step(state, seg)
            m_seg.observe(time.perf_counter() - t_seg)
            stats.segments += 1
            stats._occ_acc += len(active) / z
            if st.steps(state) >= max_steps_budget:
                # The VM's cumulative step budget is spent: further
                # segments would be silent no-ops and active lanes could
                # never retire.  Fail loudly instead of spinning.
                raise RuntimeError(
                    f"serve(): VM step budget exhausted "
                    f"({max_steps_budget} steps) with {len(active)} "
                    f"request(s) still in flight; raise the engine "
                    "program's max_steps"
                )

            # ---- retire: finished / faulted / timed-out lanes -------
            done = np.asarray(jax.device_get(st.lane_done(state)))
            codes = np.asarray(jax.device_get(st.fault_code(state)))
            pol.observe(stats.segments, time.perf_counter() - t_seg)
            t_now = now()
            # Fault beats done: a lane that faulted while (or before)
            # reaching the exit block produced invalid tokens.
            faulted = [lane for lane in active if codes[lane] != 0]
            finished = [lane for lane in active
                        if done[lane] and codes[lane] == 0]
            timed_out = [
                lane for lane, e in active.items()
                if lane not in faulted and lane not in finished
                and e["deadline_at"] is not None
                and t_now >= e["deadline_at"]
            ]
            park_mask = np.zeros((z,), bool)
            for lane in faulted:
                e = active.pop(lane)
                free.append(lane)
                park_mask[lane] = True
                _retry_or_terminal(
                    e, "faulted", lane, t_now,
                    fault=pc_vm.FAULT_NAMES[int(codes[lane])],
                )
            for lane in timed_out:
                e = active.pop(lane)
                free.append(lane)
                park_mask[lane] = True
                _retry_or_terminal(e, "timeout", lane, t_now)
            if finished:
                outs = st.outputs(state)
                tokens = np.asarray(jax.device_get(outs["tokens"]))
                lengths = np.asarray(jax.device_get(outs["lengths"]))
                for lane in finished:
                    e = active.pop(lane)
                    toks = tokens[lane, : int(lengths[lane])].copy()
                    _terminal(e, "ok", lane, t_now, tokens=toks)
                    stats.generated_tokens += int(lengths[lane])
                    m_tokens.inc(int(lengths[lane]))
                    free.append(lane)
            if park_mask.any():
                # Idle the retired-with-prejudice lanes (a later inject
                # clears their fault codes).
                state = st.park(state, park_mask)

            # ---- crash-resume snapshot ------------------------------
            if (ckpt is not None and cfg.checkpoint_every_segments
                    and stats.segments % cfg.checkpoint_every_segments
                    == 0):
                _save_checkpoint()

        if ckpt is not None:
            # Final snapshot: a resume after completion is a no-op run.
            _save_checkpoint()
        stats.vm_steps = st.steps(state)
        stats.completions = len(completions)
        stats.wall_time = time.perf_counter() - t0
        stats.occupancy = (
            stats._occ_acc / stats.segments if stats.segments else 0.0
        )
        stats.straggler_events = len(pol.flagged)
        stats.p50_latency = m_latency.percentile(50, status="ok")
        stats.p99_latency = m_latency.percentile(99, status="ok")
        m_queue.set(0)
        m_lanes.set(0)
        if stats.wall_time > 0:
            m.gauge(
                "serve_tokens_per_second",
                "generated-token throughput of the finished run",
            ).set(stats.generated_tokens / stats.wall_time)
        completions.sort(key=lambda c: c.rid)
        return completions, stats

    # ------------------------------------------------------------------

    def reference_generate(self, prompts, prompt_lens, n_req=None) -> dict:
        """Oracle: plain python loop, one lane at a time (greedy only).

        Matches the batched programs' edge-case semantics: a zero-length
        prompt yields an empty completion (no tokens, length 0), and a
        lane with ``n_req == 0`` yields all-zero outputs.
        """
        cfg = self.cfg
        assert cfg.temperature == 0.0, "oracle supports greedy only"
        z = cfg.lanes
        if n_req is None:
            n_req = np.full((z,), cfg.requests_per_lane, np.int32)
        step = jax.jit(
            lambda p, c, t, pos: self.model.decode_step(p, c, t, pos)
        )
        out = np.zeros((z, cfg.requests_per_lane, cfg.max_new_tokens),
                       np.int32)
        olens = np.zeros((z, cfg.requests_per_lane), np.int32)
        for lane in range(z):
            for r in range(int(n_req[lane])):
                if int(prompt_lens[lane, r]) == 0:
                    continue  # empty prompt => empty completion
                cache = self.model.init_cache(1, cfg.max_context)
                pos = 0
                for t in range(int(prompt_lens[lane, r])):
                    logits, cache = step(
                        self.params, cache,
                        jnp.asarray([prompts[lane, r, t]], jnp.int32),
                        jnp.asarray([pos], jnp.int32),
                    )
                    pos += 1
                tok = int(jnp.argmax(logits[0]))
                n = 0
                done = False
                while not done and n < cfg.max_new_tokens:
                    out[lane, r, n] = tok
                    n += 1
                    done = tok == cfg.eos_id
                    logits, cache = step(
                        self.params, cache,
                        jnp.asarray([tok], jnp.int32),
                        jnp.asarray([pos], jnp.int32),
                    )
                    pos += 1
                    tok = int(jnp.argmax(logits[0]))
                olens[lane, r] = n
        return {"tokens": out, "lengths": olens}
