"""The two jittable serving primitives the launcher/dry-run lowers:

* ``make_prefill_step``  — full-sequence forward over the prompt batch
  (the ``prefill_*`` shapes);
* ``make_serve_step``    — one new token against a KV/state cache of
  ``seq_len`` (the ``decode_*`` / ``long_*`` shapes), including sampling.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.transformer import Model


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch)
        # next-token distribution at the prompt boundary
        return logits[:, -1].astype(jnp.float32)

    return prefill_step


def sample_token(
    logits: jax.Array, key: jax.Array, temperature: float = 0.0
) -> jax.Array:
    """Greedy (T=0) or temperature sampling. logits: [B, V] f32."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def make_serve_step(model: Model, temperature: float = 0.0) -> Callable:
    """decode: (params, cache, tokens [B], pos [B], key) ->
    (new_tokens [B], cache)."""

    def serve_step(params, cache, tokens, pos, key):
        logits, cache = model.decode_step(params, cache, tokens, pos)
        return sample_token(logits, key, temperature), cache

    return serve_step


LONG_CONTEXT_THRESHOLD = 131_072


def decode_cache_window(cfg: ArchConfig, shape: ShapeSpec) -> int:
    """Cache window for a decode shape.

    Sub-quadratic archs carry O(1) recurrent state; their *attention*
    components (e.g. Zamba2's shared blocks) switch to a sliding-window KV
    in the long-context regime (>=128k), bounding memory at 500k+ tokens.
    Ordinary decode shapes keep the full context window.
    """
    if cfg.subquadratic and shape.seq_len >= LONG_CONTEXT_THRESHOLD:
        return cfg.long_context_window
    return shape.seq_len
