"""Serving: prefill/decode steps + the VM-scheduled generation engine."""
