"""Quickstart: autobatch control-intensive programs with one decorator.

    PYTHONPATH=src python examples/quickstart.py

The public API is `repro.core.batching.autobatch` — a `vmap`-like decorator
over restricted Python (or over a builder-built program) that returns a
callable over positional pytree arguments:

* `Batched(spec)` arguments carry a leading batch axis (`in_axes=0`);
* `Shared(spec)` arguments are broadcast constants (`in_axes=None`);
* outputs come back as pytrees;
* compiled artifacts are cached per `(backend, batch_size, input avals)`,
  and the pc backend's stack-explicit lowering is shared across batch sizes.

The decorated handles (`fib`, `collatz`) live at module level so tools can
import and inspect them — `python tools/irlint.py examples/quickstart.py:fib`
runs the lowered-IR verifier and static analyses over them.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import frontend
from repro.core.batching import Batched, Shared, autobatch
from repro.core.frontend import F32, I32

# ---------------------------------------------------------------------------
# 1. Decorate restricted Python — recursion and all — and call it batched.
#    fib is recursive, so the stack depth has no static bound: pass one.
# ---------------------------------------------------------------------------


@autobatch(in_specs=(Batched(I32),), out_spec=I32, backend="pc", max_depth=24)
def fib(n):
    if n < 2:
        return n
    return fib(n - 1) + fib(n - 2)


# ---------------------------------------------------------------------------
# 2. The builder frontend feeds the same API: Collatz trajectory length.
#    Shared(step) shows a broadcast constant; the output is a pytree.
#    collatz is loop-only (non-recursive): max_depth defaults to the
#    statically inferred bound — no stack sizing to guess.
# ---------------------------------------------------------------------------
pb = frontend.ProgramBuilder()
fb = pb.function(
    "collatz", ["n", "bound"], ["steps", "peak"],
    {"n": I32, "bound": I32}, {"steps": I32, "peak": I32},
)
fb.const(0, jnp.int32, out="steps")
fb.copy("n", out="peak")
with fb.while_(lambda n, s, b: jnp.logical_and(n > 1, s < b),
               ["n", "steps", "bound"]):
    is_even = fb.prim(lambda n: n % 2 == 0, ["n"])
    with fb.if_(is_even):
        fb.assign("n", lambda n: n // 2, ["n"])
    with fb.orelse():
        fb.assign("n", lambda n: 3 * n + 1, ["n"])
    fb.assign("peak", lambda p, n: jnp.maximum(p, n), ["peak", "n"])
    fb.assign("steps", lambda s: s + 1, ["steps"])
fb.return_()
pb.add(fb)

collatz = autobatch(
    pb,
    in_specs=(Batched(I32), Shared(I32)),   # per-member n, shared step bound
    out_spec={"steps": "steps", "peak": "peak"},
    backend="pc",
)


def trace_run():
    """vmtrace entry point: a zero-arg callable returning ``(fn, args)``.

        PYTHONPATH=src python tools/vmtrace.py examples/quickstart.py:trace_run

    runs ``fib`` with dispatch tracing on and exports the Perfetto
    timeline + block profile (see docs/observability.md).
    """
    return fib, (np.array([0, 1, 5, 9, 12, 3, 7, 2], np.int32),)


def main():
    n = np.array([0, 1, 5, 9, 12, 3, 7, 2], np.int32)
    print("fib(n)  =", np.asarray(fib(n)))
    print("VM steps:", int(fib.last_result.steps),
          "(8 divergent recursions, one fused XLA loop)")

    out = collatz(np.array([1, 6, 7, 27, 97, 871], np.int32), np.int32(1000))
    print("collatz =", np.asarray(out["steps"]), "(expect 0 8 16 111 118 178)")
    print("peaks   =", np.asarray(out["peak"]))

    # -----------------------------------------------------------------------
    # 3. One decorated function, four backends, shared compilation cache.
    # -----------------------------------------------------------------------
    for backend in ("pc", "local", "local_eager", "reference"):
        bp = autobatch(fib.program, backend=backend, max_depth=24)
        res = bp(np.array([10] * 8, np.int32))
        print(f"{backend:12s} fib(10) -> {np.asarray(res['out'])[0]}")

    # Calling again at the same avals is a pure cache hit (no re-trace,
    # no re-lower, no re-compile); a new batch size reuses the lowering.
    fib(n)
    fib(np.array([4, 5, 6, 7], np.int32))
    print("cache:", fib.cache_info())

    # What did the compiler do?  diagnostics() runs the lowered-IR
    # verifier + static analyses (tools/irlint.py prints the same report).
    print(collatz.diagnostics().pretty())


if __name__ == "__main__":
    main()
