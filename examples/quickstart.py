"""Quickstart: autobatch a recursive program three ways.

    PYTHONPATH=src python examples/quickstart.py

Writes a naive recursive Fibonacci + a data-dependent Collatz loop
against the public API, batches them with the program-counter VM (the
paper's contribution), and shows the utilization counters that make
Figure 6 tick.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import api, frontend
from repro.core.ast_frontend import Namespace
from repro.core.frontend import I32

# ---------------------------------------------------------------------------
# 1. The AST frontend: decorate restricted Python, get a batched program.
# ---------------------------------------------------------------------------
ns = Namespace()


@ns.define(param_specs={"n": I32}, output_specs=[I32])
def fib(n):
    if n < 2:
        return n
    return fib(n - 1) + fib(n - 2)


program = ns.program(main="fib")
batched = api.autobatch(program, batch_size=8, backend="pc", max_depth=24)
n = np.array([0, 1, 5, 9, 12, 3, 7, 2], np.int32)
print("fib(n)  =", np.asarray(batched({"n": n})["out"]))
print("VM steps:", int(batched.last_result.steps),
      "(8 divergent recursions, one fused XLA loop)")

# ---------------------------------------------------------------------------
# 2. The builder frontend: explicit control flow, Collatz trajectory length.
# ---------------------------------------------------------------------------
pb = frontend.ProgramBuilder()
fb = pb.function("collatz", ["n"], ["steps"], {"n": I32}, {"steps": I32})
fb.const(0, jnp.int32, out="steps")
with fb.while_(lambda n: n > 1, ["n"]):
    is_even = fb.prim(lambda n: n % 2 == 0, ["n"])
    with fb.if_(is_even):
        fb.assign("n", lambda n: n // 2, ["n"])
    with fb.orelse():
        fb.assign("n", lambda n: 3 * n + 1, ["n"])
    fb.assign("steps", lambda s: s + 1, ["steps"])
fb.return_()
pb.add(fb)

collatz = api.autobatch(pb.build(), batch_size=6, backend="pc")
n = np.array([1, 6, 7, 27, 97, 871], np.int32)
out = collatz({"n": n})
print("collatz =", np.asarray(out["steps"]), "(expect 0 8 16 111 118 178)")

# ---------------------------------------------------------------------------
# 3. Backend comparison on the same program.
# ---------------------------------------------------------------------------
for backend in ("pc", "local", "reference"):
    bp = api.autobatch(program, 8, backend=backend, max_depth=24)
    res = bp({"n": np.array([10] * 8, np.int32)})
    print(f"{backend:10s} fib(10) -> {np.asarray(res['out'])[0]}")
