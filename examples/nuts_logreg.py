"""The paper's experiment end-to-end: autobatched NUTS on Bayesian
logistic regression (Section 4.1), plus the Fig-6 utilization probe.

    PYTHONPATH=src python examples/nuts_logreg.py [--chains 64] [--full]

Builds the recursive NUTS program in the autobatch IR, runs a batch of
chains through the program-counter VM as ONE fused XLA computation,
reports posterior quality (vs the ground-truth weights that generated
the data) and gradient-evaluation throughput/utilization.
"""
import argparse
import time

import numpy as np

from repro.mcmc import nuts, targets


def build_program(dim: int = 20, num_data: int = 1_000):
    """The recursive NUTS ir.Program this example runs (small default).

    Module-level factory so static tooling can analyze the exact program:
    ``python tools/irlint.py examples/nuts_logreg.py:build_program``.
    """
    target = targets.logistic_regression(num_data=num_data, dim=dim)
    settings = nuts.NutsSettings(
        max_tree_depth=8, num_steps=20, steps_per_leaf=4
    )
    return nuts.build_nuts_program(target, settings)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chains", type=int, default=32)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale: 10k points, 100 regressors")
    args = ap.parse_args()

    if args.full:
        target = targets.logistic_regression(num_data=10_000, dim=100)
        eps = 0.01
    else:
        target = targets.logistic_regression(num_data=1_000, dim=20)
        eps = 0.05
    settings = nuts.NutsSettings(
        max_tree_depth=8, num_steps=args.steps, steps_per_leaf=4
    )
    print(f"target: {target.name}; {args.chains} chains x "
          f"{args.steps} NUTS trajectories")

    kernel = nuts.make_nuts_kernel(
        target, settings, backend="pc", max_steps=2_000_000
    )
    theta0, eps_arg, keys = nuts.initial_state(
        target, args.chains, eps=eps, seed=0
    )

    t0 = time.time()
    state = kernel(theta0, eps_arg, keys)  # includes compile
    t_compile_run = time.time() - t0
    t0 = time.time()
    state = kernel(theta0, eps_arg, keys)  # pure cache hit on the same avals
    t_warm = time.time() - t0
    assert kernel.cache_info().hits >= 1

    res = kernel.last_result
    execs, active = kernel.tag_stats["grad"]
    grads = active * settings.grads_per_leaf
    print(f"converged: {bool(res.converged)}  VM steps: {int(res.steps)}")
    print(f"cold run (incl. compile): {t_compile_run:.2f}s")
    print(f"warm run: {t_warm:.2f}s  "
          f"({grads / t_warm:,.0f} member-gradients/sec)")
    print(f"batch utilization of gradient leaves: "
          f"{kernel.utilization['grad']:.3f}")

    n = args.chains * settings.num_steps
    mean = np.asarray(state["sum_theta"]).sum(0) / n
    print(f"posterior mean norm: {np.linalg.norm(mean):.3f} "
          f"(finite: {np.isfinite(mean).all()})")


if __name__ == "__main__":
    main()
