"""Serve a small LM with batched requests through the autobatch VM.

    PYTHONPATH=src python examples/serve_lm.py --lanes 8

The generation loop (streaming prefill -> sample-until-EOS -> next
request in the lane's queue) is a *program in the paper's IR*; the
program-counter VM executes all lanes in lockstep with masking, so
requests of different prompt lengths / generation lengths / queue depths
batch together — continuous batching as a compiler artifact rather than
bespoke scheduler code.
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import get_model
from repro.serve.engine import EngineConfig, GenerationEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--requests-per-lane", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--check", action="store_true",
                    help="verify against the sequential oracle")
    args = ap.parse_args()

    cfg = configs.get_smoke_config("smollm-135m")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(
        lanes=args.lanes,
        max_context=64,
        max_prompt_len=12,
        max_new_tokens=args.max_new,
        requests_per_lane=args.requests_per_lane,
        eos_id=0,
        backend="pc",
    )
    engine = GenerationEngine(model, params, ecfg)
    print(f"engine: {args.lanes} lanes x {args.requests_per_lane} requests, "
          f"program blocks: {len(engine.batched.lowered.blocks)}, "
          f"stacks: {len(engine.batched.lowered.stack_vars)} "
          f"(loop-only program -> none)")

    rng = np.random.default_rng(0)
    prompts = rng.integers(
        1, cfg.vocab_size,
        (args.lanes, args.requests_per_lane, ecfg.max_prompt_len),
    ).astype(np.int32)
    plens = rng.integers(
        2, ecfg.max_prompt_len + 1, (args.lanes, args.requests_per_lane)
    ).astype(np.int32)

    res = engine.generate(prompts, plens)  # compile + run
    t0 = time.time()
    res = engine.generate(prompts, plens)
    dt = time.time() - t0
    total = int(res["lengths"].sum())
    print(f"generated {total} tokens in {dt:.2f}s "
          f"({total/dt:,.0f} tok/s), decode-batch utilization "
          f"{res['utilization']:.3f}")
    print("first lane, first request tokens:",
          res["tokens"][0, 0, : res['lengths'][0, 0]])

    if args.check:
        ref = engine.reference_generate(prompts, plens)
        ok = np.array_equal(res["tokens"], ref["tokens"])
        print("matches sequential oracle:", ok)


if __name__ == "__main__":
    main()
