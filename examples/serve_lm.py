"""Serve a small LM with batched requests through the autobatch VM.

    PYTHONPATH=src python examples/serve_lm.py --lanes 8
    PYTHONPATH=src python examples/serve_lm.py --lanes 4 --open-loop

The generation loop (streaming prefill -> sample-until-EOS -> next
request in the lane's queue) is a *program in the paper's IR*; the
program-counter VM executes all lanes in lockstep with masking, so
requests of different prompt lengths / generation lengths / queue depths
batch together — continuous batching as a compiler artifact rather than
bespoke scheduler code.

With ``--open-loop``, the engine instead runs the resumable (segmented)
VM: requests are admitted from a host-side queue as lanes retire, and
completions stream out the moment they finish (retire-and-refill).
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import get_model
from repro.serve.engine import EngineConfig, GenerationEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--requests-per-lane", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--check", action="store_true",
                    help="verify against the sequential oracle")
    ap.add_argument("--open-loop", action="store_true",
                    help="continuous batching: admit requests from a "
                         "host-side queue between VM segments")
    ap.add_argument("--num-requests", type=int, default=16,
                    help="open-loop: total requests in the stream")
    args = ap.parse_args()

    cfg = configs.get_smoke_config("smollm-135m")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(
        lanes=args.lanes,
        max_context=64,
        max_prompt_len=12,
        max_new_tokens=args.max_new,
        requests_per_lane=args.requests_per_lane,
        eos_id=0,
        backend="pc",
    )
    engine = GenerationEngine(model, params, ecfg)
    print(f"engine: {args.lanes} lanes x {args.requests_per_lane} requests, "
          f"program blocks: {len(engine.batched.lowered.blocks)}, "
          f"stacks: {len(engine.batched.lowered.stack_vars)} "
          f"(loop-only program -> none)")

    rng = np.random.default_rng(0)
    if args.open_loop:
        reqs = [
            Request(
                rid=i,
                prompt=rng.integers(
                    1, cfg.vocab_size,
                    int(rng.integers(1, ecfg.max_prompt_len + 1)),
                ).astype(np.int32),
                arrival=float(i) * 0.02,  # a 50 req/s trickle
            )
            for i in range(args.num_requests)
        ]
        # Warm-up: compile the segmented path off the measured run.
        engine.serve([Request(rid=0, prompt=np.array([1], np.int32))])
        comps, stats = engine.serve(
            reqs,
            on_finish=lambda c: print(
                f"  request {c.rid} done on lane {c.lane}: "
                f"{len(c.tokens)} tokens, latency {c.latency * 1e3:.1f}ms"
            ),
        )
        lat = np.array([c.latency for c in comps])
        print(f"served {stats.completions} requests / "
              f"{stats.generated_tokens} tokens in {stats.wall_time:.2f}s "
              f"over {stats.segments} segments; occupancy "
              f"{stats.occupancy:.2f}, p50 latency {np.percentile(lat, 50) * 1e3:.1f}ms, "
              f"p99 {np.percentile(lat, 99) * 1e3:.1f}ms")
        return

    prompts = rng.integers(
        1, cfg.vocab_size,
        (args.lanes, args.requests_per_lane, ecfg.max_prompt_len),
    ).astype(np.int32)
    plens = rng.integers(
        2, ecfg.max_prompt_len + 1, (args.lanes, args.requests_per_lane)
    ).astype(np.int32)

    res = engine.generate(prompts, plens)  # compile + run
    t0 = time.time()
    res = engine.generate(prompts, plens)
    dt = time.time() - t0
    total = int(res["lengths"].sum())
    print(f"generated {total} tokens in {dt:.2f}s "
          f"({total/dt:,.0f} tok/s), decode-batch utilization "
          f"{res['utilization']:.3f}")
    print("first lane, first request tokens:",
          res["tokens"][0, 0, : res['lengths'][0, 0]])

    if args.check:
        ref = engine.reference_generate(prompts, plens)
        ok = np.array_equal(res["tokens"], ref["tokens"])
        print("matches sequential oracle:", ok)


if __name__ == "__main__":
    main()
