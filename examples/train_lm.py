"""End-to-end training driver: a ~100M-parameter LM for a few hundred
steps with the full production substrate — sharded step, deterministic
resumable data, AdamW, atomic checkpoints, fault-tolerant restart.

    PYTHONPATH=src python examples/train_lm.py            # ~135M smollm
    PYTHONPATH=src python examples/train_lm.py --quick    # reduced config

The default trains the real SmolLM-135M architecture (30L/576d) at a
short sequence length so a few hundred steps finish on CPU; --quick uses
the reduced config for CI-speed sanity.  A simulated failure is injected
mid-run to demonstrate checkpoint/restart recovery.
"""
import argparse
import os
import shutil
import tempfile

import jax

from repro.launch.train import build_trainer
from repro.train import checkpoint as ckpt_lib
from repro.train import fault_tolerance as ft


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--inject-failure", action="store_true", default=True)
    args = ap.parse_args()

    if args.quick:
        steps = args.steps or 60
        kw = dict(seq_len=64, global_batch=8, smoke=True, lr=3e-3)
    else:
        steps = args.steps or 200
        kw = dict(seq_len=128, global_batch=4, smoke=False, lr=1e-3)

    model, params, opt_state, step, stream = build_trainer(
        "smollm-135m", steps=steps, microbatches=1, remat="none", **kw
    )
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"training smollm-135m ({n_params/1e6:.1f}M params) "
          f"for {steps} steps, batch {kw['global_batch']}x{kw['seq_len']}")

    def step_fn(state, i):
        p, o = state
        p, o, metrics = step(p, o, stream.batch(i))
        return (p, o), metrics

    ckpt_dir = tempfile.mkdtemp(prefix="repro_train_lm_")
    loop = ft.ResilientLoop(
        step_fn, ckpt_lib.Checkpointer(ckpt_dir), save_every=25
    )
    fail_at = {steps // 2} if args.inject_failure else set()

    def failure_hook(i):
        if i in fail_at:
            fail_at.remove(i)
            print(f"  !! injecting simulated node failure at step {i}")
            raise RuntimeError("simulated failure")

    (_, _), report = loop.run(
        (params, opt_state), steps,
        failure_hook=failure_hook, log_every=max(1, steps // 10),
    )
    print(f"final step {report.final_step}, restarts {report.restarts}")
    print(f"loss {report.losses[0]:.4f} -> {report.losses[-1]:.4f} "
          f"({'improved' if report.losses[-1] < report.losses[0] else 'NO'})")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
