#!/usr/bin/env python
"""irlint: run the lowered-IR verifier + static analyses over a program.

    PYTHONPATH=src python tools/irlint.py [--nuts] [--dce] [SPEC ...]

Each SPEC is ``module:attr`` or ``path/to/file.py:attr``, where ``attr``
resolves to an ``ir.Program``, a ``frontend.ProgramBuilder``, an
``AutobatchedFunction`` handle, or a zero-argument callable returning one
of those.  ``--nuts`` adds the built-in NUTS program (the paper's
experiment) to the lint set.

For every program, irlint:

1. lowers it with between-pass verification enabled,
2. runs the fusion pipeline (and, with ``--dce``, dead-code elimination)
   with the verifier executed between every pass,
3. prints the diagnostics report: block counts, op counts, VM state size,
   dead ops/state, the static stack-depth bound (or the recursive cycle
   that defeats it), and fusion provenance.

Exit status 1 if any program fails verification or any pass crashes —
this is the CI gate that keeps every example's lowered program honest.
"""
from __future__ import annotations

import argparse
import importlib
import importlib.util
import sys
from pathlib import Path


def _load_attr(spec: str):
    if ":" not in spec:
        raise SystemExit(f"irlint: bad spec {spec!r} (want module:attr)")
    mod_name, attr = spec.rsplit(":", 1)
    if mod_name.endswith(".py") or "/" in mod_name:
        path = Path(mod_name)
        if not path.exists():
            raise SystemExit(f"irlint: no such file: {path}")
        loaded = importlib.util.spec_from_file_location(path.stem, path)
        mod = importlib.util.module_from_spec(loaded)
        loaded.loader.exec_module(mod)
    else:
        mod = importlib.import_module(mod_name)
    try:
        return getattr(mod, attr)
    except AttributeError:
        raise SystemExit(f"irlint: {mod_name} has no attribute {attr!r}")


def _as_program(obj):
    """Resolve a spec'd object to an ir.Program."""
    from repro.core import batching, frontend, ir

    if isinstance(obj, ir.Program):
        return obj
    if isinstance(obj, frontend.ProgramBuilder):
        return obj.build()
    if isinstance(obj, batching.AutobatchedFunction):
        return obj.program
    if callable(obj):
        return _as_program(obj())
    raise SystemExit(
        f"irlint: cannot lint {type(obj).__name__} (want ir.Program, "
        "ProgramBuilder, AutobatchedFunction, or a callable returning one)"
    )


def _nuts_program():
    from repro.mcmc import nuts, targets

    t = targets.isotropic_gaussian(2)
    s = nuts.NutsSettings(max_tree_depth=3, num_steps=2, steps_per_leaf=2)
    return nuts.build_nuts_program(t, s)


def lint(name: str, program, *, dce: bool) -> bool:
    """Lower + fuse ``program`` under full verification; print diagnostics.

    Returns True on success, False if verification rejected the program
    or a pass crashed.
    """
    from repro.core import lowering, passes

    print(f"== {name} ==")
    try:
        low = lowering.lower(program, verify=True)
        pipe = list(passes.fusion_passes())
        if dce:
            pipe.append(passes.DeadCodeElimination())
        fused = passes.PassPipeline(pipe, verify=True, debug=True).run(low)
    except (passes.PassError, ValueError, TypeError) as e:
        print(f"FAILED: {e}")
        return False
    print(passes.diagnose(fused).pretty())
    prov = fused.fused_from
    n_src = len({s for srcs in prov.values() for s in srcs})
    print(
        f"provenance:    {len(fused.blocks)} superblocks cover "
        f"{n_src} of {len(low.blocks)} lowered blocks"
    )
    print()
    return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="irlint", description=__doc__.splitlines()[0]
    )
    ap.add_argument("specs", nargs="*", metavar="SPEC",
                    help="module:attr or path.py:attr to lint")
    ap.add_argument("--nuts", action="store_true",
                    help="also lint the built-in NUTS program")
    ap.add_argument("--dce", action="store_true",
                    help="include the dead-code-elimination pass")
    args = ap.parse_args(argv)
    if not args.specs and not args.nuts:
        ap.error("nothing to lint: pass SPECs and/or --nuts")

    targets_: list[tuple[str, object]] = []
    if args.nuts:
        targets_.append(("nuts (built-in)", _nuts_program()))
    for spec in args.specs:
        targets_.append((spec, _as_program(_load_attr(spec))))

    ok = True
    for name, prog in targets_:
        ok &= lint(name, prog, dce=args.dce)
    if not ok:
        print("irlint: FAILED")
        return 1
    print(f"irlint: {len(targets_)} program(s) verified clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
