"""Perf-iteration driver: run a cell with a named variant and diff the
roofline terms against the stored baseline artifact.

    PYTHONPATH=src python tools/hillclimb.py --arch X --shape Y \
        [--kv-int8] [--remat dots] [--microbatches 4] [--q-chunk 256] \
        [--window 2048] [--compress-grads] [--multi-pod] [--tag name]

Prints before/after for t_compute / t_memory / t_collective / peak and
appends a JSON record to benchmarks/artifacts/hillclimb_log.jsonl.
"""
from repro.launch import dryrun  # must be first (XLA_FLAGS)

import argparse
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "artifacts")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--param-bf16", action="store_true",
                    help="serve with bf16 weights (deployment checkpoint)")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--q-chunk", type=int, default=None)
    ap.add_argument("--window", type=int, default=None)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--tag", default="variant")
    ap.add_argument("--mesh", default=None,
                    help="logical mesh DxM over the same chips, e.g. 32x8")
    args = ap.parse_args()

    overrides = {}
    if args.kv_int8:
        overrides["kv_cache_dtype"] = "int8"
    if args.param_bf16:
        overrides["param_dtype"] = "bfloat16"
    if args.q_chunk:
        overrides["attn_q_chunk"] = args.q_chunk
    if args.window:
        overrides["long_context_window"] = args.window
    if args.capacity_factor:
        overrides["capacity_factor"] = args.capacity_factor

    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    base_path = os.path.join(
        ART, f"{args.arch}__{args.shape}__{mesh_name}.json"
    )
    base = json.load(open(base_path)) if os.path.exists(base_path) else None

    mesh_shape = (tuple(int(x) for x in args.mesh.split("x"))
                  if args.mesh else None)
    res = dryrun.run_cell(
        args.arch, args.shape, multi_pod=args.multi_pod,
        remat=args.remat, compress_grads=args.compress_grads,
        cfg_overrides=overrides or None, microbatches=args.microbatches,
        mesh_shape=mesh_shape, verbose=False,
    )
    res["variant"] = {
        "tag": args.tag, "overrides": overrides, "remat": args.remat,
        "mesh": args.mesh,
        "microbatches": args.microbatches,
        "compress_grads": args.compress_grads,
    }

    def row(name, b, v):
        delta = (v - b) / b * 100 if b else float("nan")
        print(f"  {name:16s} {b:12.4g} -> {v:12.4g}  ({delta:+.1f}%)")

    print(f"{args.arch} x {args.shape} on {mesh_name}  [{args.tag}]")
    if base:
        for k in ("t_compute", "t_memory", "t_collective",
                  "collective_bytes", "peak_bytes", "hlo_bytes",
                  "roofline_fraction"):
            row(k, float(base.get(k, 0)), float(res.get(k, 0)))
        if "t_memory_flash" in res and "t_memory_flash" in base:
            row("t_memory_flash", base["t_memory_flash"],
                res["t_memory_flash"])
    else:
        print(json.dumps({k: res[k] for k in (
            "t_compute", "t_memory", "t_collective", "peak_bytes",
            "roofline_fraction")}, indent=2, default=float))
    with open(os.path.join(ART, "hillclimb_log.jsonl"), "a") as f:
        f.write(json.dumps(res, default=float) + "\n")


if __name__ == "__main__":
    main()
