#!/usr/bin/env python
"""vmtrace: run an autobatched program with dispatch tracing and export
a Perfetto timeline plus a per-block profile.

    PYTHONPATH=src python tools/vmtrace.py [--nuts] [SPEC ...] \\
        [--out trace.json] [--blockprof profile.json]

Each SPEC is ``module:attr`` or ``path/to/file.py:attr``, where ``attr``
resolves to a zero-argument callable returning ``(fn, args)`` — an
``AutobatchedFunction`` (any trace/backend setting; vmtrace re-enables
tracing via ``with_options``) and the positional arguments to call it
with.  ``--nuts`` runs the built-in NUTS kernel (the paper's experiment)
at ``--batch`` chains.

For every program, vmtrace:

1. clones the handle with ``trace=<--capacity>`` (recording never changes
   execution — outputs, step counts and dispatch choices are bit-exact
   with tracing off),
2. runs it and drains the on-device dispatch ring buffer,
3. writes the Chrome/Perfetto trace-event JSON (``--out``; open it at
   https://ui.perfetto.dev), schema-validating what it wrote,
4. prints the per-block profile table (dispatch counts, mean residents,
   tile occupancy, wasted-slot attribution) and optionally saves the
   versioned block-frequency profile JSON (``--blockprof``) that the
   trace-driven superblock pass consumes.

Exit status 1 if any program fails to run, records no events, or emits
an invalid trace file — this is the CI smoke gate for the observability
surface.
"""
from __future__ import annotations

import argparse
import importlib
import importlib.util
import sys
from pathlib import Path


def _load_attr(spec: str):
    if ":" not in spec:
        raise SystemExit(f"vmtrace: bad spec {spec!r} (want module:attr)")
    mod_name, attr = spec.rsplit(":", 1)
    if mod_name.endswith(".py") or "/" in mod_name:
        path = Path(mod_name)
        if not path.exists():
            raise SystemExit(f"vmtrace: no such file: {path}")
        loaded = importlib.util.spec_from_file_location(path.stem, path)
        mod = importlib.util.module_from_spec(loaded)
        loaded.loader.exec_module(mod)
    else:
        mod = importlib.import_module(mod_name)
    try:
        return getattr(mod, attr)
    except AttributeError:
        raise SystemExit(f"vmtrace: {mod_name} has no attribute {attr!r}")


def _as_run(obj):
    """Resolve a spec'd object to ``(AutobatchedFunction, args)``."""
    from repro.core import batching

    if callable(obj) and not isinstance(obj, batching.AutobatchedFunction):
        obj = obj()
    if isinstance(obj, batching.AutobatchedFunction):
        raise SystemExit(
            "vmtrace: a bare AutobatchedFunction has no inputs to run "
            "with; point the SPEC at a zero-arg callable returning "
            "(fn, args)"
        )
    if (isinstance(obj, tuple) and len(obj) == 2
            and isinstance(obj[0], batching.AutobatchedFunction)):
        return obj
    raise SystemExit(
        f"vmtrace: cannot run {type(obj).__name__} (want a zero-arg "
        "callable returning (AutobatchedFunction, args))"
    )


def _nuts_run(batch: int):
    from repro.mcmc import nuts, targets

    t = targets.isotropic_gaussian(2)
    s = nuts.NutsSettings(max_tree_depth=3, num_steps=2, steps_per_leaf=2)
    kernel = nuts.make_nuts_kernel(t, s, backend="pc", batch_size=batch)
    return kernel, nuts.initial_state(t, batch, eps=0.1, seed=0)


def trace_one(name: str, fn, args, *, capacity, out, blockprof) -> bool:
    """Run ``fn(*args)`` with tracing on; export + validate artifacts."""
    from repro.obs import (
        block_profile, format_profile, validate_perfetto, write_perfetto,
    )

    print(f"== {name} ==")
    if fn.backend != "pc":
        print(f"FAILED: dispatch tracing needs the pc backend "
              f"(got {fn.backend!r})")
        return False
    traced = fn.with_options(trace=capacity)
    traced(*args)
    tr = traced.last_trace
    if tr is None or len(tr) == 0:
        print("FAILED: run recorded no dispatch events")
        return False
    print(f"dispatches: {tr.total_dispatches} "
          f"(captured {len(tr)}, dropped {tr.dropped}) "
          f"schedule={tr.schedule} batch={tr.batch_size}")
    if out:
        write_perfetto(out, tr)
        n = validate_perfetto(out)
        print(f"wrote {out}: {n} trace events (valid)")
    prof = block_profile(tr)
    print(format_profile(prof))
    if blockprof:
        prof.save(blockprof)
        print(f"wrote {blockprof}: block-frequency profile "
              f"(superblock-pass input)")
    print()
    return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="vmtrace", description=__doc__.splitlines()[0]
    )
    ap.add_argument("specs", nargs="*", metavar="SPEC",
                    help="module:attr or path.py:attr resolving to a "
                         "zero-arg callable returning (fn, args)")
    ap.add_argument("--nuts", action="store_true",
                    help="also trace the built-in NUTS kernel")
    ap.add_argument("--batch", type=int, default=32,
                    help="--nuts chain count (default 32)")
    ap.add_argument("--capacity", type=int, default=None,
                    help="trace ring-buffer capacity in dispatches "
                         "(default: obs.trace.DEFAULT_TRACE_CAPACITY; "
                         "older events beyond it are dropped)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the Perfetto trace-event JSON here")
    ap.add_argument("--blockprof", default=None, metavar="PATH",
                    help="write the block-frequency profile JSON here")
    args = ap.parse_args(argv)
    if not args.specs and not args.nuts:
        ap.error("nothing to trace: pass SPECs and/or --nuts")
    capacity = True if args.capacity is None else args.capacity

    runs: list[tuple[str, object, tuple]] = []
    if args.nuts:
        fn, fn_args = _nuts_run(args.batch)
        runs.append((f"nuts (built-in, batch={args.batch})", fn, fn_args))
    for spec in args.specs:
        fn, fn_args = _as_run(_load_attr(spec))
        runs.append((spec, fn, fn_args))

    ok = True
    for name, fn, fn_args in runs:
        ok &= trace_one(name, fn, fn_args, capacity=capacity,
                        out=args.out, blockprof=args.blockprof)
    if not ok:
        print("vmtrace: FAILED")
        return 1
    print(f"vmtrace: {len(runs)} program(s) traced")
    return 0


if __name__ == "__main__":
    sys.exit(main())
