#!/usr/bin/env python
"""pgo: trace a program, build a block-frequency profile, re-lower through
the profile-guided pipeline and verify the optimization paid off.

    PYTHONPATH=src python tools/pgo.py [--nuts] [SPEC ...] \\
        [--profile profile.json] [--save-profile profile.json]

Each SPEC is ``module:attr`` or ``path/to/file.py:attr``, where ``attr``
resolves to a zero-argument callable returning ``(fn, args)`` — an
``AutobatchedFunction`` and the positional arguments to call it with
(the same contract as ``tools/vmtrace.py``).  ``--nuts`` runs the
built-in NUTS kernel at ``--batch`` chains.

For every program, pgo:

1. runs it once with dispatch tracing on (``with_options(trace=...)``)
   and distills the trace into a :class:`repro.obs.blockprof.BlockProfile`
   — or loads a previously saved profile (``--profile``),
2. re-lowers through ``passes.pgo_passes`` via ``fn.optimize(profile)``:
   trace-driven superblock formation, hot-state layout packing, block
   reordering,
3. re-runs the optimized handle on the same inputs and checks the
   outputs are **bit-exact** with the baseline,
4. prints the before/after block counts, dispatch counts and masked
   state-update counts.

Exit status 1 if any program fails to run, the optimized outputs differ,
or the optimized run does not strictly reduce the dispatch count — this
is the CI smoke gate for the profile-guided optimization pipeline.
"""
from __future__ import annotations

import argparse
import sys

from vmtrace import _as_run, _load_attr, _nuts_run  # shared CLI contract


def pgo_one(name: str, fn, args, *, capacity, profile_path,
            save_profile) -> bool:
    """Baseline-trace, optimize and compare one program."""
    import numpy as np

    from repro.obs import block_profile, format_profile
    from repro.obs.blockprof import BlockProfile

    print(f"== {name} ==")
    if fn.backend != "pc":
        print(f"FAILED: profile-guided optimization needs the pc backend "
              f"(got {fn.backend!r})")
        return False

    traced = fn.with_options(trace=capacity)
    base_out = traced(*args)
    base = traced.scheduler_stats
    if base is None or base.steps is None:
        print("FAILED: baseline run collected no scheduler stats")
        return False
    if profile_path:
        prof = BlockProfile.load(profile_path)
        print(f"loaded {profile_path} (digest {prof.digest()})")
    else:
        tr = traced.last_trace
        if tr is None or len(tr) == 0:
            print("FAILED: baseline run recorded no dispatch events")
            return False
        prof = block_profile(tr)
    print(format_profile(prof))
    if save_profile:
        prof.save(save_profile)
        print(f"wrote {save_profile}: block-frequency profile "
              f"(digest {prof.digest()})")

    opt = fn.optimize(prof)
    opt_out = opt(*args)
    sched = opt.scheduler_stats
    layout = opt.lowered.state_layout
    groups = 0 if layout is None else len(layout.groups)
    print(f"blocks:         {base.num_blocks:6d} -> {sched.num_blocks:6d}"
          f"   (layout groups: {groups})")
    print(f"dispatches:     {base.steps:6d} -> {sched.steps:6d}")
    print(f"masked updates: {base.masked_updates:6d} -> "
          f"{sched.masked_updates:6d}")

    base_flat, _ = _flatten(base_out)
    opt_flat, _ = _flatten(opt_out)
    for i, (a, b) in enumerate(zip(base_flat, opt_flat)):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            print(f"FAILED: optimized output leaf {i} differs from baseline")
            return False
    print("outputs: bit-exact with baseline")
    if sched.steps >= base.steps:
        print(f"FAILED: dispatch count did not improve "
              f"({base.steps} -> {sched.steps})")
        return False
    print()
    return True


def _flatten(tree):
    import jax

    return jax.tree_util.tree_flatten(tree)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="pgo", description=__doc__.splitlines()[0]
    )
    ap.add_argument("specs", nargs="*", metavar="SPEC",
                    help="module:attr or path.py:attr resolving to a "
                         "zero-arg callable returning (fn, args)")
    ap.add_argument("--nuts", action="store_true",
                    help="also optimize the built-in NUTS kernel")
    ap.add_argument("--batch", type=int, default=32,
                    help="--nuts chain count (default 32)")
    ap.add_argument("--capacity", type=int, default=None,
                    help="trace ring-buffer capacity for the baseline run "
                         "(default: obs.trace.DEFAULT_TRACE_CAPACITY)")
    ap.add_argument("--profile", default=None, metavar="PATH",
                    help="reuse a saved block-frequency profile instead of "
                         "tracing a fresh one")
    ap.add_argument("--save-profile", default=None, metavar="PATH",
                    help="save the collected profile JSON here")
    args = ap.parse_args(argv)
    if not args.specs and not args.nuts:
        ap.error("nothing to optimize: pass SPECs and/or --nuts")
    capacity = True if args.capacity is None else args.capacity

    runs: list[tuple[str, object, tuple]] = []
    if args.nuts:
        fn, fn_args = _nuts_run(args.batch)
        runs.append((f"nuts (built-in, batch={args.batch})", fn, fn_args))
    for spec in args.specs:
        fn, fn_args = _as_run(_load_attr(spec))
        runs.append((spec, fn, fn_args))

    ok = True
    for name, fn, fn_args in runs:
        ok &= pgo_one(name, fn, fn_args, capacity=capacity,
                      profile_path=args.profile,
                      save_profile=args.save_profile)
    if not ok:
        print("pgo: FAILED")
        return 1
    print(f"pgo: {len(runs)} program(s) optimized")
    return 0


if __name__ == "__main__":
    sys.exit(main())
