"""Markdown link check: every relative link in the repo's *.md files must
point at an existing file (anchors and external URLs are skipped — no
network access in CI).

    python tools/check_links.py [paths...]      # default: repo *.md + docs/
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_file(path: Path) -> list[str]:
    errors = []
    for target in _LINK.findall(path.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if rel and not (path.parent / rel).exists():
            errors.append(f"{path.relative_to(REPO)}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    if argv:
        files = [Path(a) for a in argv]
    else:
        files = sorted(REPO.glob("*.md")) + sorted(REPO.glob("docs/*.md"))
    errors = []
    for f in files:
        errors += check_file(f)
    for e in errors:
        print(e, file=sys.stderr)
    print(f"[check_links: {len(files)} files, {len(errors)} broken links]")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
