"""Fault-injection chaos harness for the pc VM's containment layer.

    PYTHONPATH=src python tools/chaos.py [--rate 0.25] [--batch 16]
                                         [--seed 0] [--json PATH]

Builds one deliberately hostile program with four per-lane behaviours,
selected by a ``mode`` input:

* ``mode 0`` — healthy: a bounded Collatz-flavoured loop (the control).
* ``mode 1`` — NaN: writes ``0/0`` into VM state (``NONFINITE`` fault).
* ``mode 2`` — livelock: a data-dependent loop that never exits
  (``WATCHDOG`` fault via ``lane_step_budget``).
* ``mode 3`` — bomb: recursion deeper than ``max_depth``
  (``STACK_OVERFLOW`` fault).

For every cell of the schedule x fuse x mesh matrix it runs the batch
twice through the SAME executor — once fault-free (all lanes mode 0) and
once with faults injected at ``--rate`` (mix of modes 1-3) — under
``on_fault="quarantine"``, and asserts:

1. the chaotic run never aborts (no exception escapes the VM);
2. every injected lane reports exactly its expected fault code, and no
   healthy lane reports any fault;
3. healthy lanes' outputs are **bit-exact** with the fault-free run.

Exit status 1 on any violation; ``--json`` writes a strict-JSON record
per cell (CI uploads it next to the benchmark artifacts).
"""
from __future__ import annotations

import os

if "XLA_FLAGS" not in os.environ:  # before jax init: allow mesh cells
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO, os.path.join(_REPO, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core import batching, frontend, pc_vm  # noqa: E402
from repro.core.frontend import spec  # noqa: E402

I32 = spec((), jnp.int32)
F32 = spec((), jnp.float32)

#: Harness VM limits: the bomb recurses past MAX_DEPTH, the livelock spins
#: past LANE_STEP_BUDGET; both bounds clear every healthy lane's needs by
#: a wide margin (healthy lanes run < 200 dispatches, depth 2).
MAX_DEPTH = 8
LANE_STEP_BUDGET = 512
BOMB_DEPTH = 4 * MAX_DEPTH

#: mode -> expected per-lane fault code after a quarantined run.
EXPECT_CODE = {
    0: pc_vm.FAULT_OK,
    1: pc_vm.FAULT_NONFINITE,
    2: pc_vm.FAULT_WATCHDOG,
    3: pc_vm.FAULT_STACK_OVERFLOW,
}
FAULT_MODES = (1, 2, 3)


def build_chaos_program():
    """``chaos(x, mode) -> out``: per-lane behaviour selected by mode."""
    pb = frontend.ProgramBuilder(main="chaos")

    # Unbounded recursion helper (mode 3's stack bomb).
    rec = pb.function("rec", ["n"], ["r"], {"n": I32}, {"r": I32})
    rec.const(0, jnp.int32, out="r")
    rec.assign("go", lambda n: n > 0, ["n"], name="rec_cond")
    with rec.if_("go"):
        rec.assign("nm1", lambda n: n - 1, ["n"], name="rec_dec")
        rec.call("rec", ["nm1"], out="sub")
        rec.assign("r", lambda s: s + 1, ["sub"], name="rec_inc")
    rec.return_()
    pb.add(rec)

    fb = pb.function(
        "chaos", ["x", "mode"], ["out"],
        {"x": I32, "mode": I32}, {"out": F32},
    )
    fb.const(0.0, jnp.float32, out="out")
    # ---- healthy control work (every mode runs it) ----
    fb.assign("v", lambda x: (x % 97 + 1).astype(jnp.int32), ["x"],
              name="seed_v")
    fb.const(0, jnp.int32, out="i")
    with fb.while_(
        lambda i, v: jnp.logical_and(i < 32, v != 1), ["i", "v"]
    ):
        fb.assign(
            "v",
            lambda v: jnp.where(v % 2 == 0, v // 2, 3 * v + 1)
            .astype(jnp.int32),
            ["v"], name="collatz",
        )
        fb.assign("i", lambda i: i + 1, ["i"], name="inc_i")
    fb.assign("out", lambda v, i: (v * 100 + i).astype(jnp.float32),
              ["v", "i"], name="healthy_out")
    # ---- mode 1: non-finite write ----
    fb.assign("is_nan", lambda m: m == 1, ["mode"], name="sel_nan")
    with fb.if_("is_nan"):
        fb.assign("out", lambda o: o * jnp.float32(jnp.nan), ["out"],
                  name="poison")
    # ---- mode 2: livelock (v >= 1 here, forever) ----
    fb.assign("is_live", lambda m: m == 2, ["mode"], name="sel_live")
    with fb.if_("is_live"):
        with fb.while_(lambda v: v >= 1, ["v"]):
            fb.assign("v", lambda v: jnp.maximum(v, 1), ["v"],
                      name="spin")
    # ---- mode 3: recursion past max_depth ----
    fb.assign("is_bomb", lambda m: m == 3, ["mode"], name="sel_bomb")
    with fb.if_("is_bomb"):
        fb.const(BOMB_DEPTH, jnp.int32, out="bomb_n")
        fb.call("rec", ["bomb_n"], out="deep")
        fb.assign("out", lambda d: d.astype(jnp.float32), ["deep"],
                  name="bomb_out")
    fb.return_()
    pb.add(fb)
    return pb.build()


class ChaosModel:
    """LM wrapper injecting per-lane serving faults, keyed by sentinel
    prompt tokens (``benchmarks/serve_bench --chaos``).

    * prompt ``[nan_token]`` (vocab-1): the lane's KV-cache slice is
      poisoned with NaN on its first decode — the VM's opt-in
      ``detect_nonfinite`` check faults the lane (``NONFINITE``) the
      moment the poisoned cache is written back into VM state.
    * prompt ``[slow_token]`` (vocab-2): logits are forced to re-emit
      ``slow_token`` forever, so the lane never reaches EOS and burns
      decode steps until the ``lane_step_budget`` watchdog fires
      (``WATCHDOG`` — the serving analogue of a livelock).
    * any other token: behaves like the wrapped model, except EOS is
      forced once ``pos >= eos_pos`` so healthy requests finish in
      bounded, *known* work (which makes the watchdog budget separable:
      healthy lanes execute < 2x a calibrated fault-free run, slow lanes
      need ~``max_new/eos_pos`` x).

    Faults only touch the injecting lane's own batch slice, so healthy
    lanes' tokens are bit-exact with a chaos-free serve of the same
    requests.
    """

    def __init__(self, inner, *, eos_pos: int, eos_id: int = 0):
        from repro.serve.engine import _cache_layout

        self.inner = inner
        self.cfg = inner.cfg
        self.nan_token = inner.cfg.vocab_size - 1
        self.slow_token = inner.cfg.vocab_size - 2
        self.eos_pos = eos_pos
        self.eos_id = eos_id
        # Per-leaf batch axes of the native cache layout (window-invariant).
        _, self._axes, _ = _cache_layout(inner, 8)

    def init(self, key):
        return self.inner.init(key)

    def init_cache(self, batch: int, window: int):
        return self.inner.init_cache(batch, window)

    def decode_step(self, params, cache, token, pos):
        logits, new_cache = self.inner.decode_step(params, cache, token,
                                                   pos)
        is_slow = token == self.slow_token
        is_nan = token == self.nan_token
        floor = jnp.full_like(logits, -1e9)
        slow_logits = floor.at[:, self.slow_token].set(0.0)
        eos_logits = floor.at[:, self.eos_id].set(0.0)
        force_eos = jnp.logical_and(
            jnp.logical_not(is_slow), pos >= self.eos_pos
        )
        logits = jnp.where(is_slow[:, None], slow_logits, logits)
        logits = jnp.where(force_eos[:, None], eos_logits, logits)
        poison = jnp.where(is_nan, jnp.float32(jnp.nan), jnp.float32(0.0))
        leaves, treedef = jax.tree_util.tree_flatten(new_cache)
        out_leaves = []
        for leaf, ax in zip(leaves, self._axes):
            if jnp.issubdtype(leaf.dtype, jnp.inexact):
                shape = [1] * leaf.ndim
                shape[ax] = -1
                leaf = leaf + poison.reshape(shape).astype(leaf.dtype)
            out_leaves.append(leaf)
        return logits, jax.tree_util.tree_unflatten(treedef, out_leaves)


def make_modes(batch: int, rate: float, seed: int) -> np.ndarray:
    """Per-lane fault modes: ~``rate`` of the batch split across modes 1-3
    (at least one lane of each kind when any faults are requested)."""
    rng = np.random.default_rng(seed)
    modes = np.zeros((batch,), np.int32)
    n_fault = int(round(batch * rate))
    if rate > 0:
        n_fault = max(n_fault, len(FAULT_MODES))
    n_fault = min(n_fault, batch - 1)  # keep at least one healthy lane
    lanes = rng.choice(batch, size=n_fault, replace=False)
    for i, lane in enumerate(lanes):
        modes[lane] = FAULT_MODES[i % len(FAULT_MODES)]
    return modes


def run_cell(program, *, batch: int, modes: np.ndarray, schedule: str,
             fuse: bool, mesh, seed: int) -> dict:
    """One matrix cell: clean + chaotic run through one executor."""
    batched = batching.autobatch(
        program,
        backend="pc", batch_size=batch, max_depth=MAX_DEPTH,
        max_steps=200_000, schedule=schedule, fuse=fuse, mesh=mesh,
        on_fault="quarantine", detect_nonfinite=True,
        lane_step_budget=LANE_STEP_BUDGET,
    )
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 10_000, (batch,)).astype(np.int32)
    record = {
        "schedule": schedule, "fuse": fuse, "mesh": mesh or 1,
        "batch": batch,
        "injected": {
            pc_vm.FAULT_NAMES[EXPECT_CODE[m]]: int((modes == m).sum())
            for m in FAULT_MODES
        },
        "violations": [],
    }

    clean = np.asarray(batched(jnp.asarray(x),
                               jnp.zeros((batch,), jnp.int32))["out"])
    clean_codes = np.asarray(
        jax.device_get(batched.last_result.fault_code)
    )
    if clean_codes.any():
        record["violations"].append(
            f"fault-free run reported faults: {clean_codes.tolist()}"
        )

    try:
        chaotic = np.asarray(
            batched(jnp.asarray(x), jnp.asarray(modes))["out"]
        )
    except Exception as e:  # criterion 1: must never abort
        record["violations"].append(
            f"chaotic run aborted: {type(e).__name__}: {e}"
        )
        return record
    codes = np.asarray(jax.device_get(batched.last_result.fault_code))

    expect = np.array([EXPECT_CODE[int(m)] for m in modes], np.int32)
    if not np.array_equal(codes, expect):
        bad = np.flatnonzero(codes != expect)
        record["violations"].append(
            "fault codes != expected at lanes "
            f"{bad.tolist()}: got {codes[bad].tolist()}, "
            f"want {expect[bad].tolist()}"
        )
    healthy = modes == 0
    if not np.array_equal(chaotic[healthy], clean[healthy]):
        bad = np.flatnonzero(healthy & (chaotic != clean))
        record["violations"].append(
            f"healthy lanes not bit-exact at {bad.tolist()}: "
            f"chaotic {chaotic[bad].tolist()} vs clean {clean[bad].tolist()}"
        )
    record["healthy_lanes"] = int(healthy.sum())
    record["faulted_lanes"] = int((codes != 0).sum())
    record["ok"] = not record["violations"]
    return record


def run_matrix(*, batch: int = 16, rate: float = 0.25,
               seed: int = 0) -> list[dict]:
    """The full schedule x fuse x mesh containment matrix."""
    program = build_chaos_program()
    modes = make_modes(batch, rate, seed)
    meshes = [None]
    if jax.device_count() >= 2 and batch % 2 == 0:
        meshes.append(2)
    records = []
    for schedule in pc_vm.SCHEDULES:
        for fuse in (True, False):
            for mesh in meshes:
                records.append(run_cell(
                    program, batch=batch, modes=modes,
                    schedule=schedule, fuse=fuse, mesh=mesh, seed=seed,
                ))
    return records


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.25,
                    help="fraction of lanes injected with faults "
                         "(split across NaN / livelock / overflow)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-cell records (strict JSON)")
    args = ap.parse_args(argv)
    if not 0.0 < args.rate < 1.0:
        ap.error(f"--rate must be in (0, 1), got {args.rate}")
    records = run_matrix(batch=args.batch, rate=args.rate, seed=args.seed)
    bad = [r for r in records if not r.get("ok")]
    for r in records:
        cell = (f"schedule={r['schedule']:<9} fuse={int(r['fuse'])} "
                f"mesh={r['mesh']}")
        if r.get("ok"):
            print(f"[ok]   {cell}  healthy={r['healthy_lanes']} "
                  f"faulted={r['faulted_lanes']}")
        else:
            print(f"[FAIL] {cell}")
            for v in r["violations"]:
                print(f"       - {v}")
    print(f"\nchaos matrix: {len(records) - len(bad)}/{len(records)} "
          f"cells clean (batch={args.batch}, rate={args.rate}, "
          f"seed={args.seed})")
    if args.json:
        from benchmarks.common import write_json
        write_json(args.json, {
            "benchmark": "chaos_matrix",
            "config": {"batch": args.batch, "rate": args.rate,
                       "seed": args.seed},
            "records": records,
        })
        print(f"[wrote {args.json}: {len(records)} records]")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
