"""Sweep the full dry-run matrix, one JSON artifact per cell.

    PYTHONPATH=src python tools/run_matrix.py [--multi-pod] [--only arch]

Resilient: failures are recorded as artifacts with an "error" field and
the sweep continues.  Already-present artifacts are skipped unless
--force.
"""
# NOTE: importing repro.launch.dryrun FIRST sets XLA_FLAGS before jax init.
from repro.launch import dryrun  # noqa: E402  (must be first)

import argparse
import json
import os
import time
import traceback

ART = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "artifacts")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(ART, exist_ok=True)
    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    cells = dryrun.all_cells()
    if args.only:
        cells = [c for c in cells if c[0] == args.only]
    t_start = time.time()
    for i, (arch, shape) in enumerate(cells):
        path = os.path.join(ART, f"{arch}__{shape}__{mesh_name}.json")
        if os.path.exists(path) and not args.force:
            print(f"[{i+1}/{len(cells)}] skip {arch} x {shape} (exists)")
            continue
        print(f"[{i+1}/{len(cells)}] {arch} x {shape} on {mesh_name} ...",
              flush=True)
        t0 = time.time()
        try:
            res = dryrun.run_cell(
                arch, shape, multi_pod=args.multi_pod, verbose=False
            )
        except Exception as e:
            res = {
                "arch": arch, "shape": shape, "mesh": mesh_name,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:],
            }
            print(f"    FAILED: {res['error']}", flush=True)
        with open(path, "w") as f:
            json.dump(res, f, indent=2, default=float)
        if "error" not in res:
            print(f"    ok {time.time()-t0:.0f}s bound={res['bottleneck']} "
                  f"peak={res['peak_bytes']/1e9:.1f}GB "
                  f"roof={res['roofline_fraction']:.4f}", flush=True)
    print(f"matrix done in {(time.time()-t_start)/60:.1f} min")


if __name__ == "__main__":
    main()
