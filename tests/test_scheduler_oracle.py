"""Scheduler-oracle differential tests (ISSUE 8 satellite 1).

A pure-NumPy reference scheduler replays the VM's block choices from
observed snapshots: drive a Stepper one loop iteration at a time, read
``pc_top`` *before* the step, predict the dispatch with the oracle, and
check the prediction against which ``block_exec`` counter actually
incremented.  This pins the traced ``_pick_block`` (min / histogram
argmax / lookahead scoring, including its tie-breaks) to an independent
executable spec — a schedule regression shows up as a divergent dispatch
sequence, not just a slower benchmark.

The oracle rebuilds the lookahead successor matrix from the lowered
terminators itself (LJump -> target, LBranch -> both arms, LPushJump ->
callee entry only, LReturn -> none), so an IR-side change to the CFG
feeds both sides independently.

Compaction (``compact_every=1``) runs the same oracle unchanged: every
schedule reduces a lane-permutation-invariant statistic, so the pick
sequence must be identical however rows are shuffled.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.core import batching, ir
from tests.test_core_property import _Gen

MAX_ITERS = 400
MIN_DISPATCHES = 20  # a trace shorter than this isn't exercising much


def _succ_matrix(lowered) -> np.ndarray:
    """[B, B] 0/1 CFG successor matrix, rebuilt independently of pc_vm."""
    nb = len(lowered.blocks)
    succ = np.zeros((nb, nb), np.int64)
    for i, blk in enumerate(lowered.blocks):
        t = blk.term
        if isinstance(t, ir.LJump):
            targets = (t.target,)
        elif isinstance(t, ir.LBranch):
            targets = (t.true, t.false)
        elif isinstance(t, ir.LPushJump):
            targets = (t.target,)
        else:
            targets = ()
        for s in targets:
            if 0 <= s < nb:
                succ[i, s] = 1
    return succ


def _oracle_pick(pc: np.ndarray, exit_idx: int, num_blocks: int,
                 schedule: str, succ: np.ndarray) -> int:
    live = pc < exit_idx
    if schedule == "earliest":
        return int(np.min(np.where(live, pc, exit_idx)))
    counts = np.bincount(pc[live], minlength=num_blocks)[:num_blocks]
    if schedule == "popular":
        return int(np.argmax(counts))
    assert schedule == "lookahead"
    score = 2 * counts + succ @ counts
    score = np.where(counts > 0, score, -1)
    return int(np.argmax(score))


def _seeded_inputs(seed: int, z: int = 8):
    rng = np.random.default_rng(seed)
    prog = _Gen(rng).build()
    n = rng.integers(0, 5, size=z).astype(np.int32)
    x = rng.integers(-50, 51, size=z).astype(np.int32)
    return prog, n, x


@pytest.mark.parametrize("seed", [0, 3])
@pytest.mark.parametrize("schedule", ["earliest", "popular", "lookahead"])
@pytest.mark.parametrize("compact_every", [None, 1])
def test_scheduled_dispatches_match_numpy_oracle(seed, schedule,
                                                 compact_every):
    prog, n, x = _seeded_inputs(seed)
    fn = batching.autobatch(
        prog, backend="pc", max_depth=64, max_steps=200_000,
        schedule=schedule, compact_every=compact_every,
    )
    st = fn.stepper(n, x)
    state = st.init()
    vm = st.vm
    exit_idx = vm.lowered.exit_index
    nb = vm.num_blocks
    succ = _succ_matrix(vm.lowered)
    dispatches = 0
    for _ in range(MAX_ITERS):
        if st.done(state):
            break
        pc = np.asarray(jax.device_get(state["pc_top"]))
        before = np.asarray(jax.device_get(state["block_exec"]))
        want = _oracle_pick(pc, exit_idx, nb, schedule, succ)
        state = st.step(state, 1)
        delta = np.asarray(jax.device_get(state["block_exec"])) - before
        assert delta.sum() == 1, (
            f"one scheduled dispatch must run exactly one block; got {delta}"
        )
        got = int(np.argmax(delta))
        assert got == want, (
            f"dispatch {dispatches}: VM picked block {got}, "
            f"oracle says {want} (schedule={schedule}, "
            f"compact_every={compact_every}, pc histogram="
            f"{np.bincount(pc[pc < exit_idx], minlength=nb)[:nb]})"
        )
        dispatches += 1
    assert st.done(state), "trace did not finish within MAX_ITERS"
    assert dispatches >= MIN_DISPATCHES


@pytest.mark.parametrize("seed", [0, 3])
@pytest.mark.parametrize("compact_every", [None, 1])
def test_sweep_dispatches_cover_residents(seed, compact_every):
    """One sweep iteration counts every block that was resident at sweep
    start exactly once (lanes parked in block b cannot move until b's
    turn), and only ever increments a counter by 0 or 1.  Blocks beyond
    the resident set may legitimately count too — lanes that advance
    mid-sweep into a later block are swept the same iteration."""
    prog, n, x = _seeded_inputs(seed)
    fn = batching.autobatch(
        prog, backend="pc", max_depth=64, max_steps=200_000,
        schedule="sweep", compact_every=compact_every,
    )
    st = fn.stepper(n, x)
    state = st.init()
    vm = st.vm
    exit_idx = vm.lowered.exit_index
    nb = vm.num_blocks
    sweeps = 0
    for _ in range(MAX_ITERS):
        if st.done(state):
            break
        pc = np.asarray(jax.device_get(state["pc_top"]))
        before = np.asarray(jax.device_get(state["block_exec"]))
        resident = np.zeros(nb, bool)
        resident[pc[pc < exit_idx]] = True
        state = st.step(state, 1)
        delta = np.asarray(jax.device_get(state["block_exec"])) - before
        assert set(np.unique(delta)) <= {0, 1}
        assert np.all(delta[resident] == 1), (
            f"sweep {sweeps} skipped a resident block: residents="
            f"{np.flatnonzero(resident)}, counted={np.flatnonzero(delta)}"
        )
        sweeps += 1
    assert st.done(state), "trace did not finish within MAX_ITERS"
    assert sweeps >= 2
