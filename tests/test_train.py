"""Training substrate tests: optimization, accumulation equivalence,
checkpoint atomicity/validity, fault-tolerant restart, stragglers."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ShapeSpec
from repro.models import get_model
from repro.train import checkpoint as ckpt_lib
from repro.train import data as data_lib
from repro.train import fault_tolerance as ft
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts

SHAPE = ShapeSpec("t", 32, 4, "train")


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("smollm-135m")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    stream = data_lib.SyntheticStream(model, SHAPE)
    return model, params, stream


class TestOptimizer:
    def test_loss_decreases(self, setup):
        model, params, stream = setup
        tcfg = ts.TrainConfig(
            opt=opt_lib.OptimizerConfig(
                peak_lr=1e-2, warmup_steps=5, total_steps=60
            )
        )
        step = jax.jit(ts.make_train_step(model, tcfg))
        state = opt_lib.init_opt_state(params, tcfg.opt)
        p = params
        first = last = None
        for i in range(60):
            p, state, m = step(p, state, stream.batch(i))
            if i < 5:
                first = float(m["loss"]) if first is None else first
            last = float(m["loss"])
        assert last < first - 0.5, (first, last)

    def test_lr_schedule(self):
        cfg = opt_lib.OptimizerConfig(
            peak_lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1
        )
        assert float(opt_lib.lr_schedule(cfg, jnp.asarray(0))) == 0.0
        assert float(opt_lib.lr_schedule(cfg, jnp.asarray(10))) == (
            pytest.approx(1.0)
        )
        assert float(opt_lib.lr_schedule(cfg, jnp.asarray(100))) == (
            pytest.approx(0.1)
        )

    def test_grad_accumulation_equivalence(self, setup):
        """k microbatches == one big batch (same update, fp tolerance)."""
        model, params, stream = setup
        batch = stream.batch(0)
        ocfg = opt_lib.OptimizerConfig(peak_lr=1e-3, warmup_steps=0,
                                       total_steps=10)
        one = jax.jit(ts.make_train_step(model, ts.TrainConfig(
            microbatches=1, opt=ocfg)))
        four = jax.jit(ts.make_train_step(model, ts.TrainConfig(
            microbatches=4, opt=ocfg)))
        s0 = opt_lib.init_opt_state(params, ocfg)
        p1, _, m1 = one(params, s0, batch)
        p4, _, m4 = four(params, s0, batch)
        np.testing.assert_allclose(
            float(m1["loss"]), float(m4["loss"]), rtol=1e-5
        )
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5
            )

    def test_int8_compression_error_feedback(self):
        """Compression error is carried, not lost: sum over steps of the
        restored gradients converges to the sum of true gradients."""
        g = {"g": jax.random.normal(jax.random.PRNGKey(0), (128,)) * 0.01}
        err = {"g": jnp.zeros((128,))}
        total = jnp.zeros((128,))
        for _ in range(50):
            restored, err = opt_lib.compress_with_feedback(g, err)
            total = total + restored["g"]
        np.testing.assert_allclose(
            np.asarray(total), np.asarray(g["g"]) * 50, rtol=0.02, atol=1e-4
        )


class TestData:
    def test_deterministic_and_resumable(self, setup):
        model, _, _ = setup
        s1 = data_lib.SyntheticStream(model, SHAPE)
        s2 = data_lib.SyntheticStream(model, SHAPE)
        b1 = s1.batch(7)
        _ = s2.batch(3)  # different call history
        b2 = s2.batch(7)
        for k in b1:
            np.testing.assert_array_equal(np.asarray(b1[k]), np.asarray(b2[k]))

    def test_markov_structure_learnable(self, setup):
        model, _, stream = setup
        toks = np.asarray(stream.batch(0)["tokens"])
        v = model.cfg.vocab_size
        mult = stream.cfg.mult
        # check t_{i+1} - (a t_i + 17) mod V is small (the noise)
        pred = (toks[:, :-1].astype(np.int64) * mult + 17) % v
        diff = (toks[:, 1:].astype(np.int64) - pred) % v
        assert diff.max() < stream.cfg.noise_levels


class TestCheckpoint:
    def test_roundtrip(self, tmp_path, setup):
        model, params, _ = setup
        c = ckpt_lib.Checkpointer(str(tmp_path), async_save=False)
        c.save(3, params)
        assert c.latest_step() == 3
        restored = c.restore(3, like=params)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_corrupt_checkpoint_skipped(self, tmp_path, setup):
        model, params, _ = setup
        c = ckpt_lib.Checkpointer(str(tmp_path), async_save=False)
        c.save(1, params)
        c.save(2, params)
        # corrupt the newest payload
        path = os.path.join(str(tmp_path), "step_00000002", "arrays.npz")
        with open(path, "r+b") as f:
            f.seek(100)
            f.write(b"\xde\xad\xbe\xef" * 8)
        assert c.latest_step() == 1  # falls back to the valid one

    def test_async_save_joins(self, tmp_path, setup):
        model, params, _ = setup
        c = ckpt_lib.Checkpointer(str(tmp_path), async_save=True)
        c.save(5, params)
        c.wait()
        assert c.latest_step() == 5

    def test_gc_keeps_k(self, tmp_path, setup):
        model, params, _ = setup
        c = ckpt_lib.Checkpointer(str(tmp_path), keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            c.save(s, params)
        assert c.all_steps() == [3, 4]


class TestFaultTolerance:
    def _make_step(self, setup):
        model, params, stream = setup
        tcfg = ts.TrainConfig(opt=opt_lib.OptimizerConfig(
            peak_lr=1e-3, warmup_steps=0, total_steps=100))
        raw = jax.jit(ts.make_train_step(model, tcfg))

        def step_fn(state, i):
            p, o = state
            p, o, m = raw(p, o, stream.batch(i))
            return (p, o), m

        return step_fn, (params, opt_lib.init_opt_state(params, tcfg.opt))

    def test_restart_recovers_and_replays(self, tmp_path, setup):
        step_fn, state = self._make_step(setup)
        # ground truth: run 30 steps without failures
        c0 = ckpt_lib.Checkpointer(str(tmp_path / "a"), async_save=False)
        loop = ft.ResilientLoop(step_fn, c0, save_every=10)
        truth, rep0 = loop.run(state, 30)
        assert rep0.restarts == 0
        # now with two injected failures
        c1 = ckpt_lib.Checkpointer(str(tmp_path / "b"), async_save=False)
        loop = ft.ResilientLoop(step_fn, c1, save_every=10)
        fails = {13, 27}

        def failure_hook(i):
            if i in fails:
                fails.remove(i)
                raise RuntimeError("simulated node failure")

        recovered, rep = loop.run(state, 30, failure_hook=failure_hook)
        assert rep.restarts == 2
        assert rep.final_step == 30
        for a, b in zip(jax.tree.leaves(truth), jax.tree.leaves(recovered)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)

    def test_straggler_detection(self):
        pol = ft.StragglerPolicy(threshold=2.0, warmup=3)
        for i in range(10):
            assert not pol.observe(i, 0.1)
        assert pol.observe(10, 0.5)  # 5x the EMA
        assert len(pol.flagged) == 1
        # EMA not polluted by the outlier
        assert not pol.observe(11, 0.12)

    def test_fresh_loop_resumes_from_checkpoint(self, tmp_path, setup):
        """Process-death replay: a brand-new ResilientLoop over the same
        checkpoint directory resumes at the last snapshot (no recompute
        of finished steps) and lands bit-close to an uninterrupted run."""
        step_fn, state = self._make_step(setup)
        c0 = ckpt_lib.Checkpointer(str(tmp_path / "t"), async_save=False)
        truth, _ = ft.ResilientLoop(step_fn, c0, save_every=10).run(
            state, 30
        )
        # "Crash" after 20 steps: the first loop simply stops there.
        c1 = ckpt_lib.Checkpointer(str(tmp_path / "r"), async_save=False)
        ft.ResilientLoop(step_fn, c1, save_every=10).run(state, 20)
        # A fresh loop (new process analogue) picks up at step 20.
        c2 = ckpt_lib.Checkpointer(str(tmp_path / "r"), async_save=False)
        resumed, rep = ft.ResilientLoop(step_fn, c2, save_every=10).run(
            state, 30
        )
        assert rep.final_step == 30
        assert len(rep.losses) == 10  # only steps 20..30 re-ran
        for a, b in zip(jax.tree.leaves(truth), jax.tree.leaves(resumed)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)

    def test_max_restarts_exceeded_reraises(self, tmp_path, setup):
        step_fn, state = self._make_step(setup)
        ck = ckpt_lib.Checkpointer(str(tmp_path / "m"), async_save=False)
        loop = ft.ResilientLoop(step_fn, ck, save_every=10,
                                max_restarts=2)

        def always_fail(i):
            if i == 5:
                raise RuntimeError("persistent node failure")

        with pytest.raises(RuntimeError, match="persistent"):
            loop.run(state, 30, failure_hook=always_fail)

    def test_reshard_roundtrip(self, setup):
        """Elastic resharding: move a pytree to explicit device placements
        and back — values bit-exact, placement as requested."""
        model, params, _ = setup
        devs = jax.devices()
        if len(devs) < 2:
            pytest.skip("needs >= 2 devices (see tests/conftest.py)")
        sh1 = jax.tree.map(
            lambda _: jax.sharding.SingleDeviceSharding(devs[1]), params
        )
        moved = ft.reshard(params, sh1)
        for leaf in jax.tree.leaves(moved):
            assert leaf.devices() == {devs[1]}
        sh0 = jax.tree.map(
            lambda _: jax.sharding.SingleDeviceSharding(devs[0]), params
        )
        back = ft.reshard(moved, sh0)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_restore_onto_new_shardings(self, tmp_path, setup):
        """Checkpoint saved on one placement restores directly onto
        another (mesh change across restart) without a value change."""
        model, params, _ = setup
        devs = jax.devices()
        if len(devs) < 2:
            pytest.skip("needs >= 2 devices (see tests/conftest.py)")
        ck = ckpt_lib.Checkpointer(str(tmp_path / "e"), async_save=False)
        ck.save(1, params)
        sh1 = jax.tree.map(
            lambda _: jax.sharding.SingleDeviceSharding(devs[1]), params
        )
        restored = ck.restore(1, like=params, shardings=sh1)
        for leaf in jax.tree.leaves(restored):
            assert leaf.devices() == {devs[1]}
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
