"""Stepper API tests: segmented execution as a first-class surface of
``batching.autobatch`` — state-in/state-out, cache sharing with plain
calls, masked inject/park, and snapshot introspection.

The schedule x fuse x mesh bit-exactness *matrix* lives in
``tests/test_core_property.py``; these tests pin the API contract on a
single well-understood program (fib)."""
import numpy as np
import pytest

from repro.core import batching
from tests.test_core import build_fib, FIB


@pytest.fixture(scope="module")
def fib_fn():
    return batching.autobatch(build_fib(), backend="pc", max_depth=24)


class TestStepperBasics:
    def test_segments_match_single_shot(self, fib_fn):
        n = np.array([0, 3, 7, 11], np.int32)
        single = np.asarray(fib_fn(n)["out"])
        st = fib_fn.stepper(n)
        state = st.init()
        hops = 0
        while not st.done(state):
            state = st.step(state, 5)
            hops += 1
            assert hops < 10_000
        np.testing.assert_array_equal(np.asarray(st.result(state)["out"]),
                                      single)
        assert hops > 1  # actually exercised multiple segments
        assert st.steps(state) == int(fib_fn.last_result.steps)

    def test_stepper_shares_executor_cache(self, fib_fn):
        """stepper() is cache-keyed like lower(): no second VM is built
        for a batch size that already has an executor."""
        n = np.array([1, 2, 3, 4], np.int32)
        fib_fn(n)
        before = fib_fn.cache_info()
        st = fib_fn.stepper(n)
        after = fib_fn.cache_info()
        assert (before.lowerings, before.traces) == \
            (after.lowerings, after.traces)
        assert st.vm is fib_fn._executor(4).vm

    def test_lane_done_and_outputs_mid_flight(self, fib_fn):
        """lane_done flips per lane as it halts; a halted lane's output
        row is final even while other lanes are still running."""
        n = np.array([0, 11], np.int32)  # lane 0 trivial, lane 1 deep
        st = fib_fn.stepper(n)
        state = st.init()
        state = st.step(state, 3)  # enough for fib(0), nowhere near fib(11)
        done = np.asarray(st.lane_done(state))
        assert done[0] and not done[1]
        assert np.asarray(st.outputs(state)["out"])[0] == FIB[0]
        while not st.done(state):
            state = st.step(state, 64)
        np.testing.assert_array_equal(np.asarray(st.outputs(state)["out"]),
                                      FIB[n])

    def test_done_when_max_steps_exhausted(self):
        """done() flips once the max_steps budget is spent, exactly when a
        single-shot call would return (converged=False) — the drive loop
        must not hang on a lane that cannot halt within budget."""
        fn = batching.autobatch(build_fib(), backend="pc", max_depth=24,
                                max_steps=5)
        st = fn.stepper(np.array([11, 11], np.int32))
        state = st.init()
        hops = 0
        while not st.done(state):
            state = st.step(state, 3)
            hops += 1
            assert hops < 100
        assert st.steps(state) == 5
        assert not np.asarray(st.lane_done(state)).any()  # budget, not halt

    def test_requires_pc_backend(self):
        fn = batching.autobatch(build_fib(), backend="local")
        with pytest.raises(ValueError, match="pc"):
            fn.stepper(np.array([1, 2], np.int32))

    def test_init_rebinds_values(self, fib_fn):
        st = fib_fn.stepper(np.array([1, 2, 3, 4], np.int32))
        state = st.init(np.array([5, 6, 7, 8], np.int32))
        while not st.done(state):
            state = st.step(state, 64)
        np.testing.assert_array_equal(
            np.asarray(st.outputs(state)["out"]), FIB[[5, 6, 7, 8]]
        )

    def test_batch_size_mismatch_raises(self, fib_fn):
        st = fib_fn.stepper(np.array([1, 2, 3, 4], np.int32))
        with pytest.raises(TypeError, match="batch"):
            st.init(np.array([1, 2], np.int32))


class TestInjectAndPark:
    def test_inject_reinitializes_masked_lanes_only(self, fib_fn):
        n = np.array([2, 9, 4, 6], np.int32)
        st = fib_fn.stepper(n)
        state = st.init()
        while not st.done(state):
            state = st.step(state, 32)
        mask = np.array([True, False, True, False])
        state = st.inject(state, mask,
                          np.array([10, 0, 8, 0], np.int32))
        done = np.asarray(st.lane_done(state))
        np.testing.assert_array_equal(done, ~mask)  # injected lanes re-arm
        while not st.done(state):
            state = st.step(state, 32)
        np.testing.assert_array_equal(
            np.asarray(st.outputs(state)["out"]), FIB[[10, 9, 8, 6]]
        )

    def test_park_idles_lanes(self, fib_fn):
        n = np.array([7, 7, 7, 7], np.int32)
        st = fib_fn.stepper(n)
        state = st.init()
        state = st.park(state, np.array([True, True, True, True]))
        assert st.done(state)
        assert st.steps(state) == 0  # parked lanes never dispatch
        # Refill two parked lanes and only they run.
        state = st.inject(state, np.array([True, False, True, False]),
                          np.array([3, 0, 5, 0], np.int32))
        while not st.done(state):
            state = st.step(state, 64)
        out = np.asarray(st.outputs(state)["out"])
        assert out[0] == FIB[3] and out[2] == FIB[5]

    def test_steps_accumulate_across_inject(self, fib_fn):
        n = np.array([3, 3, 3, 3], np.int32)
        st = fib_fn.stepper(n)
        state = st.init()
        while not st.done(state):
            state = st.step(state, 64)
        first = st.steps(state)
        state = st.inject(state, np.ones(4, bool), n)
        while not st.done(state):
            state = st.step(state, 64)
        assert st.steps(state) == 2 * first
