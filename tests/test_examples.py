"""Smoke-test the example scripts end to end: they are user-facing entry
points and must keep running as the API evolves.  Each runs in a
subprocess (clean jax state, same interpreter) at tiny sizes."""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run_example(script: str, *args: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, str(REPO / "examples" / script), *args],
        capture_output=True, text=True, timeout=900, env=env,
    )


pytestmark = pytest.mark.slow  # subprocess smokes; the docs CI job runs
# them by path, the tier-1 driver runs the whole suite unfiltered.


def test_quickstart_runs():
    proc = _run_example("quickstart.py")
    assert proc.returncode == 0, proc.stderr[-2000:]
    # Spot-check the printed results, not just the exit code.
    assert "expect 0 8 16 111 118 178" in proc.stdout
    assert "fib(10) -> 55" in proc.stdout
    assert "CacheInfo" in proc.stdout


def test_nuts_logreg_runs_tiny():
    proc = _run_example("nuts_logreg.py", "--chains", "3", "--steps", "2")
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "converged: True" in proc.stdout
    assert "finite: True" in proc.stdout
