"""Occupancy accounting for the pc VM (ISSUE 8 satellite 4).

``SchedulerStats.mean_occupancy`` is the tile-based SIMD metric: per
dispatch, active lanes divided by the capacity of the tiles
(``pc_vm.OCCUPANCY_TILE`` lanes wide) that hold at least one active lane.
This is the quantity compaction actually improves — a pure permutation
never changes whole-batch utilization, but it empties tiles, and empty
tiles cost nothing on a SIMD machine.  These tests pin the three
behavioral claims:

1. on a divergent program, ``compact_every=1`` strictly improves
   ``mean_occupancy`` while outputs stay bit-identical;
2. retired and quarantined lanes never count as active, and tiles they
   vacate drop out of the denominator (``mean_occupancy`` stays high
   while the legacy whole-batch ``mean_lane_occupancy`` sinks);
3. a tier-1 floor: compacted NUTS at batch 32 keeps fused pc occupancy
   at or above the seed value 0.35 (the CI guard for the fig5 claim).
"""
import numpy as np
import pytest

from repro.core import batching, frontend, pc_vm
from repro.core.frontend import I32

Z = 32


def _parity_program():
    """Odd and even lanes diverge into two distinct loop blocks of equal
    length — the classic fragmentation shape: every other lane is masked
    out of every dispatch, so uncompacted each dispatch touches all
    tiles at half occupancy."""
    pb = frontend.ProgramBuilder()
    fb = pb.function("f", ["n", "x"], ["out"],
                     {"n": I32, "x": I32}, {"out": I32})
    fb.copy("x", out="out")
    par = fb.prim(lambda x: (x & 1) == 1, ["x"], name="parity")
    with fb.if_(par):
        i = fb.prim(lambda n: n, ["n"], name="i")
        with fb.while_(lambda i: i > 0, [i]):
            fb.assign("out", lambda o: o + 1, ["out"])
            fb.assign(i, lambda i: i - 1, [i])
    with fb.orelse():
        j = fb.prim(lambda n: n, ["n"], name="j")
        with fb.while_(lambda j: j > 0, [j]):
            fb.assign("out", lambda o: o - 1, ["out"])
            fb.assign(j, lambda j: j - 1, [j])
    fb.return_()
    pb.add(fb)
    return pb.build()


def _staged_exit_program():
    """Recurse ``n`` times (overflowing max_depth for large ``n``), then
    loop ``w`` times — lets a test retire or quarantine one contiguous
    half of the batch while the other half keeps dispatching."""
    pb = frontend.ProgramBuilder()
    fb = pb.function("f", ["n", "w"], ["out"],
                     {"n": I32, "w": I32}, {"out": I32})
    c = fb.prim(lambda n: n <= 0, ["n"], name="base")
    with fb.if_(c):
        fb.copy("w", out="out")
        i = fb.prim(lambda w: w, ["w"], name="i")
        with fb.while_(lambda i: i > 0, [i]):
            fb.assign("out", lambda o: o + 1, ["out"])
            fb.assign(i, lambda i: i - 1, [i])
        fb.return_()
    t = fb.prim(lambda n: n - 1, ["n"], name="dec")
    fb.assign("out", lambda r: r, [fb.call("f", [t, "w"])])
    fb.return_()
    pb.add(fb)
    return pb.build()


def test_compaction_strictly_improves_tile_occupancy():
    """popular + compact_every=1 on the parity program: sorted by pc, the
    two cohorts become tile-contiguous, so each dispatch's active lanes
    fill their tiles while the other cohort's tiles drop out entirely."""
    prog = _parity_program()
    n = np.full(Z, 8, np.int32)
    x = np.arange(Z, dtype=np.int32)  # alternating parity
    plain = batching.autobatch(prog, backend="pc", schedule="popular")
    compacted = batching.autobatch(prog, backend="pc", schedule="popular",
                                   compact_every=1)
    base_out = np.asarray(plain(n, x)["out"])
    base = plain.scheduler_stats
    np.testing.assert_array_equal(
        np.asarray(compacted(n, x)["out"]), base_out
    )
    comp = compacted.scheduler_stats
    assert comp.compact_every == 1 and base.compact_every is None
    assert comp.mean_occupancy > base.mean_occupancy, (
        f"compaction did not improve tile occupancy: "
        f"{comp.mean_occupancy:.3f} vs {base.mean_occupancy:.3f}"
    )
    # The improvement is structural, not marginal: interleaved cohorts
    # leave every tile half-full (~0.5); compacted cohorts fill them.
    assert base.mean_occupancy < 0.75
    assert comp.mean_occupancy > 0.9
    # Permutation invariance of the trajectory itself: the whole-batch
    # metric (active lanes per dispatch / batch) must NOT move.
    np.testing.assert_allclose(comp.mean_lane_occupancy,
                               base.mean_lane_occupancy, rtol=1e-6)


def test_retired_lanes_excluded_from_occupancy():
    """First half of the batch exits almost immediately; its tiles drop
    out of the denominator, so tile occupancy stays near 1 while the
    whole-batch metric records the idle half."""
    prog = _staged_exit_program()
    n = np.zeros(Z, np.int32)
    w = np.array([1] * (Z // 2) + [60] * (Z // 2), np.int32)
    fn = batching.autobatch(prog, backend="pc")
    fn(n, w)
    s = fn.scheduler_stats
    assert s.mean_occupancy > 0.85, s
    assert s.mean_lane_occupancy < 0.65, s
    assert s.mean_occupancy > s.mean_lane_occupancy + 0.2


def test_quarantined_lanes_excluded_from_occupancy():
    """Under on_fault="quarantine", overflow-faulted lanes are excluded
    from every later dispatch mask — and from occupancy: their vacated
    tiles must not dilute the metric while the healthy half works."""
    prog = _staged_exit_program()
    n = np.array([9] * (Z // 2) + [0] * (Z // 2), np.int32)
    w = np.full(Z, 60, np.int32)
    fn = batching.autobatch(prog, backend="pc", max_depth=4,
                            on_fault="quarantine")
    fn(n, w)
    res = fn.last_result
    codes = np.asarray(res.fault_code)
    np.testing.assert_array_equal(
        codes != 0, [True] * (Z // 2) + [False] * (Z // 2)
    )
    s = fn.scheduler_stats
    assert s.mean_occupancy > 0.8, s
    assert s.mean_lane_occupancy < 0.7, s


@pytest.mark.parametrize("compact_every", [1, 7])
def test_nuts_batch32_occupancy_floor(compact_every):
    """The tier-1 regression guard behind the fig5 acceptance number:
    fused pc NUTS at batch 32 with compaction must keep tile occupancy
    at or above the seed floor of 0.35.  Tree-depth divergence between
    chains is the paper's motivating fragmentation; if a scheduler or
    compaction change drops this, fig5's occupancy claim is gone."""
    from repro.mcmc import nuts, targets

    t = targets.isotropic_gaussian(3)
    s = nuts.NutsSettings(max_tree_depth=5, num_steps=4, steps_per_leaf=2)
    kern = nuts.make_nuts_kernel(
        t, s, max_steps=200_000, schedule="popular", fuse=True,
        compact_every=compact_every,
    )
    kern(*nuts.initial_state(t, 32, eps=0.4, seed=2))
    sched = kern.scheduler_stats
    assert sched is not None and sched.compact_every == compact_every
    assert sched.mean_occupancy >= 0.35, (
        f"fused pc occupancy at batch 32 fell to "
        f"{sched.mean_occupancy:.3f} < 0.35 (seed floor)"
    )
