"""Tests for the decorator-first, pytree-native `autobatch` API.

Covers the four tentpole layers: the ``Batched``/``Shared`` argument model
(with vmap-parity for broadcasting), pytree round-trips on all four
backends, frontend unification (AST-defined and builder-defined functions
calling each other in one program), and the execution cache (same-aval
re-calls hit; new batch sizes share the lowering).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import ast_frontend, frontend, ir
from repro.core.batching import Batched, Shared, autobatch
from repro.core.frontend import F32, I32, spec

FIB = np.array([0, 1, 1, 2, 3, 5, 8, 13, 21, 34, 55, 89, 144], np.int64)
BACKENDS = ("pc", "local", "local_eager", "reference")


@pytest.fixture()
def reg():
    return ast_frontend.Namespace()


def build_axpy_builder():
    """r = a*x + y, s = r^2 — a straight-line program with two outputs."""
    pb = frontend.ProgramBuilder()
    fb = pb.function(
        "axpy", ["a", "x", "y"], ["r", "s"],
        {"a": F32, "x": F32, "y": F32}, {"r": F32, "s": F32},
    )
    fb.assign("r", lambda a, x, y: a * x + y, ["a", "x", "y"])
    fb.assign("s", lambda r: r * r, ["r"])
    fb.return_()
    pb.add(fb)
    return pb


class TestDecoratorPath:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_recursive_fib(self, reg, backend):
        @autobatch(in_specs=(Batched(I32),), out_spec=I32,
                   backend=backend, max_depth=24, registry=reg)
        def fib(n):
            if n < 2:
                return n
            return fib(n - 1) + fib(n - 2)

        n = np.array([0, 1, 5, 9, 12, 3], np.int32)
        np.testing.assert_array_equal(np.asarray(fib(n)), FIB[n])

    def test_requires_specs(self, reg):
        with pytest.raises(TypeError, match="requires in_specs"):
            @autobatch(registry=reg)
            def f(n):
                return n

    def test_multi_output_tuple(self, reg):
        @autobatch(in_specs=(Batched(I32),), out_spec=(I32, I32),
                   registry=reg)
        def divmod7(n):
            return n // 7, n % 7

        q, r = divmod7(np.array([0, 7, 30], np.int32))
        np.testing.assert_array_equal(np.asarray(q), [0, 1, 4])
        np.testing.assert_array_equal(np.asarray(r), [0, 0, 2])

    def test_shared_scalar_argument(self, reg):
        @autobatch(in_specs=(Batched(I32), Shared(I32)), out_spec=I32,
                   registry=reg)
        def addk(n, k):
            return n + k

        out = addk(np.array([1, 2, 3], np.int32), np.int32(10))
        np.testing.assert_array_equal(np.asarray(out), [11, 12, 13])


class TestPytreeRoundTrip:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_nested_dict_tuple_io(self, reg, backend):
        """Nested dict/tuple inputs and outputs round-trip on all backends."""
        pb = frontend.ProgramBuilder()
        fb = pb.function(
            "norm2", ["gain", "u", "v", "w"], ["total", "scaled"],
            {"gain": F32, "u": F32, "v": F32, "w": F32},
            {"total": F32, "scaled": F32},
        )
        fb.assign("total", lambda u, v, w: u + v + w, ["u", "v", "w"])
        fb.assign("scaled", lambda g, t: g * t, ["gain", "total"])
        fb.return_()
        pb.add(fb)

        bf = autobatch(
            pb,
            # One shared scalar + one nested (dict-of-tuple/leaf) state arg.
            in_specs=(Shared(F32), Batched({"pair": (F32, F32), "w": F32})),
            # Restructured output pytree (name leaves pick IR outputs).
            out_spec={"sum": "total", "out": {"scaled": "scaled"}},
            backend=backend, registry=reg,
        )
        state = {"pair": (np.array([1., 2.], np.float32),
                          np.array([3., 4.], np.float32)),
                 "w": np.array([5., 6.], np.float32)}
        res = bf(np.float32(2.0), state)
        assert set(res) == {"sum", "out"}
        np.testing.assert_allclose(np.asarray(res["sum"]), [9., 12.])
        np.testing.assert_allclose(
            np.asarray(res["out"]["scaled"]), [18., 24.]
        )

    def test_structure_mismatch_raises(self, reg):
        bf = autobatch(build_axpy_builder(),
                       in_specs=(Shared(F32), Batched((F32, F32))),
                       registry=reg)
        with pytest.raises(TypeError, match="pytree structure"):
            bf(np.float32(1.0), {"x": np.zeros(2, np.float32),
                                 "y": np.zeros(2, np.float32)})

    def test_missing_batch_axis_raises(self, reg):
        bf = autobatch(build_axpy_builder(),
                       in_specs=(Shared(F32), Batched((F32, F32))),
                       registry=reg)
        with pytest.raises(TypeError, match="leading batch axis"):
            bf(np.float32(1.0), (np.float32(1.0), np.float32(2.0)))

    def test_dict_of_specs_out_spec_rejected(self, reg):
        """Dicts flatten in sorted-key order, which would silently permute
        equal-spec outputs — dict out_specs must use name-string leaves."""
        with pytest.raises(TypeError, match="ambiguous"):
            autobatch(build_axpy_builder(),
                      out_spec={"sum": F32, "prod": F32}, registry=reg)

    def test_dict_of_specs_out_spec_rejected_decorator_path(self, reg):
        with pytest.raises(TypeError, match="ambiguous"):
            @autobatch(in_specs=(Batched(I32),),
                       out_spec={"double": I32, "answer": I32}, registry=reg)
            def f(n):
                return n * 2, n * 0 + 42

    def test_interface_recorded_on_ir(self, reg):
        bf = autobatch(build_axpy_builder(),
                       in_specs=(Shared(F32), Batched((F32, F32))),
                       registry=reg)
        bf(np.float32(1.0), (np.ones(3, np.float32), np.ones(3, np.float32)))
        iface = bf.program.functions["axpy"].iface
        assert isinstance(iface, ir.Interface)
        assert iface.args[0].shared and not iface.args[1].shared
        assert iface.args[1].params == ("x", "y")


class TestSharedVmapParity:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_broadcast_matches_vmap_in_axes_none(self, reg, backend):
        """``Shared`` == ``jax.vmap(..., in_axes=None)`` of the per-member
        function run through the reference semantics."""
        vec = spec((3,), jnp.float32)
        pb = frontend.ProgramBuilder()
        fb = pb.function(
            "affine", ["w", "x", "b"], ["out"],
            {"w": vec, "x": vec, "b": F32}, {"out": F32},
        )
        fb.assign("out", lambda w, x, b: jnp.dot(w, x) + b, ["w", "x", "b"])
        fb.return_()
        pb.add(fb)

        bf = autobatch(pb, in_specs=(Shared(vec), Batched(vec), Shared(F32)),
                       backend=backend, registry=reg)
        rng = np.random.default_rng(0)
        w = rng.normal(size=3).astype(np.float32)
        x = rng.normal(size=(5, 3)).astype(np.float32)
        b = np.float32(0.5)
        got = np.asarray(bf(w, x, b)["out"])
        want = jax.vmap(
            lambda w, x, b: jnp.dot(w, x) + b, in_axes=(None, 0, None)
        )(w, x, b)
        np.testing.assert_allclose(got, np.asarray(want), rtol=1e-6)

    def test_batched_vs_shared_same_values_agree(self, reg):
        """Tiling a shared value by hand (old convention) must match
        passing it as ``Shared`` (new convention)."""
        pb = build_axpy_builder()
        shared = autobatch(pb, in_specs=(Shared(F32), Batched((F32, F32))),
                           registry=reg)
        tiled = autobatch(pb, registry=reg)  # default: everything Batched
        x = np.array([1., 2., 3.], np.float32)
        y = np.array([4., 5., 6.], np.float32)
        a = np.float32(2.0)
        out_s = shared(a, (x, y))
        out_t = tiled(np.full(3, a, np.float32), x, y)
        for k in ("r", "s"):
            np.testing.assert_allclose(
                np.asarray(out_s[k]), np.asarray(out_t[k])
            )


class TestFrontendUnification:
    def test_ast_calls_builder_function(self, reg):
        pb = frontend.ProgramBuilder()
        fb = pb.function("triple", ["x"], ["out"], {"x": I32}, {"out": I32})
        fb.assign("out", lambda x: 3 * x, ["x"])
        fb.return_()
        pb.add(fb)
        reg.add(fb)

        @autobatch(in_specs=(Batched(I32),), out_spec=I32, registry=reg)
        def f(n):
            if n < 0:
                return triple(0 - n)  # noqa: F821 - resolved in-registry
            return triple(n) + 1

        out = f(np.array([-2, 0, 4], np.int32))
        np.testing.assert_array_equal(np.asarray(out), [6, 1, 13])

    def test_builder_calls_ast_function(self, reg):
        @autobatch(in_specs=(Batched(I32),), out_spec=I32,
                   max_depth=20, registry=reg)
        def fact(n):
            if n <= 1:
                return n * 0 + 1
            return n * fact(n - 1)

        fb = frontend.FunctionBuilder(
            "fact_plus", ["n"], ["out"], {"n": I32}, {"out": I32}
        )
        fb.call("fact", ["n"], out="t")
        fb.assign("out", lambda t: t + 1, ["t"])
        fb.return_()
        g = autobatch(fb, backend="pc", max_depth=20, registry=reg)
        out = g(np.array([1, 3, 5], np.int32))
        np.testing.assert_array_equal(np.asarray(out["out"]), [2, 7, 121])

    def test_same_name_redefinition_does_not_leak(self, reg):
        """Each wrapper traces the body it decorated, even if a later
        registration shadowed its name in the shared namespace."""
        @autobatch(in_specs=(Batched(I32),), out_spec=I32, registry=reg)
        def mangle(n):
            return n + n

        first = mangle

        @autobatch(in_specs=(Batched(I32),), out_spec=I32, registry=reg)
        def mangle(n):  # noqa: F811 - deliberate shadowing
            return n * 3

        second = mangle
        n = np.array([2, 5], np.int32)
        np.testing.assert_array_equal(np.asarray(first(n)), [4, 10])
        np.testing.assert_array_equal(np.asarray(second(n)), [6, 15])
        # Order-independent: tracing second first must not poison first.
        np.testing.assert_array_equal(np.asarray(first(n)), [4, 10])

    def test_builder_redefinition_does_not_leak(self, reg):
        """Builder-path wrappers are pinned too: a later same-named builder
        registration must not replace an earlier wrapper's body."""
        def build_scale(k):
            pb = frontend.ProgramBuilder()
            fb = pb.function("scale", ["x"], ["out"], {"x": I32}, {"out": I32})
            fb.assign("out", lambda x: k * x, ["x"], name=f"mul{k}")
            fb.return_()
            pb.add(fb)
            return pb

        f2 = autobatch(build_scale(2), registry=reg)
        f3 = autobatch(build_scale(3), registry=reg)
        n = np.array([1, 2], np.int32)
        # f2 first traces *after* f3 registered "scale" — must still be x*2.
        np.testing.assert_array_equal(np.asarray(f2(n)["out"]), [2, 4])
        np.testing.assert_array_equal(np.asarray(f3(n)["out"]), [3, 6])

    def test_iface_not_shared_across_wrappers(self, reg):
        """Two wrappers over one program each record their own calling
        convention without mutating the other's (or the caller's) IR."""
        pb = build_axpy_builder()
        shared = autobatch(pb, in_specs=(Shared(F32), Batched((F32, F32))),
                           registry=reg)
        tiled = autobatch(pb, registry=reg)
        x = np.ones(2, np.float32)
        shared(np.float32(1.0), (x, x))
        tiled(x, x, x)
        assert shared.program.functions["axpy"].iface.args[0].shared
        assert not tiled.program.functions["axpy"].iface.args[0].shared

    def test_builder_default_namespace_is_private(self):
        """autobatch(builder) without registry= must not register its
        function names into the process-wide decorator namespace, where
        they could shadow the callees of not-yet-traced functions."""
        from repro.core.batching import DEFAULT_NAMESPACE
        pb = frontend.ProgramBuilder()
        fb = pb.function("__private_probe", ["x"], ["out"],
                         {"x": I32}, {"out": I32})
        fb.assign("out", lambda x: x, ["x"])
        fb.return_()
        pb.add(fb)
        bf = autobatch(pb)
        bf(np.array([1], np.int32))
        assert "__private_probe" not in DEFAULT_NAMESPACE

    def test_trace_prunes_unreachable(self, reg):
        @autobatch(in_specs=(Batched(I32),), out_spec=I32, registry=reg)
        def lonely(n):
            return n + 1

        @autobatch(in_specs=(Batched(I32),), out_spec=I32, registry=reg)
        def other(n):
            return n - 1

        assert set(lonely.program.functions) == {"lonely"}


class TestExecutionCache:
    def test_same_avals_hit_no_relowering(self, reg):
        @autobatch(in_specs=(Batched(I32),), out_spec=I32,
                   max_depth=20, registry=reg)
        def fib(n):
            if n < 2:
                return n
            return fib(n - 1) + fib(n - 2)

        n = np.array([3, 8, 5, 1], np.int32)
        fib(n)
        info1 = fib.cache_info()
        assert (info1.misses, info1.hits) == (1, 0)
        assert info1.lowerings == 1 and info1.traces == 1
        fib(n)  # identical avals: must be a pure cache hit
        info2 = fib.cache_info()
        assert (info2.misses, info2.hits) == (1, 1)
        assert info2.lowerings == 1 and info2.traces == 1  # no re-lowering

    def test_new_batch_size_shares_lowering(self, reg):
        @autobatch(in_specs=(Batched(I32),), out_spec=I32,
                   max_depth=20, registry=reg)
        def fib(n):
            if n < 2:
                return n
            return fib(n - 1) + fib(n - 2)

        fib(np.array([3, 8], np.int32))
        fib(np.array([3, 8, 5], np.int32))   # new batch size
        info = fib.cache_info()
        assert info.misses == 2 and info.entries == 2
        assert info.lowerings == 1  # the expensive lowering ran once

    def test_fixed_batch_size_validated(self, reg):
        bf = autobatch(build_axpy_builder(), batch_size=4, registry=reg)
        with pytest.raises(TypeError, match="batch axis"):
            bf(np.ones(3, np.float32), np.ones(3, np.float32),
               np.ones(3, np.float32))

    def test_aot_lower_and_cost_analysis(self, reg):
        bf = autobatch(build_axpy_builder(), registry=reg)
        low = bf.lower(np.ones(2, np.float32), np.ones(2, np.float32),
                       np.ones(2, np.float32))
        assert "while" in low.as_text()  # the fused VM loop
        cost = low.cost_analysis()
        assert isinstance(cost, dict) and cost
        with pytest.raises(ValueError, match="pc"):
            autobatch(build_axpy_builder(), backend="local",
                      registry=reg).lower(np.ones(2, np.float32),
                                          np.ones(2, np.float32),
                                          np.ones(2, np.float32))


class TestUnifiedIntrospection:
    @pytest.mark.parametrize("backend", ["pc", "local", "local_eager",
                                         "reference"])
    def test_utilization_empty_before_run(self, reg, backend):
        bf = autobatch(build_axpy_builder(), backend=backend, registry=reg)
        assert bf.utilization == {}
        assert bf.tag_stats == {}

    @pytest.mark.parametrize("backend", ["pc", "local"])
    def test_tag_stats_unified(self, reg, backend):
        pb = frontend.ProgramBuilder()
        fb = pb.function("tagged", ["x"], ["out"], {"x": F32}, {"out": F32})
        fb.prim(lambda x: x * 2.0, ["x"], out="out", name="dbl", tag="dbl")
        fb.return_()
        pb.add(fb)
        bf = autobatch(pb, backend=backend, registry=reg)
        bf(np.ones(4, np.float32))
        execs, active = bf.tag_stats["dbl"]
        assert execs == 1 and active == 4
        assert bf.utilization["dbl"] == pytest.approx(1.0)
        # Per-run semantics on every backend: a second call must not
        # accumulate (the local batcher accumulates internally).
        bf(np.ones(4, np.float32))
        assert bf.tag_stats["dbl"] == (1, 4)


class TestDeprecatedShim:
    def test_api_autobatch_warns_and_works(self):
        from repro.core import api
        pb = build_axpy_builder()
        with pytest.warns(DeprecationWarning, match="batching.autobatch"):
            bp = api.autobatch(pb.build(), 2, backend="pc")
        assert bp.utilization == {}  # unified pre-run semantics
        out = bp({"a": np.ones(2, np.float32), "x": np.ones(2, np.float32),
                  "y": np.ones(2, np.float32)})
        np.testing.assert_allclose(np.asarray(out["r"]), [2., 2.])

    def test_shim_local_utilization_is_last_run_only(self):
        """The shim's documented 'identical on every backend' semantics:
        local-backend utilization covers the most recent call, not the
        cumulative history."""
        from repro.core import api
        pb = frontend.ProgramBuilder()
        fb = pb.function("maybe", ["x"], ["out"], {"x": F32}, {"out": F32})
        c = fb.prim(lambda x: x > 0, ["x"])
        fb.copy("x", out="out")
        with fb.if_(c):
            fb.prim(lambda x: x * 2.0, ["x"], out="out", name="dbl",
                    tag="dbl")
        fb.return_()
        pb.add(fb)
        bp = api.BatchedProgram(pb.build(), 4, backend="local")
        bp({"x": np.ones(4, np.float32)})           # all active: util 1.0
        assert bp.utilization["dbl"] == pytest.approx(1.0)
        bp({"x": np.array([1., 1., -1., -1.], np.float32)})  # half active
        assert bp.utilization["dbl"] == pytest.approx(0.5)   # not 0.75
