"""Unit tests for the autobatching core: IR, lowering, both runtimes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import api, frontend, ir, lowering, reference
from repro.core.frontend import BOOL, F32, I32


def build_fib():
    pb = frontend.ProgramBuilder()
    fb = pb.function(
        "fib", ["n"], ["out"], {"n": I32}, {"out": I32}
    )
    c = fb.prim(lambda n: n < 2, ["n"], name="lt2")
    with fb.if_(c):
        fb.copy("n", out="out")
        fb.return_()
    t1 = fb.prim(lambda n: n - 1, ["n"])
    fb.call("fib", [t1], out="a")
    t2 = fb.prim(lambda n: n - 2, ["n"])
    fb.call("fib", [t2], out="b")
    fb.assign("out", lambda a, b: a + b, ["a", "b"])
    fb.return_()
    pb.add(fb)
    return pb.build()


def build_pow_loop():
    """pow(x, k) via a while loop — no recursion, control flow only."""
    pb = frontend.ProgramBuilder()
    fb = pb.function(
        "powi",
        ["x", "k"],
        ["out"],
        {"x": F32, "k": I32},
        {"out": F32},
    )
    fb.const(1.0, jnp.float32, out="out")
    fb.copy("k", out="i")
    with fb.while_(lambda i: i > 0, ["i"]):
        fb.assign("out", lambda o, x: o * x, ["out", "x"])
        fb.assign("i", lambda i: i - 1, ["i"])
    fb.return_()
    pb.add(fb)
    return pb.build()


def build_mutual():
    """Mutual recursion: is_even/is_odd on non-negative ints."""
    pb = frontend.ProgramBuilder()
    ev = pb.function("is_even", ["n"], ["out"], {"n": I32}, {"out": BOOL})
    c = ev.prim(lambda n: n == 0, ["n"])
    with ev.if_(c):
        ev.const(True, jnp.bool_, out="out")
        ev.return_()
    t = ev.prim(lambda n: n - 1, ["n"])
    ev.call("is_odd", [t], out="out")
    ev.return_()
    pb.add(ev)
    od = pb.function("is_odd", ["n"], ["out"], {"n": I32}, {"out": BOOL})
    c = od.prim(lambda n: n == 0, ["n"])
    with od.if_(c):
        od.const(False, jnp.bool_, out="out")
        od.return_()
    t = od.prim(lambda n: n - 1, ["n"])
    od.call("is_even", [t], out="out")
    od.return_()
    pb.add(od)
    return ir.Program(functions=pb.functions, main="is_even")


FIB = np.array([0, 1, 1, 2, 3, 5, 8, 13, 21, 34, 55, 89, 144], np.int64)


class TestLowering:
    def test_fib_stack_assignment(self):
        """Paper opts (ii)/(iii): n, a stacked; b top-only; temps elided."""
        low = lowering.lower(build_fib())
        assert low.stack_vars == {"fib/n", "fib/a"}
        assert "fib/b" in low.temp_vars or "fib/b" not in low.stack_vars
        assert "fib/out" not in low.stack_vars
        # temporaries never appear in VM state
        assert all(v.startswith("fib/%") or v == "fib/b" for v in low.temp_vars)

    def test_nonrecursive_has_no_stacks(self):
        """A recursion-free program needs no data stacks at all (paper §3)."""
        low = lowering.lower(build_pow_loop())
        assert low.stack_vars == frozenset()

    def test_popush_elimination(self):
        """Adjacent sibling calls cancel the pop/push on the param stack."""
        pb = frontend.ProgramBuilder()
        fb = pb.function("f", ["n"], ["out"], {"n": I32}, {"out": I32})
        c = fb.prim(lambda n: n <= 0, ["n"])
        with fb.if_(c):
            fb.const(0, jnp.int32, out="out")
            fb.return_()
        t = fb.prim(lambda n: n - 1, ["n"])
        fb.call("f", [t], out="a")
        # Second sibling call with an argument that does NOT read n:
        fb.call("f", ["a"], out="b")
        fb.assign("out", lambda a, b: a + b, ["a", "b"])
        fb.return_()
        pb.add(fb)
        low = lowering.lower(pb.build())
        names = [
            op.name
            for blk in low.blocks
            for op in blk.ops
            if isinstance(op, ir.LPrim)
        ]
        assert "popush" in names  # the peephole fired

    def test_exit_index(self):
        low = lowering.lower(build_fib())
        assert low.exit_index == len(low.blocks)


class TestBackendAgreement:
    @pytest.mark.parametrize("backend", ["pc", "local", "local_eager"])
    def test_fib(self, backend):
        prog = build_fib()
        n = np.array([0, 1, 5, 9, 12, 3, 7, 2], np.int32)
        out = api.autobatch(prog, 8, backend=backend, max_depth=20)({"n": n})
        np.testing.assert_array_equal(np.asarray(out["out"]), FIB[n])

    @pytest.mark.parametrize("backend", ["pc", "local", "local_eager"])
    def test_loop(self, backend):
        prog = build_pow_loop()
        x = np.array([1.5, 2.0, 0.5, 3.0], np.float32)
        k = np.array([3, 0, 4, 2], np.int32)
        out = api.autobatch(prog, 4, backend=backend)({"x": x, "k": k})
        np.testing.assert_allclose(
            np.asarray(out["out"]), x.astype(np.float64) ** k, rtol=1e-6
        )

    @pytest.mark.parametrize("backend", ["pc", "local"])
    def test_mutual_recursion(self, backend):
        prog = build_mutual()
        n = np.array([0, 1, 2, 7, 10, 13], np.int32)
        out = api.autobatch(prog, 6, backend=backend, max_depth=20)({"n": n})
        np.testing.assert_array_equal(np.asarray(out["out"]), n % 2 == 0)

    def test_reference_matches(self):
        prog = build_fib()
        n = np.array([4, 6], np.int32)
        ref = reference.run_reference_batch(prog, {"n": n})
        np.testing.assert_array_equal(ref["out"], FIB[n])


class TestVMBehavior:
    def test_vector_state(self):
        """Per-member values may be vectors (NUTS carries [dim] positions)."""
        pb = frontend.ProgramBuilder()
        vec = frontend.spec((4,), jnp.float32)
        fb = pb.function(
            "scale", ["v", "k"], ["out"], {"v": vec, "k": I32}, {"out": vec}
        )
        fb.copy("v", out="out")
        fb.copy("k", out="i")
        with fb.while_(lambda i: i > 0, ["i"]):
            fb.assign("out", lambda o: o * 2.0, ["out"])
            fb.assign("i", lambda i: i - 1, ["i"])
        fb.return_()
        pb.add(fb)
        prog = pb.build()
        v = np.arange(12, dtype=np.float32).reshape(3, 4)
        k = np.array([1, 0, 3], np.int32)
        for backend in ("pc", "local"):
            out = api.autobatch(prog, 3, backend=backend)({"v": v, "k": k})
            np.testing.assert_allclose(
                np.asarray(out["out"]), v * (2.0 ** k)[:, None]
            )

    def test_non_convergence_flag(self):
        pb = frontend.ProgramBuilder()
        fb = pb.function("spin", ["n"], ["out"], {"n": I32}, {"out": I32})
        fb.copy("n", out="out")
        with fb.while_(lambda o: o >= 0, ["out"]):  # never exits for n >= 0
            fb.assign("out", lambda o: o, ["out"])
        fb.return_()
        pb.add(fb)
        bp = api.autobatch(pb.build(), 2, backend="pc", max_steps=50)
        bp({"n": np.array([1, 2], np.int32)})
        assert not bool(bp.last_result.converged)

    def test_divergence_and_reconvergence(self):
        """Members taking different branches re-converge at the join block."""
        pb = frontend.ProgramBuilder()
        fb = pb.function("f", ["x"], ["out"], {"x": F32}, {"out": F32})
        c = fb.prim(lambda x: x > 0, ["x"])
        with fb.if_(c):
            fb.assign("y", lambda x: x * 2.0, ["x"])
        with fb.orelse():
            fb.assign("y", lambda x: -x, ["x"])
        fb.assign("out", lambda y: y + 1.0, ["y"])
        fb.return_()
        pb.add(fb)
        prog = pb.build()
        x = np.array([1.0, -2.0, 3.0, -4.0], np.float32)
        expect = np.where(x > 0, x * 2 + 1, -x + 1)
        for backend in ("pc", "local", "local_eager"):
            out = api.autobatch(prog, 4, backend=backend)({"x": x})
            np.testing.assert_allclose(np.asarray(out["out"]), expect)

    def test_batching_across_depth_beats_local(self):
        """The paper's headline property (Fig. 1 vs Fig. 3, Fig. 6): because
        the PC VM batches members at *different stack depths*, it executes the
        expensive leaf primitive far fewer times (at higher utilization) than
        the host-recursive local-static runtime, which can only batch members
        whose Python call stacks coincide."""
        pb = frontend.ProgramBuilder()
        fb = pb.function("fib", ["n"], ["out"], {"n": I32}, {"out": I32})
        c = fb.prim(lambda n: n < 2, ["n"], name="lt2")
        with fb.if_(c):
            fb.prim(lambda n: n, ["n"], out="out", name="leaf", tag="leaf")
            fb.return_()
        t1 = fb.prim(lambda n: n - 1, ["n"])
        fb.call("fib", [t1], out="a")
        t2 = fb.prim(lambda n: n - 2, ["n"])
        fb.call("fib", [t2], out="b")
        fb.assign("out", lambda a, b: a + b, ["a", "b"])
        fb.return_()
        pb.add(fb)
        prog = pb.build()

        rng = np.random.default_rng(0)
        n = rng.integers(8, 13, 32).astype(np.int32)
        bp = api.autobatch(prog, 32, backend="pc", max_depth=24)
        bp({"n": n})
        pc_execs, pc_active = bp.last_result.tag_stats["leaf"]
        loc = api.autobatch(prog, 32, backend="local")
        loc({"n": n})
        loc_execs = loc.batcher.stats.tag_execs["leaf"]
        loc_active = loc.batcher.stats.tag_active["leaf"]
        assert pc_execs < loc_execs  # fewer expensive-primitive launches
        pc_util = pc_active / (pc_execs * 32)
        loc_util = loc_active / (loc_execs * 32)
        assert pc_util > loc_util  # at strictly better batch utilization

    def test_utilization_stats(self):
        prog = build_fib()
        bp = api.autobatch(prog, 4, backend="pc", max_depth=16)
        bp({"n": np.array([8, 8, 8, 8], np.int32)})
        res = bp.last_result
        assert int(res.steps) > 0
        assert res.block_exec.sum() == res.steps
        # Identical inputs => every step fully active.
        util = res.block_active.sum() / (res.block_exec.sum() * 4)
        assert util == pytest.approx(1.0)


class TestTypeInference:
    def test_conflicting_merge_raises(self):
        pb = frontend.ProgramBuilder()
        fb = pb.function("f", ["x"], ["out"], {"x": F32}, {"out": F32})
        c = fb.prim(lambda x: x > 0, ["x"])
        with fb.if_(c):
            fb.assign("y", lambda x: x, ["x"])
        with fb.orelse():
            fb.assign("y", lambda x: x.astype(jnp.int32), ["x"])
        fb.assign("out", lambda y: y * 1.0, ["y"])
        fb.return_()
        pb.add(fb)
        with pytest.raises(TypeError, match="conflicting"):
            lowering.lower(pb.build())

    def test_missing_output_spec_raises(self):
        with pytest.raises(ValueError, match="missing output spec"):
            fb = frontend.FunctionBuilder("f", ["x"], ["out"], {"x": F32}, {})
            fb.copy("x", out="out")
            fb.return_()
            fb.build().validate()
