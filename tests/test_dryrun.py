"""Distribution-layer tests: sharding rules, loop-aware HLO accounting,
and a subprocess smoke of the real 512-device dry-run entry point."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import hlo_cost

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class TestHloCostParser:
    HLO = textwrap.dedent("""\
    HloModule test, is_scheduled=true

    %body.1 (param.1: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %param.1 = (s32[], f32[8,16]) parameter(0)
      %gte.0 = s32[] get-tuple-element(%param.1), index=0
      %gte.1 = f32[8,16] get-tuple-element(%param.1), index=1
      %w = f32[16,16] constant({...})
      %dot.1 = f32[8,16] dot(%gte.1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar.1 = f32[8,16] all-reduce(%dot.1), replica_groups={}, to_apply=%add.red
      %one = s32[] constant(1)
      %next = s32[] add(%gte.0, %one)
      ROOT %tup = (s32[], f32[8,16]) tuple(%next, %ar.1)
    }

    %cond.1 (param.2: (s32[], f32[8,16])) -> pred[] {
      %param.2 = (s32[], f32[8,16]) parameter(0)
      %gte.2 = s32[] get-tuple-element(%param.2), index=0
      %limit = s32[] constant(12)
      ROOT %lt = pred[] compare(%gte.2, %limit), direction=LT
    }

    %add.red (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (x: f32[8,16]) -> f32[8,16] {
      %x = f32[8,16] parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,16]) tuple(%zero, %x)
      %loop = (s32[], f32[8,16]) while(%init), condition=%cond.1, body=%body.1
      ROOT %out = f32[8,16] get-tuple-element(%loop), index=1
    }
    """)

    def test_trip_count_multiplies_body(self):
        cost = hlo_cost.analyze(self.HLO)
        # dot: 2 * 8*16 * 16 = 4096 flops, x12 trips
        assert cost.flops == pytest.approx(4096 * 12)
        assert cost.unknown_trip_loops == 0
        ar = cost.collectives["all-reduce"]
        assert ar["count"] == 12
        assert ar["bytes"] == 8 * 16 * 4 * 12

    def test_parse_module_structure(self):
        comps, entry = hlo_cost.parse_module(self.HLO)
        assert entry == "main"
        assert set(comps) == {"body.1", "cond.1", "add.red", "main"}
        assert comps["cond.1"].int_constants == [12]


class TestShardingRules:
    @pytest.fixture()
    def mesh(self):
        # a tiny abstract mesh over the single CPU device set is enough to
        # exercise the rule logic (device count 1, axis sizes 1x1)
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_indivisible_axis_dropped(self, mesh):
        from repro.launch import sharding as sh

        # hubert vocab 504 is not divisible by 16 on the real mesh; with
        # this 1x1 mesh everything divides, so check the size guard with a
        # synthetic mesh-size table instead.
        spec = sh._fit(("tp", "fsdp"), (7, 13), mesh)
        assert spec == P("model", "data")  # 1 divides everything

    def test_param_rules_match_expected_paths(self, mesh):
        from repro.launch import sharding as sh

        assert sh.param_spec("embed/embedding", (1024, 64), mesh) == \
            P("model", None)
        assert sh.param_spec("layers/attn/wq", (64, 64), mesh) == \
            P("data", "model")
        assert sh.param_spec("layers/moe/wg", (4, 64, 32), mesh) == \
            P("model", "data", None)
        assert sh.param_spec("layers/mamba/w_out", (128, 64), mesh) == \
            P("model", "data")
        assert sh.param_spec("final_norm/scale", (64,), mesh) == P(None)

    def test_cache_shardings_batch_and_window(self, mesh):
        from repro.launch import sharding as sh

        cache = {"k": jax.ShapeDtypeStruct((4, 8, 64, 2, 16), "bfloat16")}
        out = sh.cache_shardings(cache, batch_size=8, mesh=mesh)
        spec = out["k"].spec
        assert spec[1] == "data"  # batch axis
        assert "model" in spec  # some axis took the model dim


@pytest.mark.slow
class TestDryRunSubprocess:
    def test_smallest_cell_compiles_on_512_devices(self, tmp_path):
        """End-to-end: the real dryrun entry point on the production mesh."""
        out = tmp_path / "cell.json"
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "smollm-135m", "--shape", "decode_32k",
             "--out", str(out)],
            env={**os.environ, "PYTHONPATH": SRC},
            capture_output=True, text=True, timeout=1200,
        )
        assert proc.returncode == 0, proc.stderr[-2000:]
        data = json.load(open(out))[0]
        assert data["chips"] == 256
        assert data["peak_bytes"] > 0
        assert data["bottleneck"] in ("compute", "memory", "collective")

    def test_multipod_mesh_compiles(self, tmp_path):
        out = tmp_path / "cell_mp.json"
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "smollm-135m", "--shape", "decode_32k",
             "--multi-pod", "--out", str(out)],
            env={**os.environ, "PYTHONPATH": SRC},
            capture_output=True, text=True, timeout=1200,
        )
        assert proc.returncode == 0, proc.stderr[-2000:]
        data = json.load(open(out))[0]
        assert data["chips"] == 512
        assert data["mesh"] == "2x16x16"
