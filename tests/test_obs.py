"""Observability tests (ISSUE 9): dispatch tracing, Perfetto timelines,
block profiles, and the serve-metrics registry.

The anchor test replays every traced dispatch against the NumPy
scheduler oracle (``tests/test_scheduler_oracle.py``): each trace event
records the pre-dispatch resident histogram, so the oracle can predict
the chosen block from the trace alone — recording is honest only if the
prediction matches ``trace.block`` event-for-event.
"""
import json

import numpy as np
import pytest

from repro.core import batching
from repro.obs import (
    Counter,
    DispatchTrace,
    Gauge,
    Histogram,
    MetricsRegistry,
    block_profile,
    to_perfetto,
    validate_perfetto,
    write_perfetto,
)
from repro.obs.blockprof import PROFILE_VERSION
from repro.obs.timeline import segment_tracks
from repro.obs.trace import SWEEP_BLOCK, resolve_capacity
from tests.test_core_property import _Gen
from tests.test_scheduler_oracle import _succ_matrix


def _traced_fn(seed: int, schedule: str, **kw):
    rng = np.random.default_rng(seed)
    prog = _Gen(rng).build()
    n = rng.integers(0, 5, size=8).astype(np.int32)
    x = rng.integers(-50, 51, size=8).astype(np.int32)
    fn = batching.autobatch(
        prog, backend="pc", max_depth=64, max_steps=200_000,
        schedule=schedule, trace=True, **kw,
    )
    return fn, n, x


def _oracle_pick_from_counts(counts: np.ndarray, schedule: str,
                             succ: np.ndarray) -> int:
    """The scheduler oracle, driven by a traced resident histogram.

    Same scoring as ``test_scheduler_oracle._oracle_pick`` but from the
    per-block counts a trace event records instead of raw pcs (the two
    are equivalent: counts = bincount(pc[live])).
    """
    if schedule == "earliest":
        resident = np.flatnonzero(counts)
        return int(resident[0]) if len(resident) else 0
    if schedule == "popular":
        return int(np.argmax(counts))
    assert schedule == "lookahead"
    score = 2 * counts + succ @ counts
    score = np.where(counts > 0, score, -1)
    return int(np.argmax(score))


# ---------------------------------------------------------------------------
# Dispatch trace vs the scheduler oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 3])
@pytest.mark.parametrize("schedule", ["earliest", "popular", "lookahead"])
def test_trace_replays_against_scheduler_oracle(seed, schedule):
    """Every traced dispatch must be predictable from its own recorded
    resident histogram: the trace is an honest transcript of
    ``_pick_block``, not an approximation of it."""
    fn, n, x = _traced_fn(seed, schedule)
    fn(n, x)
    tr = fn.last_trace
    assert tr.dropped == 0, "test programs must fit the default ring"
    assert len(tr) >= 20, "trace too short to exercise the scheduler"
    succ = _succ_matrix(fn.stepper(n, x).vm.lowered)
    for i in range(len(tr)):
        want = _oracle_pick_from_counts(
            np.asarray(tr.resident[i]), schedule, succ
        )
        assert int(tr.block[i]) == want, (
            f"event {i}: trace recorded block {int(tr.block[i])}, oracle "
            f"replays {want} from residents {tr.resident[i].tolist()} "
            f"(schedule={schedule})"
        )
        # The histogram itself must be internally consistent.
        assert int(tr.resident[i].sum()) == int(tr.live[i])
        assert int(tr.active[i]) >= 1


@pytest.mark.parametrize("seed", [0])
def test_sweep_trace_records_sentinel_block(seed):
    fn, n, x = _traced_fn(seed, "sweep")
    fn(n, x)
    tr = fn.last_trace
    assert (tr.block == SWEEP_BLOCK).all()
    # A sweep iteration's active count is the live-lane count.
    np.testing.assert_array_equal(tr.active, tr.live)


def test_trace_matches_block_exec_histogram():
    fn, n, x = _traced_fn(3, "popular", collect_stats=True)
    fn(n, x)
    tr = fn.last_trace
    be = np.asarray(fn.last_result.block_exec)
    hist = np.bincount(tr.block, minlength=tr.num_blocks)
    np.testing.assert_array_equal(hist, be)


def test_ring_overflow_keeps_newest_events():
    fn, n, x = _traced_fn(0, "earliest")
    fn(n, x)
    full = fn.last_trace
    total = full.total_dispatches
    cap = 8
    small = fn.with_options(trace=cap)
    np.testing.assert_array_equal(
        np.asarray(small(n, x)["out"]), np.asarray(fn(n, x)["out"])
    )
    tr = small.last_trace
    assert tr.capacity == cap and len(tr) == cap
    assert tr.total_dispatches == total
    assert tr.dropped == total - cap
    # Absolute dispatch ordinals of exactly the newest `cap` events.
    np.testing.assert_array_equal(tr.steps, np.arange(total - cap, total))
    np.testing.assert_array_equal(tr.block, full.block[-cap:])


def test_segmented_trace_equals_single_shot():
    fn, n, x = _traced_fn(3, "lookahead")
    fn(n, x)
    full = fn.last_trace
    st = fn.stepper(n, x)
    state = st.init()
    mid = None
    while not st.done(state):
        state = st.step(state, 5)
        if mid is None:
            mid = st.trace(state)  # drain mid-run: must be a prefix
    tr = st.trace(state)
    np.testing.assert_array_equal(tr.block, full.block)
    np.testing.assert_array_equal(tr.steps, full.steps)
    np.testing.assert_array_equal(tr.resident, full.resident)
    assert mid is not None and len(mid) <= len(tr)
    np.testing.assert_array_equal(mid.block, tr.block[: len(mid)])


def test_compaction_events_recorded_and_neutral():
    fn, n, x = _traced_fn(0, "popular")
    base = np.asarray(fn(n, x)["out"])
    comp = fn.with_options(compact_every=4)
    np.testing.assert_array_equal(np.asarray(comp(n, x)["out"]), base)
    tr = comp.last_trace
    assert tr.compacted.any()
    # compact_every=4 marks exactly the post-increment multiples of 4.
    np.testing.assert_array_equal(
        np.asarray(tr.compacted), (np.asarray(tr.steps) + 1) % 4 == 0
    )


def test_resolve_capacity_validation():
    from repro.core.pc_vm import VMConfig

    assert resolve_capacity(None) is None
    assert resolve_capacity(False) is None
    assert resolve_capacity(True) >= 1
    assert resolve_capacity(12) == 12
    with pytest.raises(ValueError):
        resolve_capacity(0)
    with pytest.raises(ValueError):
        resolve_capacity("yes")
    with pytest.raises(ValueError):
        VMConfig(batch_size=4, trace=-3)  # validated at config time


# ---------------------------------------------------------------------------
# Timeline export
# ---------------------------------------------------------------------------


def test_perfetto_export_is_valid_and_strict(tmp_path):
    fn, n, x = _traced_fn(0, "earliest")
    fn(n, x)
    tr = fn.last_trace
    path = str(tmp_path / "trace.json")
    obj = write_perfetto(path, tr)
    assert validate_perfetto(path) == len(obj["traceEvents"])
    with open(path) as f:  # strict JSON: no bare NaN/Infinity tokens
        json.load(f, parse_constant=lambda c: pytest.fail(
            f"non-strict constant {c!r} in perfetto output"))
    # One "X" event per traced dispatch, on the chosen block's track.
    xs = [e for e in obj["traceEvents"] if e["ph"] == "X"]
    assert len(xs) == len(tr)
    assert [e["tid"] for e in xs] == [int(b) for b in tr.block]
    assert obj["otherData"]["total_dispatches"] == tr.total_dispatches


def test_perfetto_validator_rejects_malformed():
    with pytest.raises(ValueError):
        validate_perfetto({"nope": []})
    with pytest.raises(ValueError):
        validate_perfetto({"traceEvents": []})
    with pytest.raises(ValueError):
        validate_perfetto(
            {"traceEvents": [{"name": "a", "ph": "X", "pid": 1}]}
        )  # X without ts/dur


def test_segment_tracks_merges_on_global_ordinals(tmp_path):
    fn, n, x = _traced_fn(3, "earliest")
    st = fn.stepper(n, x)
    state = st.init()
    traces = []
    while not st.done(state):
        state = st.step(state, 7)
        traces.append(st.trace(state))
    merged = segment_tracks(traces, path=str(tmp_path / "seg.json"))
    assert validate_perfetto(str(tmp_path / "seg.json")) > 0
    assert merged["otherData"]["segments"] == len(traces)
    names = [e["name"] for e in merged["traceEvents"] if e["ph"] == "M"]
    assert len(names) == len(set(
        (e["name"], e.get("tid")) for e in merged["traceEvents"]
        if e["ph"] == "M"
    )), "metadata events must be deduplicated"


# ---------------------------------------------------------------------------
# Block profiles
# ---------------------------------------------------------------------------


def test_block_profile_consistent_with_trace(tmp_path):
    fn, n, x = _traced_fn(0, "popular", collect_stats=True)
    fn(n, x)
    tr = fn.last_trace
    prof = block_profile(tr)
    np.testing.assert_array_equal(
        prof.dispatches, np.asarray(fn.last_result.block_exec)
    )
    np.testing.assert_array_equal(
        prof.total_active, np.asarray(fn.last_result.block_active)
    )
    assert (prof.wasted_slots >= 0).all()
    assert (prof.occupancy <= 1.0 + 1e-9).all()
    # Transition counts cover every consecutive scheduled pair.
    assert prof.transitions.sum() == len(tr) - 1
    # The versioned superblock-pass input format round-trips strictly.
    path = str(tmp_path / "prof.json")
    prof.save(path)
    with open(path) as f:
        obj = json.load(f, parse_constant=lambda c: pytest.fail(c))
    assert obj["version"] == PROFILE_VERSION
    assert len(obj["blocks"]) == tr.num_blocks
    assert sum(b["dispatches"] for b in obj["blocks"]) == len(tr)


def test_block_profile_excludes_sweep_iterations():
    fn, n, x = _traced_fn(0, "sweep")
    fn(n, x)
    prof = block_profile(fn.last_trace)
    assert prof.dispatches.sum() == 0
    assert prof.transitions.sum() == 0


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_counter(self):
        c = Counter("requests_total", "help!")
        c.inc()
        c.inc(2, status="ok")
        assert c.value() == 1 and c.value(status="ok") == 2
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_gauge(self):
        g = Gauge("depth")
        g.set(5)
        g.dec(2)
        assert g.value() == 3

    def test_histogram_percentiles_and_buckets(self):
        h = Histogram("lat", buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 0.5, 2.0):
            h.observe(v)
        assert h.count() == 4 and h.sum() == pytest.approx(3.05)
        assert h.percentile(50) == pytest.approx(0.5)
        assert np.isnan(h.percentile(50, status="missing"))
        rendered = dict(
            (name + labels, v) for name, labels, v in h.samples()
        )
        assert rendered['lat_bucket{le="0.1"}'] == 1
        assert rendered['lat_bucket{le="1"}'] == 3
        assert rendered['lat_bucket{le="+Inf"}'] == 4

    def test_registry_get_or_create_and_type_clash(self):
        r = MetricsRegistry()
        assert r.counter("a") is r.counter("a")
        with pytest.raises(ValueError):
            r.gauge("a")
        assert r.get("a").type == "counter"
        assert r.get("missing") is None

    def test_prometheus_rendering(self):
        r = MetricsRegistry()
        r.counter("reqs", "total requests").inc(3, status="ok")
        r.gauge("depth").set(2)
        r.histogram("lat", buckets=(1.0,)).observe(0.5)
        text = r.render_prometheus()
        assert "# HELP reqs total requests" in text
        assert "# TYPE reqs counter" in text
        assert 'reqs{status="ok"} 3' in text
        assert "depth 2" in text
        assert 'lat_bucket{le="+Inf"} 1' in text
        assert text.endswith("\n")

    def test_invalid_names_rejected(self):
        with pytest.raises(ValueError):
            Counter("bad name")
        with pytest.raises(ValueError):
            Gauge("1starts_with_digit")


# ---------------------------------------------------------------------------
# Drain shape contract
# ---------------------------------------------------------------------------


def test_dispatch_trace_properties():
    tr = DispatchTrace(
        schedule="earliest", num_blocks=2, batch_size=4, capacity=8,
        total_dispatches=3, dropped=0,
        steps=np.arange(3), block=np.array([0, 1, 0]),
        resident=np.array([[2, 1], [1, 1], [1, 0]]),
        active=np.array([2, 1, 1]), live=np.array([3, 2, 1]),
        quarantined=np.zeros(3, np.int64),
        tile_capacity=np.array([8, 8, 0]),
        compacted=np.zeros(3, bool),
        faults=np.array([0, 1, 1]),
    )
    assert len(tr) == 3
    occ = tr.occupancy
    assert occ[0] == pytest.approx(0.25)
    assert occ[2] == 0.0, "zero tile capacity must not divide to nan"
    np.testing.assert_array_equal(tr.fault_events, [0, 1, 0])
