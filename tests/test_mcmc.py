"""Tests for the NUTS workload: backend agreement, moments, baselines."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import api, lowering
from repro.mcmc import iterative, nuts, targets


@pytest.fixture(scope="module")
def small_nuts():
    t = targets.isotropic_gaussian(3)
    s = nuts.NutsSettings(max_tree_depth=5, num_steps=4, steps_per_leaf=2)
    prog = nuts.build_nuts_program(t, s)
    inp = nuts.initial_state(t, 4, eps=0.4, seed=2)
    return t, s, prog, inp


class TestNutsProgram:
    def test_lowering_structure(self, small_nuts):
        """The recursion forces stacks exactly on build_tree's frame state."""
        _, _, prog, _ = small_nuts
        low = lowering.lower(prog)
        # The recursive frame's parameters must be stacked.
        for v in ["build_tree/theta", "build_tree/r", "build_tree/j"]:
            assert v in low.stack_vars
        # Chain-level accumulators never cross a recursive call.
        assert "nuts_chain/sum_theta" not in low.stack_vars
        assert "nuts_chain/sum_sq" not in low.stack_vars

    @pytest.mark.parametrize("backend", ["pc", "local"])
    def test_agrees_with_reference(self, small_nuts, backend):
        """Batched NUTS must equal the unbatched oracle member-by-member.

        On an elementwise target the primitives are bitwise-stable under
        vmap, so whole chaotic trajectories must coincide."""
        t, s, prog, inp = small_nuts
        ref = api.autobatch(prog, 4, backend="reference")(inp)
        out = api.autobatch(
            prog, 4, backend=backend,
            max_depth=nuts.recommended_max_depth(s), max_steps=50_000,
        )(inp)
        for k in ("theta", "sum_theta", "sum_sq"):
            np.testing.assert_allclose(
                np.asarray(out[k]), ref[k], rtol=1e-4, atol=1e-4
            )

    def test_moments_correlated_gaussian(self):
        """Sampled marginal moments match the target (paper §4.2 problem)."""
        t = targets.correlated_gaussian(8, rho=0.9)
        s = nuts.NutsSettings(max_tree_depth=8, num_steps=60, steps_per_leaf=4)
        prog = nuts.build_nuts_program(t, s)
        z = 64
        inp = nuts.initial_state(t, z, eps=0.25, seed=3)
        bp = api.autobatch(
            prog, z, backend="pc",
            max_depth=nuts.recommended_max_depth(s), max_steps=200_000,
        )
        out = bp(inp)
        assert bool(bp.last_result.converged)
        n = z * s.num_steps
        mean = np.asarray(out["sum_theta"]).sum(0) / n
        ex2 = np.asarray(out["sum_sq"]).sum(0) / n
        std = np.sqrt(ex2 - mean**2)
        np.testing.assert_allclose(mean, 0.0, atol=0.12)
        np.testing.assert_allclose(std, 1.0, atol=0.12)

    def test_divergent_chains_have_low_utilization(self):
        """Different chains pick different tree depths => util < 1 (Fig. 6)."""
        t = targets.correlated_gaussian(8, rho=0.9)
        s = nuts.NutsSettings(max_tree_depth=8, num_steps=10, steps_per_leaf=4)
        prog = nuts.build_nuts_program(t, s)
        z = 16
        bp = api.autobatch(
            prog, z, backend="pc",
            max_depth=nuts.recommended_max_depth(s), max_steps=100_000,
        )
        bp(nuts.initial_state(t, z, eps=0.25, seed=4))
        util = bp.utilization["grad"]
        assert 0.0 < util < 1.0

    def test_logistic_regression_target_runs(self):
        t = targets.logistic_regression(num_data=200, dim=8, seed=0)
        s = nuts.NutsSettings(max_tree_depth=6, num_steps=3, steps_per_leaf=2)
        prog = nuts.build_nuts_program(t, s)
        z = 4
        bp = api.autobatch(
            prog, z, backend="pc",
            max_depth=nuts.recommended_max_depth(s), max_steps=50_000,
        )
        out = bp(nuts.initial_state(t, z, eps=0.05, seed=5))
        assert bool(bp.last_result.converged)
        assert np.all(np.isfinite(np.asarray(out["theta"])))


class TestIterativeBaseline:
    def test_moments(self):
        """The hand-batched iterative rewrite samples the same distribution."""
        t = targets.correlated_gaussian(8, rho=0.9)
        s = nuts.NutsSettings(max_tree_depth=8, num_steps=60, steps_per_leaf=4)
        z = 64
        inp = nuts.initial_state(t, z, eps=0.25, seed=3)
        out = iterative.run_batched(t, s, inp["theta0"], inp["eps"], inp["key"])
        n = z * s.num_steps
        mean = np.asarray(out["sum_theta"]).sum(0) / n
        ex2 = np.asarray(out["sum_sq"]).sum(0) / n
        std = np.sqrt(ex2 - mean**2)
        np.testing.assert_allclose(mean, 0.0, atol=0.12)
        np.testing.assert_allclose(std, 1.0, atol=0.12)
        assert int(out["grads"].sum()) > 0

    def test_matches_autobatched_grad_count_scale(self):
        """Grad-eval counts of the two implementations are the same order:
        both run the same doubling procedure over the same trajectories."""
        t = targets.isotropic_gaussian(4)
        s = nuts.NutsSettings(max_tree_depth=6, num_steps=5, steps_per_leaf=2)
        z = 8
        inp = nuts.initial_state(t, z, eps=0.3, seed=7)
        prog = nuts.build_nuts_program(t, s)
        bp = api.autobatch(
            prog, z, backend="pc",
            max_depth=nuts.recommended_max_depth(s), max_steps=50_000,
        )
        bp(inp)
        execs, active = bp.last_result.tag_stats["grad"]
        vm_grads = active * s.grads_per_leaf  # member-leaf evals
        out = iterative.run_batched(t, s, inp["theta0"], inp["eps"], inp["key"])
        it_grads = int(out["grads"].sum())
        assert 0.2 < vm_grads / it_grads < 5.0
