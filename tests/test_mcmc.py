"""Tests for the NUTS workload: backend agreement, moments, baselines.

NUTS runs entirely on the decorator-first pytree API: the kernel takes
positional ``(theta0, eps, key)`` arguments (``eps`` is a ``Shared``
scalar) and returns the pytree state ``{"theta", "sum_theta", "sum_sq"}``.
"""
import numpy as np
import pytest

from repro.core import lowering
from repro.mcmc import iterative, nuts, targets


@pytest.fixture(scope="module")
def small_nuts():
    t = targets.isotropic_gaussian(3)
    s = nuts.NutsSettings(max_tree_depth=5, num_steps=4, steps_per_leaf=2)
    args = nuts.initial_state(t, 4, eps=0.4, seed=2)
    return t, s, args


STATE_KEYS = ("theta", "sum_theta", "sum_sq")


class TestNutsProgram:
    def test_lowering_structure(self, small_nuts):
        """The recursion forces stacks exactly on build_tree's frame state."""
        t, s, _ = small_nuts
        low = lowering.lower(nuts.build_nuts_program(t, s))
        # The recursive frame's parameters must be stacked.
        for v in ["build_tree/theta", "build_tree/r", "build_tree/j"]:
            assert v in low.stack_vars
        # Chain-level accumulators never cross a recursive call.
        assert "nuts_chain/sum_theta" not in low.stack_vars
        assert "nuts_chain/sum_sq" not in low.stack_vars

    @pytest.mark.parametrize("backend", ["pc", "local"])
    def test_agrees_with_reference(self, small_nuts, backend):
        """Batched NUTS must equal the unbatched oracle member-by-member.

        On an elementwise target the primitives are bitwise-stable under
        vmap, so whole chaotic trajectories must coincide."""
        t, s, args = small_nuts
        ref = nuts.make_nuts_kernel(t, s, backend="reference")(*args)
        out = nuts.make_nuts_kernel(t, s, backend=backend,
                                    max_steps=50_000)(*args)
        assert set(out) == set(STATE_KEYS)
        for k in STATE_KEYS:
            np.testing.assert_allclose(
                np.asarray(out[k]), ref[k], rtol=1e-4, atol=1e-4
            )

    def test_moments_correlated_gaussian(self):
        """Sampled marginal moments match the target (paper §4.2 problem)."""
        t = targets.correlated_gaussian(8, rho=0.9)
        s = nuts.NutsSettings(max_tree_depth=8, num_steps=60, steps_per_leaf=4)
        z = 64
        kern = nuts.make_nuts_kernel(t, s, max_steps=200_000)
        state = kern(*nuts.initial_state(t, z, eps=0.25, seed=3))
        assert bool(kern.last_result.converged)
        n = z * s.num_steps
        mean = np.asarray(state["sum_theta"]).sum(0) / n
        ex2 = np.asarray(state["sum_sq"]).sum(0) / n
        std = np.sqrt(ex2 - mean**2)
        np.testing.assert_allclose(mean, 0.0, atol=0.12)
        np.testing.assert_allclose(std, 1.0, atol=0.12)

    def test_divergent_chains_have_low_utilization(self):
        """Different chains pick different tree depths => util < 1 (Fig. 6)."""
        t = targets.correlated_gaussian(8, rho=0.9)
        s = nuts.NutsSettings(max_tree_depth=8, num_steps=10, steps_per_leaf=4)
        kern = nuts.make_nuts_kernel(t, s, max_steps=100_000)
        assert kern.utilization == {}  # unified semantics: {} before any run
        kern(*nuts.initial_state(t, 16, eps=0.25, seed=4))
        util = kern.utilization["grad"]
        assert 0.0 < util < 1.0

    def test_logistic_regression_target_runs(self):
        t = targets.logistic_regression(num_data=200, dim=8, seed=0)
        s = nuts.NutsSettings(max_tree_depth=6, num_steps=3, steps_per_leaf=2)
        kern = nuts.make_nuts_kernel(t, s, max_steps=50_000)
        state = kern(*nuts.initial_state(t, 4, eps=0.05, seed=5))
        assert bool(kern.last_result.converged)
        assert np.all(np.isfinite(np.asarray(state["theta"])))

    def test_kernel_cache_shared_across_batch_sizes(self):
        """One NUTS kernel serves several chain counts; the stack-explicit
        lowering happens exactly once (the decorator API's cache contract)."""
        t = targets.isotropic_gaussian(2)
        s = nuts.NutsSettings(max_tree_depth=4, num_steps=2, steps_per_leaf=2)
        kern = nuts.make_nuts_kernel(t, s, max_steps=50_000)
        kern(*nuts.initial_state(t, 2, eps=0.4, seed=0))
        kern(*nuts.initial_state(t, 5, eps=0.4, seed=0))
        info = kern.cache_info()
        assert info.lowerings == 1 and info.misses == 2


class TestIterativeBaseline:
    def test_moments(self):
        """The hand-batched iterative rewrite samples the same distribution."""
        t = targets.correlated_gaussian(8, rho=0.9)
        s = nuts.NutsSettings(max_tree_depth=8, num_steps=60, steps_per_leaf=4)
        z = 64
        theta0, eps, keys = nuts.initial_state(t, z, eps=0.25, seed=3)
        out = iterative.run_batched(t, s, theta0, eps, keys)
        n = z * s.num_steps
        mean = np.asarray(out["sum_theta"]).sum(0) / n
        ex2 = np.asarray(out["sum_sq"]).sum(0) / n
        std = np.sqrt(ex2 - mean**2)
        np.testing.assert_allclose(mean, 0.0, atol=0.12)
        np.testing.assert_allclose(std, 1.0, atol=0.12)
        assert int(out["grads"].sum()) > 0

    def test_matches_autobatched_grad_count_scale(self):
        """Grad-eval counts of the two implementations are the same order:
        both run the same doubling procedure over the same trajectories."""
        t = targets.isotropic_gaussian(4)
        s = nuts.NutsSettings(max_tree_depth=6, num_steps=5, steps_per_leaf=2)
        theta0, eps, keys = nuts.initial_state(t, 8, eps=0.3, seed=7)
        kern = nuts.make_nuts_kernel(t, s, max_steps=50_000)
        kern(theta0, eps, keys)
        execs, active = kern.tag_stats["grad"]
        vm_grads = active * s.grads_per_leaf  # member-leaf evals
        out = iterative.run_batched(t, s, theta0, eps, keys)
        it_grads = int(out["grads"].sum())
        assert 0.2 < vm_grads / it_grads < 5.0
