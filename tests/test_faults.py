"""Per-lane fault containment: quarantine vs raise policies, fault codes
(NONFINITE / WATCHDOG / STACK_OVERFLOW), exception attributes, healthy-lane
bit-exactness across the schedule x fuse x mesh matrix, and the stepper's
fault surface (``tools/chaos.py`` is the CLI face of the same harness)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batching, frontend, pc_vm
from repro.core.frontend import spec

from tools.chaos import (
    EXPECT_CODE,
    LANE_STEP_BUDGET,
    MAX_DEPTH,
    build_chaos_program,
    make_modes,
    run_cell,
)

I32 = spec((), jnp.int32)
F32 = spec((), jnp.float32)


def _sqrt_program():
    """``f(x) = sqrt(x)``: negative lanes write NaN into VM state."""
    pb = frontend.ProgramBuilder(main="f")
    fb = pb.function("f", ["x"], ["out"], {"x": F32}, {"out": F32})
    fb.assign("out", lambda x: jnp.sqrt(x), ["x"], name="root")
    fb.return_()
    pb.add(fb)
    return pb.build()


def _chaos_fn(**kw):
    opts = dict(
        backend="pc", batch_size=8, max_depth=MAX_DEPTH,
        max_steps=100_000, detect_nonfinite=True,
        lane_step_budget=LANE_STEP_BUDGET,
    )
    opts.update(kw)
    return batching.autobatch(build_chaos_program(), **opts)


X8 = jnp.arange(8, dtype=jnp.int32) * 37


class TestQuarantine:
    def test_nonfinite_quarantined_lanes_flagged_healthy_exact(self):
        fn = batching.autobatch(
            _sqrt_program(), backend="pc", batch_size=4,
            on_fault="quarantine", detect_nonfinite=True,
        )
        x = jnp.asarray([1.0, 4.0, -1.0, 9.0], jnp.float32)
        out = np.asarray(fn(x)["out"])
        codes = np.asarray(jax.device_get(fn.last_result.fault_code))
        np.testing.assert_array_equal(
            codes, [0, 0, pc_vm.FAULT_NONFINITE, 0]
        )
        np.testing.assert_array_equal(out[[0, 1, 3]], [1.0, 2.0, 3.0])

    def test_nonfinite_check_is_opt_in(self):
        """Without detect_nonfinite, NaN flows through unfaulted (the
        historical behavior — finiteness checks cost a reduce per write)."""
        fn = batching.autobatch(
            _sqrt_program(), backend="pc", batch_size=2,
        )
        out = np.asarray(fn(jnp.asarray([-1.0, 4.0], jnp.float32))["out"])
        assert np.isnan(out[0]) and out[1] == 2.0
        codes = np.asarray(jax.device_get(fn.last_result.fault_code))
        assert not codes.any()

    @pytest.mark.parametrize("mode,code", [
        (1, pc_vm.FAULT_NONFINITE),
        (2, pc_vm.FAULT_WATCHDOG),
        (3, pc_vm.FAULT_STACK_OVERFLOW),
    ])
    def test_each_fault_kind_quarantines(self, mode, code):
        fn = _chaos_fn(on_fault="quarantine")
        modes = np.zeros((8,), np.int32)
        modes[2] = modes[5] = mode
        clean = np.asarray(fn(X8, jnp.zeros((8,), jnp.int32))["out"])
        out = np.asarray(fn(X8, jnp.asarray(modes))["out"])
        codes = np.asarray(jax.device_get(fn.last_result.fault_code))
        expect = np.where(modes == mode, code, 0)
        np.testing.assert_array_equal(codes, expect)
        healthy = modes == 0
        np.testing.assert_array_equal(out[healthy], clean[healthy])

    def test_converges_with_every_kind_at_once(self):
        """A mixed batch (NaN + livelock + overflow together) terminates
        and contains each fault to its own lane."""
        fn = _chaos_fn(on_fault="quarantine")
        modes = np.array([0, 1, 2, 3, 0, 3, 2, 1], np.int32)
        clean = np.asarray(fn(X8, jnp.zeros((8,), jnp.int32))["out"])
        out = np.asarray(fn(X8, jnp.asarray(modes))["out"])
        codes = np.asarray(jax.device_get(fn.last_result.fault_code))
        np.testing.assert_array_equal(
            codes, [EXPECT_CODE[int(m)] for m in modes]
        )
        np.testing.assert_array_equal(out[modes == 0], clean[modes == 0])


class TestRaisePolicy:
    def test_nonfinite_raises_lanefault_with_lanes(self):
        fn = batching.autobatch(
            _sqrt_program(), backend="pc", batch_size=4,
            on_fault="raise", detect_nonfinite=True,
        )
        x = jnp.asarray([1.0, -4.0, 9.0, -16.0], jnp.float32)
        with pytest.raises(pc_vm.LaneFault) as ei:
            fn(x)
        np.testing.assert_array_equal(ei.value.lanes, [1, 3])
        assert ei.value.faults == {1: "nonfinite", 3: "nonfinite"}
        assert "quarantine" in str(ei.value)

    def test_watchdog_raises_and_fails_fast(self):
        """Raise-mode watchdog halts the while_loop at the first fault —
        it must not spin to max_steps before reporting."""
        fn = _chaos_fn(on_fault="raise", max_steps=10_000_000)
        modes = np.zeros((8,), np.int32)
        modes[3] = 2
        with pytest.raises(pc_vm.LaneFault) as ei:
            fn(X8, jnp.asarray(modes))
        assert ei.value.faults == {3: "watchdog"}

    def test_overflow_carries_mask_and_lanes(self):
        fn = _chaos_fn(on_fault="raise")
        modes = np.zeros((8,), np.int32)
        modes[0] = modes[6] = 3
        with pytest.raises(pc_vm.StackOverflow) as ei:
            fn(X8, jnp.asarray(modes))
        np.testing.assert_array_equal(
            np.asarray(ei.value.depth_exceeded), modes == 3
        )
        np.testing.assert_array_equal(ei.value.lanes, [0, 6])


class TestValidation:
    def test_bad_on_fault_rejected(self):
        with pytest.raises(ValueError, match="on_fault"):
            batching.autobatch(
                _sqrt_program(), backend="pc", on_fault="ignore"
            )

    def test_bad_lane_step_budget_rejected(self):
        with pytest.raises(ValueError, match="lane_step_budget"):
            pc_vm.VMConfig(batch_size=2, lane_step_budget=0)


class TestMatrix:
    """The chaos harness's own acceptance: healthy lanes bit-exact with a
    fault-free run across schedule x fuse (x mesh where available)."""

    @pytest.mark.parametrize("schedule", pc_vm.SCHEDULES)
    @pytest.mark.parametrize("fuse", [True, False])
    def test_quarantine_matrix_cell(self, schedule, fuse):
        r = run_cell(
            build_chaos_program(), batch=8,
            modes=make_modes(8, 0.375, seed=0),
            schedule=schedule, fuse=fuse, mesh=None, seed=0,
        )
        assert r["ok"], r["violations"]
        assert r["faulted_lanes"] >= 3  # one of each kind at least

    def test_quarantine_mesh_cell(self):
        if jax.device_count() < 2:
            pytest.skip("needs >= 2 devices (see tests/conftest.py)")
        r = run_cell(
            build_chaos_program(), batch=8,
            modes=make_modes(8, 0.375, seed=0),
            schedule="earliest", fuse=True, mesh=2, seed=0,
        )
        assert r["ok"], r["violations"]


class TestStepperFaults:
    def _drive(self, st, state):
        while not st.done(state):
            state = st.step(state, 64)
        return state

    def test_fault_surface_and_inject_clears(self):
        fn = _chaos_fn(on_fault="quarantine")
        modes = np.array([0, 2, 0, 1, 0, 0, 3, 0], np.int32)
        st = fn.stepper(X8, jnp.asarray(modes))
        state = self._drive(st, st.init())
        codes = np.asarray(jax.device_get(st.fault_code(state)))
        np.testing.assert_array_equal(
            codes, [EXPECT_CODE[int(m)] for m in modes]
        )
        flagged = np.asarray(jax.device_get(st.lane_faulted(state)))
        np.testing.assert_array_equal(flagged, modes != 0)
        # Re-inject healthy work into the faulted lanes: faults clear and
        # the lanes run to completion again.
        mask = modes != 0
        state = st.inject(
            state, mask, X8, jnp.zeros((8,), jnp.int32)
        )
        assert not np.asarray(
            jax.device_get(st.lane_faulted(state))
        ).any()
        state = self._drive(st, state)
        codes = np.asarray(jax.device_get(st.fault_code(state)))
        assert not codes.any()
        clean = np.asarray(fn(X8, jnp.zeros((8,), jnp.int32))["out"])
        out = np.asarray(jax.device_get(st.outputs(state)["out"]))
        np.testing.assert_array_equal(out, clean)

    def test_result_raises_under_raise_policy_only(self):
        modes = np.array([0, 1, 0, 0, 0, 0, 0, 0], np.int32)
        fn = _chaos_fn(on_fault="raise")
        st = fn.stepper(X8, jnp.asarray(modes))
        state = self._drive(st, st.init())
        with pytest.raises(pc_vm.LaneFault):
            st.result(state)
        fn2 = _chaos_fn(on_fault="quarantine")
        st2 = fn2.stepper(X8, jnp.asarray(modes))
        state2 = self._drive(st2, st2.init())
        st2.result(state2)  # quarantine: no raise, codes tell the story


class TestCacheKey:
    def test_fault_knobs_are_part_of_the_executor_key(self):
        """Two wrappers over one program with different fault knobs must
        not share executors (the knobs change compiled behavior)."""
        prog = _sqrt_program()
        a = batching.autobatch(prog, backend="pc", batch_size=2,
                               on_fault="quarantine",
                               detect_nonfinite=True)
        b = batching.autobatch(prog, backend="pc", batch_size=2)
        x = jnp.asarray([-1.0, 4.0], jnp.float32)
        a(x)
        b(x)
        assert np.asarray(
            jax.device_get(a.last_result.fault_code)
        ).any()
        assert not np.asarray(
            jax.device_get(b.last_result.fault_code)
        ).any()
        assert a._aval_key({"x": x}, 2) != b._aval_key({"x": x}, 2)
