"""Suite-wide setup: fake a multi-device host platform.

Lane sharding (``mesh=``) needs more than one device to mean anything, and
CI runs on CPU-only machines.  Force 8 host CPU devices *before the first
jax import* (this conftest is imported by pytest ahead of every test
module), so sharded execution is exercised by the regular tier-1 run.
Single-device semantics are unchanged — jit still places unsharded work on
device 0 — and an operator-provided setting is respected.
"""
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8"
    ).strip()
