"""Serving tests: VM-scheduled engine vs sequential oracle, prefill step,
divergent lanes (prompt lengths, queue depths, EOS times)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.base import ShapeSpec
from repro.models import get_model
from repro.serve.engine import EngineConfig, GenerationEngine
from repro.serve.steps import decode_cache_window, make_prefill_step, \
    make_serve_step


@pytest.fixture(scope="module")
def small_lm():
    cfg = configs.get_smoke_config("smollm-135m")
    m = get_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


class TestVMEngine:
    @pytest.mark.parametrize("backend", ["pc", "local"])
    def test_matches_sequential_oracle(self, small_lm, backend):
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=4, max_context=32, max_prompt_len=6, max_new_tokens=8,
            requests_per_lane=2, eos_id=0, backend=backend,
        )
        eng = GenerationEngine(m, params, ecfg)
        rng = np.random.default_rng(0)
        prompts = rng.integers(
            1, m.cfg.vocab_size, (4, 2, 6)
        ).astype(np.int32)
        plens = rng.integers(2, 7, (4, 2)).astype(np.int32)
        res = eng.generate(prompts, plens)
        ref = eng.reference_generate(prompts, plens)
        np.testing.assert_array_equal(res["tokens"], ref["tokens"])
        np.testing.assert_array_equal(res["lengths"], ref["lengths"])

    def test_divergent_queue_depths(self, small_lm):
        """Lanes with different request counts reconverge correctly."""
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=4, max_context=32, max_prompt_len=5, max_new_tokens=4,
            requests_per_lane=3, eos_id=0, backend="pc",
        )
        eng = GenerationEngine(m, params, ecfg)
        rng = np.random.default_rng(1)
        prompts = rng.integers(1, m.cfg.vocab_size, (4, 3, 5)).astype(np.int32)
        plens = rng.integers(1, 6, (4, 3)).astype(np.int32)
        n_req = np.array([3, 1, 2, 3], np.int32)
        res = eng.generate(prompts, plens, n_req=n_req)
        ref = eng.reference_generate(prompts, plens, n_req=n_req)
        np.testing.assert_array_equal(res["tokens"], ref["tokens"])
        # un-run queue slots stay zero
        assert res["lengths"][1, 1] == 0 and res["lengths"][2, 2] == 0

    def test_utilization_under_divergence(self, small_lm):
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=8, max_context=32, max_prompt_len=8, max_new_tokens=6,
            requests_per_lane=1, eos_id=0, backend="pc",
        )
        eng = GenerationEngine(m, params, ecfg)
        rng = np.random.default_rng(2)
        prompts = rng.integers(1, m.cfg.vocab_size, (8, 1, 8)).astype(np.int32)
        plens = rng.integers(1, 9, (8, 1)).astype(np.int32)  # heavy skew
        res = eng.generate(prompts, plens)
        assert 0.0 < res["utilization"] <= 1.0

    def test_nonrecursive_program_has_no_stacks(self, small_lm):
        """Paper §3: loop-only programs get no data stacks in the PC VM."""
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=2, max_context=16, max_prompt_len=4, max_new_tokens=4,
            requests_per_lane=1, backend="pc",
        )
        eng = GenerationEngine(m, params, ecfg)
        assert eng.batched.lowered.stack_vars == frozenset()


class TestServeSteps:
    def test_prefill_matches_decode_chain(self, small_lm):
        m, params = small_lm
        b, s = 2, 16
        tokens = jax.random.randint(
            jax.random.PRNGKey(3), (b, s), 0, m.cfg.vocab_size
        )
        prefill = jax.jit(make_prefill_step(m))
        last = prefill(params, {"tokens": tokens})
        cache = m.init_cache(b, s)
        step = jax.jit(m.decode_step)
        for t in range(s):
            logits, cache = step(
                params, cache, tokens[:, t], jnp.full((b,), t, jnp.int32)
            )
        np.testing.assert_allclose(
            np.asarray(last), np.asarray(logits), rtol=2e-4, atol=2e-4
        )

    def test_serve_step_greedy(self, small_lm):
        m, params = small_lm
        serve = jax.jit(make_serve_step(m))
        cache = m.init_cache(2, 8)
        tok, cache = serve(
            params, cache, jnp.array([1, 2], jnp.int32),
            jnp.zeros((2,), jnp.int32), jax.random.PRNGKey(0),
        )
        assert tok.shape == (2,) and tok.dtype == jnp.int32

    def test_cache_window_rules(self):
        zcfg = configs.get_config("zamba2-7b")
        dcfg = configs.get_config("qwen3-0.6b")
        long = ShapeSpec("long_500k", 524_288, 1, "decode")
        dec = ShapeSpec("decode_32k", 32_768, 128, "decode")
        assert decode_cache_window(zcfg, long) == zcfg.long_context_window
        assert decode_cache_window(zcfg, dec) == 32_768
        assert decode_cache_window(dcfg, dec) == 32_768
