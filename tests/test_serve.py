"""Serving tests: VM-scheduled engine vs sequential oracle, prefill step,
divergent lanes (prompt lengths, queue depths, EOS times), edge-case
semantics (empty prompts, empty queues), and open-loop continuous
batching (retire-and-refill)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.base import ShapeSpec
from repro.models import get_model
from repro.serve.engine import (
    Completion,
    EngineConfig,
    GenerationEngine,
    Request,
    _cache_layout,
)
from repro.serve.steps import decode_cache_window, make_prefill_step, \
    make_serve_step


@pytest.fixture(scope="module")
def small_lm():
    cfg = configs.get_smoke_config("smollm-135m")
    m = get_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


class TestVMEngine:
    @pytest.mark.parametrize("backend", ["pc", "local"])
    def test_matches_sequential_oracle(self, small_lm, backend):
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=4, max_context=32, max_prompt_len=6, max_new_tokens=8,
            requests_per_lane=2, eos_id=0, backend=backend,
        )
        eng = GenerationEngine(m, params, ecfg)
        rng = np.random.default_rng(0)
        prompts = rng.integers(
            1, m.cfg.vocab_size, (4, 2, 6)
        ).astype(np.int32)
        plens = rng.integers(2, 7, (4, 2)).astype(np.int32)
        res = eng.generate(prompts, plens)
        ref = eng.reference_generate(prompts, plens)
        np.testing.assert_array_equal(res["tokens"], ref["tokens"])
        np.testing.assert_array_equal(res["lengths"], ref["lengths"])

    def test_divergent_queue_depths(self, small_lm):
        """Lanes with different request counts reconverge correctly."""
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=4, max_context=32, max_prompt_len=5, max_new_tokens=4,
            requests_per_lane=3, eos_id=0, backend="pc",
        )
        eng = GenerationEngine(m, params, ecfg)
        rng = np.random.default_rng(1)
        prompts = rng.integers(1, m.cfg.vocab_size, (4, 3, 5)).astype(np.int32)
        plens = rng.integers(1, 6, (4, 3)).astype(np.int32)
        n_req = np.array([3, 1, 2, 3], np.int32)
        res = eng.generate(prompts, plens, n_req=n_req)
        ref = eng.reference_generate(prompts, plens, n_req=n_req)
        np.testing.assert_array_equal(res["tokens"], ref["tokens"])
        # un-run queue slots stay zero
        assert res["lengths"][1, 1] == 0 and res["lengths"][2, 2] == 0

    def test_utilization_under_divergence(self, small_lm):
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=8, max_context=32, max_prompt_len=8, max_new_tokens=6,
            requests_per_lane=1, eos_id=0, backend="pc",
        )
        eng = GenerationEngine(m, params, ecfg)
        rng = np.random.default_rng(2)
        prompts = rng.integers(1, m.cfg.vocab_size, (8, 1, 8)).astype(np.int32)
        plens = rng.integers(1, 9, (8, 1)).astype(np.int32)  # heavy skew
        res = eng.generate(prompts, plens)
        assert 0.0 < res["utilization"] <= 1.0

    def test_nonrecursive_program_has_no_stacks(self, small_lm):
        """Paper §3: loop-only programs get no data stacks in the PC VM."""
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=2, max_context=16, max_prompt_len=4, max_new_tokens=4,
            requests_per_lane=1, backend="pc",
        )
        eng = GenerationEngine(m, params, ecfg)
        assert eng.batched.lowered.stack_vars == frozenset()

    def test_empty_prompts_match_oracle(self, small_lm):
        """Zero-length prompts produce empty completions — batched path
        and oracle agree (regression: the oracle used to crash on an
        unbound ``logits``)."""
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=3, max_context=32, max_prompt_len=5, max_new_tokens=6,
            requests_per_lane=2, eos_id=0, backend="pc",
        )
        eng = GenerationEngine(m, params, ecfg)
        rng = np.random.default_rng(3)
        prompts = rng.integers(1, m.cfg.vocab_size, (3, 2, 5)).astype(np.int32)
        # Empty prompts in every position: first, last, and a whole lane.
        plens = np.array([[0, 3], [2, 0], [0, 0]], np.int32)
        res = eng.generate(prompts, plens)
        ref = eng.reference_generate(prompts, plens)
        np.testing.assert_array_equal(res["tokens"], ref["tokens"])
        np.testing.assert_array_equal(res["lengths"], ref["lengths"])
        # The semantics, explicitly: empty prompt => no tokens emitted.
        assert res["lengths"][0, 0] == 0
        assert (res["tokens"][2] == 0).all() and (res["lengths"][2] == 0).all()

    def test_zero_request_lanes_match_oracle(self, small_lm):
        """Lanes with n_req == 0 (empty queues) stay all-zero in both the
        batched path and the oracle, including n_req == 0 everywhere."""
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=2, max_context=32, max_prompt_len=4, max_new_tokens=4,
            requests_per_lane=2, eos_id=0, backend="pc",
        )
        eng = GenerationEngine(m, params, ecfg)
        rng = np.random.default_rng(4)
        prompts = rng.integers(1, m.cfg.vocab_size, (2, 2, 4)).astype(np.int32)
        plens = rng.integers(1, 5, (2, 2)).astype(np.int32)
        for n_req in (np.array([2, 0], np.int32), np.zeros(2, np.int32)):
            res = eng.generate(prompts, plens, n_req=n_req)
            ref = eng.reference_generate(prompts, plens, n_req=n_req)
            np.testing.assert_array_equal(res["tokens"], ref["tokens"])
            np.testing.assert_array_equal(res["lengths"], ref["lengths"])
            for lane in np.flatnonzero(n_req == 0):
                assert (res["tokens"][lane] == 0).all()
                assert (res["lengths"][lane] == 0).all()


class TestCacheLayout:
    def test_ambiguous_leaf_raises_value_error(self):
        """_cache_layout names the offending leaf in a ValueError instead
        of an assert (asserts vanish under ``python -O``)."""

        class BadModel:
            def init_cache(self, batch, window):
                # 'k' is fine; 'v' scales two axes with the batch size.
                return {
                    "k": jnp.zeros((batch, window)),
                    "v": jnp.zeros((batch, batch + 1)),
                }

        with pytest.raises(ValueError, match=r"\['v'\]"):
            _cache_layout(BadModel(), 4)

    def test_batch_independent_leaf_raises_value_error(self):
        class ConstModel:
            def init_cache(self, batch, window):
                return {"scale": jnp.zeros((window,))}

        with pytest.raises(ValueError, match="scale"):
            _cache_layout(ConstModel(), 4)


class TestContinuousServe:
    """Open-loop serving: retire-and-refill over the segmented VM."""

    def _engine(self, small_lm, lanes=2, segment_steps=8, **kw):
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=lanes, max_context=32, max_prompt_len=5, max_new_tokens=6,
            requests_per_lane=1, eos_id=0, backend="pc",
            segment_steps=segment_steps, **kw,
        )
        return m, GenerationEngine(m, params, ecfg)

    def _oracle(self, m, params, requests, max_new=6):
        """Per-request greedy oracle via reference_generate, one lane each."""
        z = len(requests)
        ocfg = EngineConfig(
            lanes=z, max_context=32, max_prompt_len=5, max_new_tokens=max_new,
            requests_per_lane=1, eos_id=0,
        )
        oeng = GenerationEngine.__new__(GenerationEngine)
        oeng.model, oeng.params, oeng.cfg = m, params, ocfg
        prompts = np.zeros((z, 1, 5), np.int32)
        plens = np.zeros((z, 1), np.int32)
        for i, r in enumerate(requests):
            prompts[i, 0, : len(r.prompt)] = r.prompt
            plens[i, 0] = len(r.prompt)
        return oeng.reference_generate(prompts, plens)

    def test_more_requests_than_lanes_matches_oracle(self, small_lm):
        """5 requests through 2 lanes: every completion's tokens match the
        sequential oracle bit-for-bit — refill does not perturb decoding."""
        m, eng = self._engine(small_lm, lanes=2)
        rng = np.random.default_rng(5)
        reqs = [
            Request(rid=i, prompt=rng.integers(
                1, m.cfg.vocab_size, (1 + i % 5,)).astype(np.int32))
            for i in range(5)
        ]
        comps, stats = eng.serve(reqs)
        assert [c.rid for c in comps] == [0, 1, 2, 3, 4]
        ref = self._oracle(m, eng.params, reqs)
        for c in comps:
            expect = ref["tokens"][c.rid, 0, : ref["lengths"][c.rid, 0]]
            np.testing.assert_array_equal(c.tokens, expect)
        assert stats.completions == 5
        assert stats.generated_tokens == int(ref["lengths"].sum())
        assert 0.0 < stats.occupancy <= 1.0

    def test_metrics_and_latency_percentiles(self, small_lm):
        """serve() populates the engine's obs.metrics registry and backfills
        ServeStats.p50/p99 (exact percentiles over "ok" latencies)."""
        m, eng = self._engine(small_lm, lanes=2)
        rng = np.random.default_rng(7)
        reqs = [
            Request(rid=i, prompt=rng.integers(
                1, m.cfg.vocab_size, (3,)).astype(np.int32))
            for i in range(4)
        ]
        comps, stats = eng.serve(reqs)
        assert stats.ok == 4
        lat = sorted(c.latency for c in comps)
        assert 0.0 <= stats.p50_latency <= stats.p99_latency
        assert stats.p99_latency <= lat[-1] + 1e-9
        reg = eng.metrics
        assert reg.get("serve_admissions_total").value() == 4
        assert reg.get("serve_completions_total").value(status="ok") == 4
        assert (reg.get("serve_generated_tokens_total").value()
                == stats.generated_tokens)
        seg_h = reg.get("serve_segment_seconds")
        assert seg_h.count() == stats.segments
        lat_h = reg.get("serve_request_latency_seconds")
        assert lat_h.count(status="ok") == 4
        assert stats.p50_latency == lat_h.percentile(50, status="ok")
        text = reg.render_prometheus()
        assert 'serve_completions_total{status="ok"} 4' in text
        assert "# TYPE serve_segment_seconds histogram" in text
        # A shared registry aggregates across engines/runs.
        _, eng2 = self._engine(small_lm, lanes=2)
        eng2.metrics = reg
        eng2.serve([Request(rid=9, prompt=np.array([1], np.int32))])
        assert reg.get("serve_admissions_total").value() == 5

    def test_serve_with_trace_is_neutral(self, small_lm):
        """EngineConfig.trace composes with open-loop serving: identical
        completions, and the drained trace covers the run's dispatches."""
        m, eng = self._engine(small_lm, lanes=2)
        rng = np.random.default_rng(8)
        reqs = [
            Request(rid=i, prompt=rng.integers(
                1, m.cfg.vocab_size, (2 + i % 3,)).astype(np.int32))
            for i in range(3)
        ]
        base, base_stats = eng.serve(reqs)
        _, teng = self._engine(small_lm, lanes=2, trace=64)
        comps, stats = teng.serve(reqs)
        assert stats.vm_steps == base_stats.vm_steps
        for c, b in zip(comps, base):
            assert c.rid == b.rid and c.status == b.status
            np.testing.assert_array_equal(c.tokens, b.tokens)

    def test_streaming_and_lane_reuse(self, small_lm):
        """Completions stream via on_finish as lanes retire, and lanes are
        actually reused (more requests than lanes, bounded lane ids)."""
        m, eng = self._engine(small_lm, lanes=2, segment_steps=4)
        rng = np.random.default_rng(6)
        reqs = [
            Request(rid=i, prompt=rng.integers(
                1, m.cfg.vocab_size, (3,)).astype(np.int32))
            for i in range(4)
        ]
        streamed = []
        comps, _ = eng.serve(reqs, on_finish=streamed.append)
        assert len(streamed) == 4
        assert all(isinstance(c, Completion) for c in streamed)
        assert {c.lane for c in comps} <= {0, 1}
        # Streaming happened in retire order, which respects admission:
        # the first two admitted requests finish before the last one.
        assert streamed[-1].admitted >= streamed[0].admitted

    def test_empty_prompt_request(self, small_lm):
        """An empty prompt is a legal request: empty completion, lane is
        freed for the next request."""
        m, eng = self._engine(small_lm, lanes=1)
        rng = np.random.default_rng(7)
        reqs = [
            Request(rid=0, prompt=np.zeros((0,), np.int32)),
            Request(rid=1, prompt=rng.integers(
                1, m.cfg.vocab_size, (2,)).astype(np.int32)),
        ]
        comps, stats = eng.serve(reqs)
        assert comps[0].tokens.size == 0
        assert comps[1].tokens.size > 0
        assert stats.completions == 2

    def test_late_arrivals_with_virtual_clock(self, small_lm):
        """Requests admitted only once their arrival time has passed, on a
        caller-supplied clock: work genuinely arrives mid-flight."""
        m, eng = self._engine(small_lm, lanes=2, segment_steps=4)
        rng = np.random.default_rng(8)
        reqs = [
            Request(rid=0, prompt=rng.integers(
                1, m.cfg.vocab_size, (3,)).astype(np.int32), arrival=0.0),
            Request(rid=1, prompt=rng.integers(
                1, m.cfg.vocab_size, (2,)).astype(np.int32), arrival=2.0),
        ]
        # Virtual clock: one tick per call — arrival 2.0 is admitted only
        # after a couple of segments have already run.
        t = {"now": 0.0}

        def clock():
            t["now"] += 1.0
            return t["now"]

        comps, _ = eng.serve(reqs, now_fn=clock)
        assert [c.rid for c in comps] == [0, 1]
        assert comps[1].admitted >= 2.0
        ref = self._oracle(m, eng.params, reqs)
        for c in comps:
            expect = ref["tokens"][c.rid, 0, : ref["lengths"][c.rid, 0]]
            np.testing.assert_array_equal(c.tokens, expect)

    def test_sharded_serve_matches_unsharded(self, small_lm):
        """Retire-and-refill composes with lane sharding: injecting into a
        mesh-sharded snapshot yields the same per-request tokens."""
        if jax.device_count() < 2:
            pytest.skip("needs >= 2 devices (see tests/conftest.py)")
        m, eng = self._engine(small_lm, lanes=2, mesh=2)
        _, eng0 = self._engine(small_lm, lanes=2)
        rng = np.random.default_rng(9)
        reqs = [
            Request(rid=i, prompt=rng.integers(
                1, m.cfg.vocab_size, (1 + i % 5,)).astype(np.int32))
            for i in range(4)
        ]
        comps, _ = eng.serve(reqs)
        comps0, _ = eng0.serve(reqs)
        for a, b in zip(comps, comps0):
            assert a.rid == b.rid
            np.testing.assert_array_equal(a.tokens, b.tokens)

    def test_rejects_oversized_prompt(self, small_lm):
        _, eng = self._engine(small_lm, lanes=1)
        with pytest.raises(ValueError, match="max_prompt_len"):
            eng.serve([Request(rid=0, prompt=np.ones((9,), np.int32))])

    def test_serve_requires_pc_backend(self, small_lm):
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=1, max_context=16, max_prompt_len=4, max_new_tokens=2,
            requests_per_lane=1, backend="local",
        )
        eng = GenerationEngine(m, params, ecfg)
        with pytest.raises(ValueError, match="pc backend"):
            eng.serve([Request(rid=0, prompt=np.ones((2,), np.int32))])


class TestServeResilience:
    """Fault containment + degraded execution in the open-loop server:
    bounded queue shedding, deadlines, retry with backoff, quarantined
    lane faults, and crash-resume from a Checkpointer snapshot."""

    def _engine(self, small_lm, lanes=2, segment_steps=8, **kw):
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=lanes, max_context=32, max_prompt_len=5, max_new_tokens=6,
            requests_per_lane=1, eos_id=0, backend="pc",
            segment_steps=segment_steps, **kw,
        )
        return m, GenerationEngine(m, params, ecfg)

    def _reqs(self, m, n, seed=5, plen=None, arrival=0.0):
        rng = np.random.default_rng(seed)
        return [
            Request(
                rid=i,
                prompt=rng.integers(
                    1, m.cfg.vocab_size, (plen or (1 + i % 4),)
                ).astype(np.int32),
                arrival=arrival,
            )
            for i in range(n)
        ]

    def test_bounded_queue_sheds_as_rejected(self, small_lm):
        """1 lane + capacity-1 queue + 4 simultaneous arrivals: exactly
        two requests are shed with a terminal 'rejected' completion."""
        m, eng = self._engine(small_lm, lanes=1, queue_capacity=1)
        comps, stats = eng.serve(self._reqs(m, 4))
        assert stats.rejected == 2 and stats.ok == 2
        assert stats.completions == 4  # every request terminal
        by = {c.rid: c for c in comps}
        rejected = [c for c in comps if c.status == "rejected"]
        assert all(c.lane == -1 and c.tokens.size == 0 for c in rejected)
        assert by[0].status == "ok"  # first arrival got the lane

    def test_deadline_times_out_inflight_and_queued(self, small_lm):
        """A 1s deadline on a virtual clock that advances 0.6s per
        observation: both the in-flight and the queued request time out
        (no retries configured => terminal 'timeout')."""
        m, eng = self._engine(small_lm, lanes=1, deadline_s=1.0)
        t = {"now": 0.0}

        def clock():
            t["now"] += 0.6
            return t["now"]

        comps, stats = eng.serve(
            self._reqs(m, 2, plen=4), now_fn=clock
        )
        assert stats.timeout == 2 and stats.completions == 2
        assert all(c.status == "timeout" for c in comps)

    def test_watchdog_fault_retries_then_terminal(self, small_lm):
        """A lane-step budget no request can meet: every attempt faults
        'watchdog', each request retries once (backoff 0), and resolves
        terminally as 'faulted' with attempts == max_attempts."""
        m, eng = self._engine(
            small_lm, lanes=2, lane_step_budget=3, max_attempts=2,
            retry_backoff_s=0.0,
        )
        comps, stats = eng.serve(self._reqs(m, 2, plen=3))
        assert stats.retries == 2 and stats.faulted == 2
        for c in comps:
            assert c.status == "faulted"
            assert c.fault == "watchdog"
            assert c.attempts == 2
            assert c.tokens.size == 0

    def test_faults_do_not_perturb_healthy_lanes(self, small_lm):
        """A faulting request shares the batch with healthy ones: the
        healthy completions stay bit-exact with a fault-free serve."""
        m, eng = self._engine(
            small_lm, lanes=2, lane_step_budget=64, max_attempts=1,
        )
        healthy = self._reqs(m, 3, plen=2)
        clean, _ = eng.serve(healthy)
        # rid 3: max-length prompt and the budget tuned so only it trips.
        hog = Request(rid=3, prompt=np.full((5,), 1, np.int32))
        comps, stats = eng.serve(healthy + [hog])
        assert {c.rid for c in comps} == {0, 1, 2, 3}
        by = {c.rid: c for c in comps}
        if stats.faulted:  # the hog tripped the watchdog
            assert by[3].status == "faulted"
        for c in clean:
            np.testing.assert_array_equal(by[c.rid].tokens, c.tokens)
            assert by[c.rid].status == "ok"

    def test_crash_resume_completes_all_requests(self, small_lm, tmp_path):
        """Kill the host loop after two completions; a fresh engine with
        resume=True finishes every remaining request with tokens
        bit-exact to an uninterrupted run (at-least-once delivery)."""
        m, params = small_lm

        def mk(d):
            ecfg = EngineConfig(
                lanes=2, max_context=32, max_prompt_len=5,
                max_new_tokens=6, requests_per_lane=1, eos_id=0,
                backend="pc", segment_steps=4,
                checkpoint_dir=str(d), checkpoint_every_segments=1,
            )
            return GenerationEngine(m, params, ecfg)

        reqs = self._reqs(m, 5, seed=7)

        class Crash(Exception):
            pass

        seen = []

        def boom(c):
            seen.append(c)
            if len(seen) == 2:
                raise Crash

        eng = mk(tmp_path / "a")
        with pytest.raises(Crash):
            eng.serve(reqs, on_finish=boom)
        comps, stats = mk(tmp_path / "a").serve(reqs, resume=True)
        got = {c.rid for c in comps}
        assert {c.rid for c in seen} | got == {0, 1, 2, 3, 4}
        assert all(c.status == "ok" for c in comps)
        clean, _ = mk(tmp_path / "b").serve(reqs)
        ref = {c.rid: c.tokens for c in clean}
        for c in comps:
            np.testing.assert_array_equal(c.tokens, ref[c.rid])
        # resume after completion is a no-op (all rids recorded done)
        again, stats2 = mk(tmp_path / "a").serve(reqs, resume=True)
        assert again == [] and stats2.completions == 0

    def test_resume_requires_checkpoint_dir(self, small_lm):
        m, eng = self._engine(small_lm, lanes=1)
        with pytest.raises(ValueError, match="checkpoint_dir"):
            eng.serve(self._reqs(m, 1), resume=True)

    def test_straggler_policy_wired(self, small_lm):
        """A caller-supplied StragglerPolicy observes per-segment
        latencies; stats mirror its flagged count."""
        from repro.train.fault_tolerance import StragglerPolicy

        m, eng = self._engine(small_lm, lanes=2)
        pol = StragglerPolicy(threshold=3.0, warmup=2)
        _, stats = eng.serve(self._reqs(m, 3), straggler=pol)
        assert stats.straggler_events == len(pol.flagged)
        assert pol._n >= stats.segments > 0


class TestServeSteps:
    def test_prefill_matches_decode_chain(self, small_lm):
        m, params = small_lm
        b, s = 2, 16
        tokens = jax.random.randint(
            jax.random.PRNGKey(3), (b, s), 0, m.cfg.vocab_size
        )
        prefill = jax.jit(make_prefill_step(m))
        last = prefill(params, {"tokens": tokens})
        cache = m.init_cache(b, s)
        step = jax.jit(m.decode_step)
        for t in range(s):
            logits, cache = step(
                params, cache, tokens[:, t], jnp.full((b,), t, jnp.int32)
            )
        np.testing.assert_allclose(
            np.asarray(last), np.asarray(logits), rtol=2e-4, atol=2e-4
        )

    def test_serve_step_greedy(self, small_lm):
        m, params = small_lm
        serve = jax.jit(make_serve_step(m))
        cache = m.init_cache(2, 8)
        tok, cache = serve(
            params, cache, jnp.array([1, 2], jnp.int32),
            jnp.zeros((2,), jnp.int32), jax.random.PRNGKey(0),
        )
        assert tok.shape == (2,) and tok.dtype == jnp.int32

    def test_cache_window_rules(self):
        zcfg = configs.get_config("zamba2-7b")
        dcfg = configs.get_config("qwen3-0.6b")
        long = ShapeSpec("long_500k", 524_288, 1, "decode")
        dec = ShapeSpec("decode_32k", 32_768, 128, "decode")
        assert decode_cache_window(zcfg, long) == zcfg.long_context_window
        assert decode_cache_window(zcfg, dec) == 32_768
        assert decode_cache_window(dcfg, dec) == 32_768
