"""Serving tests: VM-scheduled engine vs sequential oracle, prefill step,
divergent lanes (prompt lengths, queue depths, EOS times), edge-case
semantics (empty prompts, empty queues), and open-loop continuous
batching (retire-and-refill)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.base import ShapeSpec
from repro.models import get_model
from repro.serve.engine import (
    Completion,
    EngineConfig,
    GenerationEngine,
    Request,
    _cache_layout,
)
from repro.serve.steps import decode_cache_window, make_prefill_step, \
    make_serve_step


@pytest.fixture(scope="module")
def small_lm():
    cfg = configs.get_smoke_config("smollm-135m")
    m = get_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


class TestVMEngine:
    @pytest.mark.parametrize("backend", ["pc", "local"])
    def test_matches_sequential_oracle(self, small_lm, backend):
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=4, max_context=32, max_prompt_len=6, max_new_tokens=8,
            requests_per_lane=2, eos_id=0, backend=backend,
        )
        eng = GenerationEngine(m, params, ecfg)
        rng = np.random.default_rng(0)
        prompts = rng.integers(
            1, m.cfg.vocab_size, (4, 2, 6)
        ).astype(np.int32)
        plens = rng.integers(2, 7, (4, 2)).astype(np.int32)
        res = eng.generate(prompts, plens)
        ref = eng.reference_generate(prompts, plens)
        np.testing.assert_array_equal(res["tokens"], ref["tokens"])
        np.testing.assert_array_equal(res["lengths"], ref["lengths"])

    def test_divergent_queue_depths(self, small_lm):
        """Lanes with different request counts reconverge correctly."""
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=4, max_context=32, max_prompt_len=5, max_new_tokens=4,
            requests_per_lane=3, eos_id=0, backend="pc",
        )
        eng = GenerationEngine(m, params, ecfg)
        rng = np.random.default_rng(1)
        prompts = rng.integers(1, m.cfg.vocab_size, (4, 3, 5)).astype(np.int32)
        plens = rng.integers(1, 6, (4, 3)).astype(np.int32)
        n_req = np.array([3, 1, 2, 3], np.int32)
        res = eng.generate(prompts, plens, n_req=n_req)
        ref = eng.reference_generate(prompts, plens, n_req=n_req)
        np.testing.assert_array_equal(res["tokens"], ref["tokens"])
        # un-run queue slots stay zero
        assert res["lengths"][1, 1] == 0 and res["lengths"][2, 2] == 0

    def test_utilization_under_divergence(self, small_lm):
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=8, max_context=32, max_prompt_len=8, max_new_tokens=6,
            requests_per_lane=1, eos_id=0, backend="pc",
        )
        eng = GenerationEngine(m, params, ecfg)
        rng = np.random.default_rng(2)
        prompts = rng.integers(1, m.cfg.vocab_size, (8, 1, 8)).astype(np.int32)
        plens = rng.integers(1, 9, (8, 1)).astype(np.int32)  # heavy skew
        res = eng.generate(prompts, plens)
        assert 0.0 < res["utilization"] <= 1.0

    def test_nonrecursive_program_has_no_stacks(self, small_lm):
        """Paper §3: loop-only programs get no data stacks in the PC VM."""
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=2, max_context=16, max_prompt_len=4, max_new_tokens=4,
            requests_per_lane=1, backend="pc",
        )
        eng = GenerationEngine(m, params, ecfg)
        assert eng.batched.lowered.stack_vars == frozenset()

    def test_empty_prompts_match_oracle(self, small_lm):
        """Zero-length prompts produce empty completions — batched path
        and oracle agree (regression: the oracle used to crash on an
        unbound ``logits``)."""
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=3, max_context=32, max_prompt_len=5, max_new_tokens=6,
            requests_per_lane=2, eos_id=0, backend="pc",
        )
        eng = GenerationEngine(m, params, ecfg)
        rng = np.random.default_rng(3)
        prompts = rng.integers(1, m.cfg.vocab_size, (3, 2, 5)).astype(np.int32)
        # Empty prompts in every position: first, last, and a whole lane.
        plens = np.array([[0, 3], [2, 0], [0, 0]], np.int32)
        res = eng.generate(prompts, plens)
        ref = eng.reference_generate(prompts, plens)
        np.testing.assert_array_equal(res["tokens"], ref["tokens"])
        np.testing.assert_array_equal(res["lengths"], ref["lengths"])
        # The semantics, explicitly: empty prompt => no tokens emitted.
        assert res["lengths"][0, 0] == 0
        assert (res["tokens"][2] == 0).all() and (res["lengths"][2] == 0).all()

    def test_zero_request_lanes_match_oracle(self, small_lm):
        """Lanes with n_req == 0 (empty queues) stay all-zero in both the
        batched path and the oracle, including n_req == 0 everywhere."""
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=2, max_context=32, max_prompt_len=4, max_new_tokens=4,
            requests_per_lane=2, eos_id=0, backend="pc",
        )
        eng = GenerationEngine(m, params, ecfg)
        rng = np.random.default_rng(4)
        prompts = rng.integers(1, m.cfg.vocab_size, (2, 2, 4)).astype(np.int32)
        plens = rng.integers(1, 5, (2, 2)).astype(np.int32)
        for n_req in (np.array([2, 0], np.int32), np.zeros(2, np.int32)):
            res = eng.generate(prompts, plens, n_req=n_req)
            ref = eng.reference_generate(prompts, plens, n_req=n_req)
            np.testing.assert_array_equal(res["tokens"], ref["tokens"])
            np.testing.assert_array_equal(res["lengths"], ref["lengths"])
            for lane in np.flatnonzero(n_req == 0):
                assert (res["tokens"][lane] == 0).all()
                assert (res["lengths"][lane] == 0).all()


class TestCacheLayout:
    def test_ambiguous_leaf_raises_value_error(self):
        """_cache_layout names the offending leaf in a ValueError instead
        of an assert (asserts vanish under ``python -O``)."""

        class BadModel:
            def init_cache(self, batch, window):
                # 'k' is fine; 'v' scales two axes with the batch size.
                return {
                    "k": jnp.zeros((batch, window)),
                    "v": jnp.zeros((batch, batch + 1)),
                }

        with pytest.raises(ValueError, match=r"\['v'\]"):
            _cache_layout(BadModel(), 4)

    def test_batch_independent_leaf_raises_value_error(self):
        class ConstModel:
            def init_cache(self, batch, window):
                return {"scale": jnp.zeros((window,))}

        with pytest.raises(ValueError, match="scale"):
            _cache_layout(ConstModel(), 4)


class TestContinuousServe:
    """Open-loop serving: retire-and-refill over the segmented VM."""

    def _engine(self, small_lm, lanes=2, segment_steps=8, **kw):
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=lanes, max_context=32, max_prompt_len=5, max_new_tokens=6,
            requests_per_lane=1, eos_id=0, backend="pc",
            segment_steps=segment_steps, **kw,
        )
        return m, GenerationEngine(m, params, ecfg)

    def _oracle(self, m, params, requests, max_new=6):
        """Per-request greedy oracle via reference_generate, one lane each."""
        z = len(requests)
        ocfg = EngineConfig(
            lanes=z, max_context=32, max_prompt_len=5, max_new_tokens=max_new,
            requests_per_lane=1, eos_id=0,
        )
        oeng = GenerationEngine.__new__(GenerationEngine)
        oeng.model, oeng.params, oeng.cfg = m, params, ocfg
        prompts = np.zeros((z, 1, 5), np.int32)
        plens = np.zeros((z, 1), np.int32)
        for i, r in enumerate(requests):
            prompts[i, 0, : len(r.prompt)] = r.prompt
            plens[i, 0] = len(r.prompt)
        return oeng.reference_generate(prompts, plens)

    def test_more_requests_than_lanes_matches_oracle(self, small_lm):
        """5 requests through 2 lanes: every completion's tokens match the
        sequential oracle bit-for-bit — refill does not perturb decoding."""
        m, eng = self._engine(small_lm, lanes=2)
        rng = np.random.default_rng(5)
        reqs = [
            Request(rid=i, prompt=rng.integers(
                1, m.cfg.vocab_size, (1 + i % 5,)).astype(np.int32))
            for i in range(5)
        ]
        comps, stats = eng.serve(reqs)
        assert [c.rid for c in comps] == [0, 1, 2, 3, 4]
        ref = self._oracle(m, eng.params, reqs)
        for c in comps:
            expect = ref["tokens"][c.rid, 0, : ref["lengths"][c.rid, 0]]
            np.testing.assert_array_equal(c.tokens, expect)
        assert stats.completions == 5
        assert stats.generated_tokens == int(ref["lengths"].sum())
        assert 0.0 < stats.occupancy <= 1.0

    def test_streaming_and_lane_reuse(self, small_lm):
        """Completions stream via on_finish as lanes retire, and lanes are
        actually reused (more requests than lanes, bounded lane ids)."""
        m, eng = self._engine(small_lm, lanes=2, segment_steps=4)
        rng = np.random.default_rng(6)
        reqs = [
            Request(rid=i, prompt=rng.integers(
                1, m.cfg.vocab_size, (3,)).astype(np.int32))
            for i in range(4)
        ]
        streamed = []
        comps, _ = eng.serve(reqs, on_finish=streamed.append)
        assert len(streamed) == 4
        assert all(isinstance(c, Completion) for c in streamed)
        assert {c.lane for c in comps} <= {0, 1}
        # Streaming happened in retire order, which respects admission:
        # the first two admitted requests finish before the last one.
        assert streamed[-1].admitted >= streamed[0].admitted

    def test_empty_prompt_request(self, small_lm):
        """An empty prompt is a legal request: empty completion, lane is
        freed for the next request."""
        m, eng = self._engine(small_lm, lanes=1)
        rng = np.random.default_rng(7)
        reqs = [
            Request(rid=0, prompt=np.zeros((0,), np.int32)),
            Request(rid=1, prompt=rng.integers(
                1, m.cfg.vocab_size, (2,)).astype(np.int32)),
        ]
        comps, stats = eng.serve(reqs)
        assert comps[0].tokens.size == 0
        assert comps[1].tokens.size > 0
        assert stats.completions == 2

    def test_late_arrivals_with_virtual_clock(self, small_lm):
        """Requests admitted only once their arrival time has passed, on a
        caller-supplied clock: work genuinely arrives mid-flight."""
        m, eng = self._engine(small_lm, lanes=2, segment_steps=4)
        rng = np.random.default_rng(8)
        reqs = [
            Request(rid=0, prompt=rng.integers(
                1, m.cfg.vocab_size, (3,)).astype(np.int32), arrival=0.0),
            Request(rid=1, prompt=rng.integers(
                1, m.cfg.vocab_size, (2,)).astype(np.int32), arrival=2.0),
        ]
        # Virtual clock: one tick per call — arrival 2.0 is admitted only
        # after a couple of segments have already run.
        t = {"now": 0.0}

        def clock():
            t["now"] += 1.0
            return t["now"]

        comps, _ = eng.serve(reqs, now_fn=clock)
        assert [c.rid for c in comps] == [0, 1]
        assert comps[1].admitted >= 2.0
        ref = self._oracle(m, eng.params, reqs)
        for c in comps:
            expect = ref["tokens"][c.rid, 0, : ref["lengths"][c.rid, 0]]
            np.testing.assert_array_equal(c.tokens, expect)

    def test_sharded_serve_matches_unsharded(self, small_lm):
        """Retire-and-refill composes with lane sharding: injecting into a
        mesh-sharded snapshot yields the same per-request tokens."""
        if jax.device_count() < 2:
            pytest.skip("needs >= 2 devices (see tests/conftest.py)")
        m, eng = self._engine(small_lm, lanes=2, mesh=2)
        _, eng0 = self._engine(small_lm, lanes=2)
        rng = np.random.default_rng(9)
        reqs = [
            Request(rid=i, prompt=rng.integers(
                1, m.cfg.vocab_size, (1 + i % 5,)).astype(np.int32))
            for i in range(4)
        ]
        comps, _ = eng.serve(reqs)
        comps0, _ = eng0.serve(reqs)
        for a, b in zip(comps, comps0):
            assert a.rid == b.rid
            np.testing.assert_array_equal(a.tokens, b.tokens)

    def test_rejects_oversized_prompt(self, small_lm):
        _, eng = self._engine(small_lm, lanes=1)
        with pytest.raises(ValueError, match="max_prompt_len"):
            eng.serve([Request(rid=0, prompt=np.ones((9,), np.int32))])

    def test_serve_requires_pc_backend(self, small_lm):
        m, params = small_lm
        ecfg = EngineConfig(
            lanes=1, max_context=16, max_prompt_len=4, max_new_tokens=2,
            requests_per_lane=1, backend="local",
        )
        eng = GenerationEngine(m, params, ecfg)
        with pytest.raises(ValueError, match="pc backend"):
            eng.serve([Request(rid=0, prompt=np.ones((2,), np.int32))])


class TestServeSteps:
    def test_prefill_matches_decode_chain(self, small_lm):
        m, params = small_lm
        b, s = 2, 16
        tokens = jax.random.randint(
            jax.random.PRNGKey(3), (b, s), 0, m.cfg.vocab_size
        )
        prefill = jax.jit(make_prefill_step(m))
        last = prefill(params, {"tokens": tokens})
        cache = m.init_cache(b, s)
        step = jax.jit(m.decode_step)
        for t in range(s):
            logits, cache = step(
                params, cache, tokens[:, t], jnp.full((b,), t, jnp.int32)
            )
        np.testing.assert_allclose(
            np.asarray(last), np.asarray(logits), rtol=2e-4, atol=2e-4
        )

    def test_serve_step_greedy(self, small_lm):
        m, params = small_lm
        serve = jax.jit(make_serve_step(m))
        cache = m.init_cache(2, 8)
        tok, cache = serve(
            params, cache, jnp.array([1, 2], jnp.int32),
            jnp.zeros((2,), jnp.int32), jax.random.PRNGKey(0),
        )
        assert tok.shape == (2,) and tok.dtype == jnp.int32

    def test_cache_window_rules(self):
        zcfg = configs.get_config("zamba2-7b")
        dcfg = configs.get_config("qwen3-0.6b")
        long = ShapeSpec("long_500k", 524_288, 1, "decode")
        dec = ShapeSpec("decode_32k", 32_768, 128, "decode")
        assert decode_cache_window(zcfg, long) == zcfg.long_context_window
        assert decode_cache_window(zcfg, dec) == 32_768
        assert decode_cache_window(dcfg, dec) == 32_768
