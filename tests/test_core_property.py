"""Property-based tests: random control-flow programs, all backends agree.

The invariant under test is the paper's correctness argument (§2): "consider
this runtime from the point of view of one batch member — every time the
runtime runs one of its blocks, it updates that member exactly as a size-1
batch would".  We generate random terminating programs with divergent
branches, bounded loops, and structurally-decreasing recursion, then check
the local-static interpreter and the PC VM member-for-member against the
unbatched reference interpreter.
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # randomized tests skip; deterministic ones still run
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="needs hypothesis (pip install -r "
                "requirements-dev.txt)"
            )(fn)
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _StrategyStub:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

from repro.core import api, batching, frontend
from repro.core.frontend import I32

# Small, wrap-safe int arithmetic (identical semantics in np/jnp int32).
_BINOPS = [
    ("add", lambda a, b: a + b),
    ("sub", lambda a, b: a - b),
    ("xor", lambda a, b: a ^ b),
    ("min", lambda a, b: jnp.minimum(a, b)),
    ("max", lambda a, b: jnp.maximum(a, b)),
]
_CMPS = [
    ("lt", lambda a, b: a < b),
    ("le", lambda a, b: a <= b),
    ("eq", lambda a, b: (a & 3) == (b & 3)),
]


class _Gen:
    """Deterministic random program generator driven by a hypothesis seed."""

    def __init__(self, rng: np.random.Generator):
        self.rng = rng

    def expr(self, fb, scope):
        a, b = self.rng.choice(scope, 2)
        name, fn = _BINOPS[self.rng.integers(len(_BINOPS))]
        return fb.prim(fn, [a, b], name=name)

    def cond(self, fb, scope):
        a, b = self.rng.choice(scope, 2)
        name, fn = _CMPS[self.rng.integers(len(_CMPS))]
        return fb.prim(fn, [a, b], name=name)

    def stmts(self, fb, scope, depth, allow_call):
        n = int(self.rng.integers(1, 4))
        for _ in range(n):
            kind = self.rng.integers(4)
            if kind == 0 or depth >= 2:
                scope.append(self.expr(fb, scope))
            elif kind == 1:
                c = self.cond(fb, scope)
                with fb.if_(c):
                    self.stmts(fb, list(scope), depth + 1, allow_call)
                if self.rng.integers(2):
                    with fb.orelse():
                        self.stmts(fb, list(scope), depth + 1, allow_call)
            elif kind == 2:
                # Bounded counter loop (always terminates).
                i = fb.prim(
                    lambda: jnp.int32(3), (), name="c3"
                )
                with fb.while_(lambda i: i > 0, [i]):
                    self.stmts(fb, list(scope) + [i], depth + 1, False)
                    fb.assign(i, lambda i: i - 1, [i])
            elif allow_call:
                # Structurally decreasing recursion on 'n'.
                t = fb.prim(lambda n: n - 1, ["n"], name="dec")
                arg = self.rng.choice(scope)
                scope.append(fb.call("f", [t, arg]))

    def build(self):
        pb = frontend.ProgramBuilder()
        fb = pb.function(
            "f",
            ["n", "x"],
            ["out"],
            {"n": I32, "x": I32},
            {"out": I32},
        )
        c = fb.prim(lambda n: n <= 0, ["n"], name="base")
        with fb.if_(c):
            fb.copy("x", out="out")
            fb.return_()
        scope = ["n", "x"]
        self.stmts(fb, scope, 0, allow_call=True)
        a, b = self.rng.choice(scope, 2)
        fb.assign("out", lambda a, b: a + b, [a, b])
        fb.return_()
        pb.add(fb)
        return pb.build()


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    inputs=st.lists(
        st.tuples(st.integers(0, 4), st.integers(-50, 50)),
        min_size=1,
        max_size=6,
    ),
)
def test_backends_agree_on_random_programs(seed, inputs):
    rng = np.random.default_rng(seed)
    prog = _Gen(rng).build()
    n = np.array([i[0] for i in inputs], np.int32)
    x = np.array([i[1] for i in inputs], np.int32)
    z = len(inputs)
    ref = api.autobatch(prog, z, backend="reference", max_depth=64)(
        {"n": n, "x": x}
    )["out"]
    for backend in ("pc", "local"):
        got = api.autobatch(
            prog, z, backend=backend, max_depth=64, max_steps=200_000
        )({"n": n, "x": x})["out"]
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(ref), err_msg=f"{backend} != reference"
        )


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    inputs=st.lists(
        st.tuples(st.integers(0, 4), st.integers(-50, 50)),
        min_size=1,
        max_size=5,
    ),
)
def test_schedule_fuse_matrix_matches_reference(seed, inputs):
    """Every schedule x fuse combination of the pc VM is bit-exact against
    the unbatched reference on random recursive CFG programs (the ISSUE 2
    superblock-fusion / pluggable-scheduler contract)."""
    rng = np.random.default_rng(seed)
    prog = _Gen(rng).build()
    n = np.array([i[0] for i in inputs], np.int32)
    x = np.array([i[1] for i in inputs], np.int32)
    z = len(inputs)
    ref = api.autobatch(prog, z, backend="reference", max_depth=64)(
        {"n": n, "x": x}
    )["out"]
    for schedule in ("earliest", "popular", "sweep"):
        for fuse in (False, True):
            got = api.autobatch(
                prog, z, backend="pc", max_depth=64, max_steps=200_000,
                schedule=schedule, fuse=fuse, verify=True,
            )({"n": n, "x": x})["out"]
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(ref),
                err_msg=f"pc[{schedule},fuse={fuse}] != reference",
            )


@settings(max_examples=4, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    inputs=st.lists(
        st.tuples(st.integers(0, 4), st.integers(-50, 50)),
        min_size=1,
        max_size=5,
    ),
)
def test_mesh_schedule_fuse_matrix_matches_reference(seed, inputs):
    """Lane sharding composes with every schedule x fuse combination and
    stays bit-exact against the unbatched reference (the ISSUE 3 mesh
    contract).  The batch is padded (members are independent) so it
    divides across the 2-device mesh."""
    import jax

    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (see tests/conftest.py)")
    rng = np.random.default_rng(seed)
    prog = _Gen(rng).build()
    pairs = list(inputs)
    if len(pairs) % 2:
        pairs.append(pairs[-1])  # pad to divide across the mesh
    n = np.array([i[0] for i in pairs], np.int32)
    x = np.array([i[1] for i in pairs], np.int32)
    z = len(pairs)
    ref = api.autobatch(prog, z, backend="reference", max_depth=64)(
        {"n": n, "x": x}
    )["out"]
    for schedule in ("earliest", "popular", "sweep"):
        for fuse in (False, True):
            got = api.autobatch(
                prog, z, backend="pc", max_depth=64, max_steps=200_000,
                schedule=schedule, fuse=fuse, mesh=2, verify=True,
            )({"n": n, "x": x})["out"]
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(ref),
                err_msg=f"pc[{schedule},fuse={fuse},mesh=2] != reference",
            )


@pytest.mark.parametrize("seed,seg", [(0, 1), (1, 3), (2, 7), (3, 64)])
def test_segmented_matches_single_shot_matrix(seed, seg):
    """Segmented execution (the ISSUE 5 resumable-VM contract) is bit-exact
    with single-shot for every schedule x fuse x mesh combination: chaining
    ``stepper.step(state, seg)`` segments of any size yields identical
    outputs AND an identical step count on random recursive CFG programs.

    Deterministic (seeded) rather than hypothesis-driven so the matrix
    always runs; the program generator is the same ``_Gen``."""
    import jax

    rng = np.random.default_rng(seed)
    prog = _Gen(rng).build()
    pairs = [(int(rng.integers(0, 5)), int(rng.integers(-50, 51)))
             for _ in range(4)]
    n = np.array([i[0] for i in pairs], np.int32)
    x = np.array([i[1] for i in pairs], np.int32)
    meshes = [None] + ([2] if jax.device_count() >= 2 else [])
    for mesh in meshes:
        for schedule in ("earliest", "popular", "sweep"):
            for fuse in (False, True):
                fn = batching.autobatch(
                    prog, backend="pc", max_depth=64, max_steps=200_000,
                    schedule=schedule, fuse=fuse, mesh=mesh, verify=True,
                )
                single = np.asarray(fn(n, x)["out"])
                single_steps = int(fn.last_result.steps)
                st_ = fn.stepper(n, x)
                state = st_.init()
                budget = 0
                while not st_.done(state):
                    state = st_.step(state, seg)
                    budget += 1
                    assert budget < 200_000
                tag = f"pc[{schedule},fuse={fuse},mesh={mesh},seg={seg}]"
                np.testing.assert_array_equal(
                    np.asarray(st_.result(state)["out"]), single,
                    err_msg=f"{tag} outputs != single-shot",
                )
                assert st_.steps(state) == single_steps, (
                    f"{tag}: segmented step count {st_.steps(state)} != "
                    f"single-shot {single_steps}"
                )


@pytest.mark.parametrize(
    "schedule,fuse",
    [
        ("earliest", True),
        ("popular", True),
        ("sweep", True),
        ("lookahead", True),
        ("popular", False),
    ],
)
def test_compaction_kernel_matrix_matches_uncompacted(schedule, fuse):
    """The ISSUE 8 tentpole contract: ``compact_every`` x ``use_kernel`` x
    mesh extends the schedule x fuse x mesh matrix bit-exactly.  For every
    cell, outputs, per-lane ordering AND the VM step count must be
    identical to the uncompacted, kernel-free, unsharded run — compaction
    permutes rows and tracks ``lane_ids``, schedules only ever observe
    permutation-invariant reductions, and the Pallas stack kernels run
    shard-locally under a mesh."""
    import jax

    rng = np.random.default_rng(11)
    prog = _Gen(rng).build()
    pairs = [(int(rng.integers(0, 5)), int(rng.integers(-50, 51)))
             for _ in range(8)]
    n = np.array([i[0] for i in pairs], np.int32)
    x = np.array([i[1] for i in pairs], np.int32)
    base_fn = batching.autobatch(
        prog, backend="pc", max_depth=64, max_steps=200_000,
        schedule=schedule, fuse=fuse,
    )
    base = np.asarray(base_fn(n, x)["out"])
    base_steps = int(base_fn.last_result.steps)
    meshes = [None] + ([2] if jax.device_count() >= 2 else [])
    # use_kernel=True cells are pallas-interpret on CPU (slow), so they
    # run a trimmed compact axis; the pure-compaction cells run all of it.
    cells = [(False, 1), (False, 7), (True, None), (True, 1)]
    for mesh in meshes:
        for use_kernel, ce in cells:
            fn = batching.autobatch(
                prog, backend="pc", max_depth=64, max_steps=200_000,
                schedule=schedule, fuse=fuse, mesh=mesh,
                use_kernel=use_kernel, compact_every=ce,
            )
            tag = (f"pc[{schedule},fuse={fuse},mesh={mesh},"
                   f"kernel={use_kernel},compact={ce}]")
            np.testing.assert_array_equal(
                np.asarray(fn(n, x)["out"]), base,
                err_msg=f"{tag} != uncompacted baseline",
            )
            assert int(fn.last_result.steps) == base_steps, (
                f"{tag}: step count {int(fn.last_result.steps)} != "
                f"baseline {base_steps} — the dispatch sequence drifted"
            )


@pytest.mark.parametrize(
    "schedule,fuse",
    [
        ("earliest", True),
        ("popular", True),
        ("sweep", True),
        ("lookahead", True),
        ("popular", False),
    ],
)
def test_trace_is_bitexact_neutral(schedule, fuse):
    """The ISSUE 9 tentpole contract: ``trace=`` recording is strictly
    write-only.  For every schedule x fuse x mesh x compact_every x
    use_kernel cell, outputs, VM step count AND per-lane fault codes must
    be identical with tracing on (any ring capacity) and off — the ring
    buffer rides along in loop state but never feeds a dispatch choice,
    a mask, or a lane update."""
    import jax

    rng = np.random.default_rng(23)
    prog = _Gen(rng).build()
    pairs = [(int(rng.integers(0, 5)), int(rng.integers(-50, 51)))
             for _ in range(8)]
    n = np.array([i[0] for i in pairs], np.int32)
    x = np.array([i[1] for i in pairs], np.int32)
    base_fn = batching.autobatch(
        prog, backend="pc", max_depth=64, max_steps=200_000,
        schedule=schedule, fuse=fuse,
    )
    base = np.asarray(base_fn(n, x)["out"])
    base_steps = int(base_fn.last_result.steps)
    base_faults = np.asarray(base_fn.last_result.fault_code)
    meshes = [None] + ([2] if jax.device_count() >= 2 else [])
    # trace=16 overflows the ring on these programs (hundreds of
    # dispatches), proving overflow handling is neutral too; the
    # use_kernel cell is pallas-interpret on CPU (slow) so only the
    # earliest arm carries it.
    cells = [(True, None, False), (16, None, False), (True, 7, False)]
    if schedule == "earliest":
        cells.append((True, None, True))
    for mesh in meshes:
        for trace, ce, use_kernel in cells:
            fn = batching.autobatch(
                prog, backend="pc", max_depth=64, max_steps=200_000,
                schedule=schedule, fuse=fuse, mesh=mesh,
                compact_every=ce, use_kernel=use_kernel, trace=trace,
            )
            tag = (f"pc[{schedule},fuse={fuse},mesh={mesh},"
                   f"compact={ce},kernel={use_kernel},trace={trace}]")
            np.testing.assert_array_equal(
                np.asarray(fn(n, x)["out"]), base,
                err_msg=f"{tag} != untraced baseline",
            )
            res = fn.last_result
            assert int(res.steps) == base_steps, (
                f"{tag}: step count {int(res.steps)} != baseline "
                f"{base_steps} — tracing changed the dispatch sequence"
            )
            np.testing.assert_array_equal(
                np.asarray(res.fault_code), base_faults,
                err_msg=f"{tag}: fault codes != untraced baseline",
            )
            tr = fn.last_trace
            assert tr is not None and tr.total_dispatches == base_steps
            assert len(tr) == min(base_steps,
                                  16 if trace == 16 else len(tr))
            assert tr.dropped == tr.total_dispatches - len(tr)


@pytest.mark.parametrize("seg", [3, 16])
def test_compaction_segmented_quarantine_matches_uncompacted(seg):
    """Compaction under the full serving stack of knobs: segmented
    (Stepper) execution, ``on_fault="quarantine"`` with real overflow
    faults, mesh sharding and the Pallas kernel.  Outputs, per-lane fault
    codes, halt flags and step counts must all match the uncompacted
    single-shot run in the caller's lane order."""
    import jax

    prog = _deep_program()
    # depths 9/0/1/8 against max_depth=4: lanes 0 and 3 overflow-fault,
    # lanes 1 and 2 stay healthy.
    n = np.array([9, 0, 1, 8], np.int32)
    base_fn = batching.autobatch(
        prog, backend="pc", max_depth=4, on_fault="quarantine",
    )
    base = np.asarray(base_fn(n)["out"])
    base_res = base_fn.last_result
    base_steps = int(base_res.steps)
    base_faults = np.asarray(base_res.fault_code)
    np.testing.assert_array_equal(base_faults != 0, [True, False, False, True])
    base_st = base_fn.stepper(n)
    base_state = base_st.init()
    while not base_st.done(base_state):
        base_state = base_st.step(base_state, seg)
    base_done = np.asarray(base_st.lane_done(base_state))
    meshes = [None] + ([2] if jax.device_count() >= 2 else [])
    for mesh in meshes:
        for use_kernel in (False, True):
            fn = batching.autobatch(
                prog, backend="pc", max_depth=4, on_fault="quarantine",
                mesh=mesh, use_kernel=use_kernel, compact_every=1,
            )
            st_ = fn.stepper(n)
            state = st_.init()
            while not st_.done(state):
                state = st_.step(state, seg)
            tag = f"pc[quarantine,mesh={mesh},kernel={use_kernel},seg={seg}]"
            np.testing.assert_array_equal(
                np.asarray(st_.result(state)["out"]), base,
                err_msg=f"{tag} outputs != uncompacted",
            )
            np.testing.assert_array_equal(
                np.asarray(st_.fault_code(state)), base_faults,
                err_msg=f"{tag} fault codes != uncompacted",
            )
            np.testing.assert_array_equal(
                np.asarray(st_.lane_done(state)), base_done,
                err_msg=f"{tag} halt flags in wrong lane order",
            )
            assert st_.steps(state) == base_steps, tag


@pytest.mark.parametrize(
    "schedule,fuse",
    [
        ("earliest", True),
        ("lookahead", True),
        ("sweep", True),
        ("popular", False),
    ],
)
def test_pgo_matrix_matches_unoptimized(schedule, fuse):
    """The ISSUE 10 tentpole contract: re-lowering through the
    profile-guided pipeline (trace-driven superblocks, hot-state layout
    packing, frequency block reordering) is a pure optimization.  For
    every mesh x compact_every x use_kernel cell — and the segmented
    Stepper — outputs and per-lane fault codes must be bit-exact with the
    un-optimized run, and the dispatch count must agree across every PGO
    cell (the optimized program is one program; only its schedule-free
    semantics are shared with the baseline)."""
    import jax

    from repro.obs import block_profile

    rng = np.random.default_rng(31)
    prog = _Gen(rng).build()
    pairs = [(int(rng.integers(0, 5)), int(rng.integers(-50, 51)))
             for _ in range(8)]
    n = np.array([i[0] for i in pairs], np.int32)
    x = np.array([i[1] for i in pairs], np.int32)
    base_fn = batching.autobatch(
        prog, backend="pc", max_depth=64, max_steps=200_000,
        schedule=schedule, fuse=fuse, trace=True,
    )
    base = np.asarray(base_fn(n, x)["out"])
    base_faults = np.asarray(base_fn.last_result.fault_code)
    prof = block_profile(base_fn.last_trace)
    meshes = [None] + ([2] if jax.device_count() >= 2 else [])
    # The use_kernel cell is pallas-interpret on CPU (slow), so only the
    # earliest arm carries it; every arm runs the compaction cells.
    cells = [(None, False), (1, False)]
    if schedule == "earliest":
        cells.append((None, True))
    pgo_steps = None
    for mesh in meshes:
        for ce, use_kernel in cells:
            fn = batching.autobatch(
                prog, backend="pc", max_depth=64, max_steps=200_000,
                schedule=schedule, fuse=fuse, mesh=mesh,
                compact_every=ce, use_kernel=use_kernel,
                verify=True, pgo=prof,
            )
            tag = (f"pgo[{schedule},fuse={fuse},mesh={mesh},"
                   f"compact={ce},kernel={use_kernel}]")
            np.testing.assert_array_equal(
                np.asarray(fn(n, x)["out"]), base,
                err_msg=f"{tag} outputs != un-optimized baseline",
            )
            res = fn.last_result
            np.testing.assert_array_equal(
                np.asarray(res.fault_code), base_faults,
                err_msg=f"{tag} fault codes != un-optimized baseline",
            )
            if pgo_steps is None:
                pgo_steps = int(res.steps)
            assert int(res.steps) == pgo_steps, (
                f"{tag}: step count {int(res.steps)} != other PGO cells "
                f"{pgo_steps} — the optimized dispatch sequence drifted"
            )
    # Segmented execution sees the same packed layout through the Stepper
    # boundary (outputs read tops[packed][:, slot]).
    fn = batching.autobatch(
        prog, backend="pc", max_depth=64, max_steps=200_000,
        schedule=schedule, fuse=fuse, verify=True, pgo=prof,
    )
    st_ = fn.stepper(n, x)
    state = st_.init()
    budget = 0
    while not st_.done(state):
        state = st_.step(state, 5)
        budget += 1
        assert budget < 200_000
    np.testing.assert_array_equal(
        np.asarray(st_.result(state)["out"]), base,
        err_msg=f"pgo[{schedule},fuse={fuse},seg=5] != baseline",
    )
    assert st_.steps(state) == pgo_steps
    np.testing.assert_array_equal(
        np.asarray(st_.lane_done(state)), np.ones(len(n), bool),
    )


def _deep_program():
    """Unbounded-depth recursion: overflows any small max_depth for n>=d."""
    pb = frontend.ProgramBuilder()
    fb = pb.function("deep", ["n"], ["out"], {"n": I32}, {"out": I32})
    c = fb.prim(lambda n: n <= 0, ["n"], name="base")
    with fb.if_(c):
        fb.copy("n", out="out")
        fb.return_()
    t = fb.prim(lambda n: n - 1, ["n"], name="dec")
    fb.assign("out", lambda r: r, [fb.call("deep", [t])])
    fb.return_()
    pb.add(fb)
    return pb.build()


@pytest.mark.parametrize("mesh", [None, 2])
def test_depth_exceeded_flags_under_mesh(mesh):
    """Per-member overflow flags are reported identically sharded and
    unsharded (contained semantics of the legacy shim): exactly the
    members whose recursion exceeds max_depth are flagged, and the
    non-overflowing members' results stay exact."""
    import jax

    if mesh and jax.device_count() < mesh:
        pytest.skip("needs >= 2 devices (see tests/conftest.py)")
    prog = _deep_program()
    n = np.array([9, 0, 1, 8], np.int32)  # depth 9/0/1/8 vs max_depth=4
    with pytest.warns(DeprecationWarning):
        bp = api.autobatch(prog, 4, backend="pc", max_depth=4, mesh=mesh)
    out = bp({"n": n})
    flags = np.asarray(bp.last_result.depth_exceeded)
    np.testing.assert_array_equal(flags, [True, False, False, True])
    np.testing.assert_array_equal(np.asarray(out["out"])[~flags], [0, 0])


@pytest.mark.parametrize("mesh", [None, 2])
def test_stack_overflow_raised_from_segmented_run(mesh):
    """StackOverflow reporting survives mesh sharding on the segmented
    path too: stepper.result() raises with max_depth guidance while the
    per-lane flags stay inspectable via stepper.depth_exceeded()."""
    import jax

    from repro.core import pc_vm

    if mesh and jax.device_count() < mesh:
        pytest.skip("needs >= 2 devices (see tests/conftest.py)")
    fn = batching.autobatch(
        _deep_program(), backend="pc", max_depth=4, mesh=mesh
    )
    n = np.array([9, 0], np.int32)
    st_ = fn.stepper(n)
    state = st_.init()
    while not st_.done(state):
        state = st_.step(state, 16)
    flags = np.asarray(st_.depth_exceeded(state))
    np.testing.assert_array_equal(flags, [True, False])
    with pytest.raises(pc_vm.StackOverflow, match="max_depth"):
        st_.result(state)
    with pytest.raises(pc_vm.StackOverflow, match="max_depth"):
        fn(n)


@settings(max_examples=15, deadline=None)
@given(
    n=st.lists(st.integers(0, 11), min_size=1, max_size=8),
)
def test_fib_any_batch(n):
    from tests.test_core import build_fib, FIB

    prog = build_fib()
    arr = np.array(n, np.int32)
    out = api.autobatch(prog, len(n), backend="pc", max_depth=20)({"n": arr})
    np.testing.assert_array_equal(np.asarray(out["out"]), FIB[arr])


@settings(max_examples=10, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(1, 500), st.integers(1, 500)),
        min_size=1,
        max_size=8,
    )
)
def test_gcd_property(pairs):
    """gcd via Euclid's loop: result divides both inputs; matches math.gcd."""
    import math

    pb = frontend.ProgramBuilder()
    fb = pb.function(
        "gcd", ["a", "b"], ["out"], {"a": I32, "b": I32}, {"out": I32}
    )
    with fb.while_(lambda b: b > 0, ["b"]):
        fb.copy("b", out="t")
        fb.assign("b", lambda a, b: a % b, ["a", "b"])
        fb.copy("t", out="a")
    fb.copy("a", out="out")
    fb.return_()
    pb.add(fb)
    prog = pb.build()
    a = np.array([p[0] for p in pairs], np.int32)
    b = np.array([p[1] for p in pairs], np.int32)
    out = api.autobatch(prog, len(pairs), backend="pc")({"a": a, "b": b})
    expect = np.array([math.gcd(int(x), int(y)) for x, y in pairs], np.int32)
    np.testing.assert_array_equal(np.asarray(out["out"]), expect)
