"""Extra serving-engine and VM edge-case coverage."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core import api, frontend
from repro.core.frontend import I32
from repro.models import get_model
from repro.serve.engine import EngineConfig, GenerationEngine


class TestTemperatureSampling:
    def test_temperature_engine_runs_and_differs_across_lanes(self):
        """Stochastic sampling: per-lane PRNG keys give diverse outputs,
        all tokens in-vocab, lengths respected."""
        cfg = configs.get_smoke_config("smollm-135m")
        m = get_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        ecfg = EngineConfig(
            lanes=4, max_context=32, max_prompt_len=4, max_new_tokens=12,
            requests_per_lane=1, eos_id=0, temperature=0.8, backend="pc",
        )
        eng = GenerationEngine(m, params, ecfg)
        prompts = np.full((4, 1, 4), 7, np.int32)  # identical prompts
        plens = np.full((4, 1), 4, np.int32)
        res = eng.generate(prompts, plens, seed=3)
        toks = res["tokens"][:, 0]
        assert np.all((toks >= 0) & (toks < cfg.vocab_size))
        # identical prompts but different lane keys -> diverse samples
        assert not all(
            np.array_equal(toks[0], toks[i]) for i in range(1, 4)
        )


class TestVMDepthOverflow:
    def test_push_beyond_max_depth_is_contained(self):
        """Recursion deeper than max_depth must not corrupt other lanes:
        out-of-range pushes are dropped (kernel/ref contract) and the
        shallow lanes still produce exact results."""
        pb = frontend.ProgramBuilder()
        fb = pb.function("depth", ["n"], ["out"], {"n": I32}, {"out": I32})
        c = fb.prim(lambda n: n <= 0, ["n"])
        with fb.if_(c):
            fb.const(0, jnp.int32, out="out")
            fb.return_()
        t = fb.prim(lambda n: n - 1, ["n"])
        fb.call("depth", [t], out="r")
        fb.assign("out", lambda r: r + 1, ["r"])
        fb.return_()
        pb.add(fb)
        prog = pb.build()
        n = np.array([2, 3, 30], np.int32)  # lane 2 exceeds max_depth=8
        bp = api.autobatch(prog, 3, backend="pc", max_depth=8,
                           max_steps=5_000)
        out = np.asarray(bp({"n": n})["out"])
        # shallow lanes exact despite the deep lane's overflow
        assert out[0] == 2 and out[1] == 3
