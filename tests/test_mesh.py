"""Lane sharding (VMConfig.mesh / autobatch(mesh=...)): bit-exactness with
unsharded execution, layout of the sharded state, cache-key isolation,
validation errors, and the AOT path.  The suite runs with 8 forced host
CPU devices (tests/conftest.py)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import api, frontend, ir, lowering, pc_vm
from repro.core.batching import Batched, autobatch
from repro.core.frontend import I32

from tests.test_core import FIB, build_fib

multi_device = pytest.mark.skipif(
    jax.device_count() < 2, reason="needs >= 2 devices (see tests/conftest.py)"
)


# ----------------------------------------------------------------------
# resolve_mesh / mesh_cache_key
# ----------------------------------------------------------------------


class TestResolveMesh:
    def test_none_passthrough(self):
        assert pc_vm.resolve_mesh(None) is None
        assert pc_vm.mesh_cache_key(None) is None

    def test_int_builds_1d_mesh(self):
        m = pc_vm.resolve_mesh(1)
        assert m.axis_names == (pc_vm.LANE_AXIS,)
        assert m.size == 1

    def test_explicit_mesh_passthrough(self):
        m = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("lanes",))
        assert pc_vm.resolve_mesh(m) is m

    def test_2d_mesh_rejected(self):
        devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
        m = jax.sharding.Mesh(devs, ("a", "b"))
        with pytest.raises(ValueError, match="1-D mesh"):
            pc_vm.resolve_mesh(m)

    def test_too_many_devices(self):
        with pytest.raises(ValueError, match="devices"):
            pc_vm.resolve_mesh(jax.device_count() + 1)

    def test_nonpositive(self):
        with pytest.raises(ValueError, match=">= 1"):
            pc_vm.resolve_mesh(0)

    @multi_device
    def test_cache_key_int_and_mesh_agree(self):
        m = jax.sharding.Mesh(np.asarray(jax.devices()[:2]), (pc_vm.LANE_AXIS,))
        assert pc_vm.mesh_cache_key(2) == pc_vm.mesh_cache_key(m)
        assert pc_vm.mesh_cache_key(2) != pc_vm.mesh_cache_key(1)


# ----------------------------------------------------------------------
# VM-level sharded execution
# ----------------------------------------------------------------------


def _fib_inputs(z):
    n = (np.arange(z) % 13).astype(np.int32)
    return n, {ir.qualify("fib", "n"): n}


class TestShardedVM:
    @multi_device
    @pytest.mark.parametrize("schedule", pc_vm.SCHEDULES)
    def test_bit_exact_across_mesh(self, schedule):
        low = lowering.lower(build_fib())
        z = 8
        n, inputs = _fib_inputs(z)
        base = pc_vm.ProgramCounterVM(
            low, pc_vm.VMConfig(batch_size=z, max_depth=24, schedule=schedule)
        ).run(inputs)
        for mesh in (1, 2, jax.device_count()):
            res = pc_vm.ProgramCounterVM(
                low,
                pc_vm.VMConfig(batch_size=z, max_depth=24,
                               schedule=schedule, mesh=mesh),
            ).run(inputs)
            for k in base.outputs:
                np.testing.assert_array_equal(
                    np.asarray(res.outputs[k]), np.asarray(base.outputs[k]),
                    err_msg=f"schedule={schedule} mesh={mesh}",
                )
            assert int(res.steps) == int(base.steps)
            assert res.sched.num_devices == mesh

    @multi_device
    def test_output_is_lane_sharded(self):
        low = lowering.lower(build_fib())
        z = 8
        n, inputs = _fib_inputs(z)
        vm = pc_vm.ProgramCounterVM(
            low, pc_vm.VMConfig(batch_size=z, max_depth=24, mesh=2)
        )
        res = vm.run(inputs)
        (out,) = res.outputs.values()
        assert pc_vm.LANE_AXIS in str(out.sharding.spec)
        np.testing.assert_array_equal(np.asarray(out), FIB[n])

    def test_indivisible_batch_rejected(self):
        low = lowering.lower(build_fib())
        with pytest.raises(ValueError, match="divide"):
            pc_vm.ProgramCounterVM(
                low, pc_vm.VMConfig(batch_size=3, mesh=2)
            )

    @multi_device
    def test_use_kernel_with_mesh_accepted_and_bit_exact(self):
        """use_kernel + mesh now composes (ISSUE 8): the stack kernels run
        shard-locally via ``stack_ops.shard_local``, one pallas_call per
        device slice, and the result must be bit-identical to both the
        plain sharded VM and the unsharded interpreter."""
        low = lowering.lower(build_fib())
        z = 8
        n, inputs = _fib_inputs(z)
        base = pc_vm.ProgramCounterVM(
            low, pc_vm.VMConfig(batch_size=z, max_depth=24)
        ).run(inputs)
        for mesh in (2, jax.device_count()):
            vm = pc_vm.ProgramCounterVM(
                low,
                pc_vm.VMConfig(batch_size=z, max_depth=24, mesh=mesh,
                               use_kernel=True),
            )
            res = vm.run(inputs)
            (out,) = res.outputs.values()
            np.testing.assert_array_equal(np.asarray(out), FIB[n])
            for k in base.outputs:
                np.testing.assert_array_equal(
                    np.asarray(res.outputs[k]),
                    np.asarray(base.outputs[k]),
                    err_msg=f"mesh={mesh} use_kernel=True",
                )
            assert int(res.steps) == int(base.steps)
            # still lane-sharded on the way out — the kernel path must not
            # have collapsed the layout
            assert pc_vm.LANE_AXIS in str(out.sharding.spec)

    @multi_device
    def test_staged_donation_path_matches_run(self):
        """run() takes the composed program on CPU (no donation there);
        the staged init/donated-loop pair used on accelerators must stay
        equivalent, so exercise it explicitly."""
        low = lowering.lower(build_fib())
        z = 8
        n, inputs = _fib_inputs(z)
        vm = pc_vm.ProgramCounterVM(
            low, pc_vm.VMConfig(batch_size=z, max_depth=24, mesh=2)
        )
        staged = vm._result(vm._jitted_loop(vm._jitted_start(inputs)))
        np.testing.assert_array_equal(
            np.asarray(list(staged.outputs.values())[0]), FIB[n]
        )
        assert bool(staged.converged)


# ----------------------------------------------------------------------
# Pytree API plumbing
# ----------------------------------------------------------------------


class TestAutobatchMesh:
    @multi_device
    def test_decorator_mesh_matches_unsharded(self):
        @autobatch(in_specs=(Batched(I32),), out_spec=I32, max_depth=24)
        def fib(n):
            if n < 2:
                return n
            return fib(n - 1) + fib(n - 2)

        sharded = autobatch(fib.program, max_depth=24, mesh=2)
        n = (np.arange(8) % 12).astype(np.int32)
        np.testing.assert_array_equal(
            np.asarray(sharded(n)["out"]), np.asarray(fib(n))
        )
        assert sharded.last_result.sched.num_devices == 2

    @multi_device
    def test_mesh_in_cache_key(self):
        pb = frontend.ProgramBuilder()
        fb = pb.function("double", ["x"], ["out"], {"x": I32}, {"out": I32})
        fb.assign("out", lambda x: 2 * x, ["x"])
        fb.return_()
        pb.add(fb)
        f_plain = autobatch(pb.build())
        f_mesh = autobatch(pb.build(), mesh=2)
        x = np.arange(4, dtype=np.int32)
        assert f_plain._aval_key({"x": x}, 4) != f_mesh._aval_key({"x": x}, 4)
        np.testing.assert_array_equal(
            np.asarray(f_mesh(x)["out"]), np.asarray(f_plain(x)["out"])
        )

    @multi_device
    def test_shared_args_and_pytree_outputs(self):
        from repro.core.batching import Shared

        pb = frontend.ProgramBuilder()
        fb = pb.function(
            "clampsum", ["x", "cap"], ["tot"],
            {"x": I32, "cap": I32}, {"tot": I32},
        )
        fb.const(0, jnp.int32, out="tot")
        with fb.while_(lambda x: x > 0, ["x"]):
            fb.assign("tot", lambda t, x, c: jnp.minimum(t + x, c),
                      ["tot", "x", "cap"])
            fb.assign("x", lambda x: x - 1, ["x"])
        fb.return_()
        pb.add(fb)
        kern = autobatch(
            pb, in_specs=(Batched(I32), Shared(I32)), mesh=2
        )
        ref = autobatch(pb, in_specs=(Batched(I32), Shared(I32)))
        x = np.array([0, 3, 7, 2], np.int32)
        np.testing.assert_array_equal(
            np.asarray(kern(x, np.int32(9))["tot"]),
            np.asarray(ref(x, np.int32(9))["tot"]),
        )

    @multi_device
    def test_aot_lower_and_cost_analysis(self):
        @autobatch(in_specs=(Batched(I32),), out_spec=I32, max_depth=16,
                   mesh=2)
        def tri(n):
            if n < 1:
                return n
            return n + tri(n - 1)

        handle = tri.lower(np.arange(4, dtype=np.int32))
        text = handle.as_text()
        assert "while" in text
        cost = handle.cost_analysis()
        assert isinstance(cost, dict)

    @multi_device
    def test_legacy_api_shim_passes_mesh(self):
        prog = build_fib()
        n = np.array([5, 9, 2, 11], np.int32)
        with pytest.warns(DeprecationWarning):
            got = api.autobatch(prog, 4, max_depth=24, mesh=2)({"n": n})
        np.testing.assert_array_equal(np.asarray(got["out"]), FIB[n])

    @multi_device
    def test_stack_overflow_still_raised_sharded(self):
        @autobatch(in_specs=(Batched(I32),), out_spec=I32, max_depth=4,
                   mesh=2)
        def deep(n):
            if n < 1:
                return n
            return deep(n - 1)

        with pytest.raises(pc_vm.StackOverflow, match="max_depth"):
            deep(np.array([9, 0], np.int32))
