"""Pallas kernel validation (interpret mode on CPU): shape/dtype sweeps
asserting allclose against the pure-jnp oracles, plus hypothesis
property tests for the stack kernels (the paper's hot spot)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.flash_decode import ops as fd_ops
from repro.kernels.flash_decode import ref as fd_ref
from repro.kernels.stack_ops import ops as sk_ops
from repro.kernels.stack_ops import ref as sk_ref


class TestStackOps:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32, jnp.bfloat16])
    @pytest.mark.parametrize("feat", [(), (7,), (3, 5)])
    def test_push_peek_sweep(self, dtype, feat):
        rng = np.random.default_rng(0)
        d, z = 6, 9
        stack = jnp.asarray(
            rng.normal(size=(d, z) + feat) * 10, dtype
        )
        val = jnp.asarray(rng.normal(size=(z,) + feat) * 10, dtype)
        ptr = jnp.asarray(rng.integers(0, d, z), jnp.int32)
        mask = jnp.asarray(rng.integers(0, 2, z).astype(bool))
        np.testing.assert_array_equal(
            np.asarray(sk_ops.masked_push(stack, ptr, val, mask)),
            np.asarray(sk_ref.masked_push(stack, ptr, val, mask)),
        )
        np.testing.assert_array_equal(
            np.asarray(sk_ops.masked_peek(stack, ptr)),
            np.asarray(sk_ref.masked_peek(stack, ptr)),
        )

    def test_out_of_range_ptr_dropped(self):
        stack = jnp.zeros((4, 3, 2), jnp.float32)
        val = jnp.ones((3, 2), jnp.float32)
        ptr = jnp.asarray([0, 7, -1], jnp.int32)  # 7, -1 out of range
        mask = jnp.asarray([True, True, True])
        out = sk_ops.masked_push(stack, ptr, val, mask)
        refo = sk_ref.masked_push(stack, ptr, val, mask)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(refo))
        assert float(out[:, 1:].sum()) == 0.0  # nothing written for lanes 1,2

    @settings(max_examples=25, deadline=None)
    @given(
        d=st.integers(1, 8),
        z=st.integers(1, 12),
        f=st.integers(1, 9),
        seed=st.integers(0, 2**16),
    )
    def test_property_push_then_peek_roundtrip(self, d, z, f, seed):
        """For active lanes, peek(push(stack, ptr, v), ptr) == v; inactive
        lanes and untouched depths are unchanged — the VM's invariant."""
        rng = np.random.default_rng(seed)
        stack = jnp.asarray(rng.normal(size=(d, z, f)), jnp.float32)
        val = jnp.asarray(rng.normal(size=(z, f)), jnp.float32)
        ptr = jnp.asarray(rng.integers(0, d, z), jnp.int32)
        mask = jnp.asarray(rng.integers(0, 2, z).astype(bool))
        pushed = sk_ops.masked_push(stack, ptr, val, mask)
        peeked = sk_ops.masked_peek(pushed, ptr)
        m = np.asarray(mask)
        np.testing.assert_allclose(
            np.asarray(peeked)[m], np.asarray(val)[m], rtol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(peeked)[~m],
            np.asarray(sk_ref.masked_peek(stack, ptr))[~m], rtol=1e-6,
        )
        # untouched depths identical
        o, s = np.asarray(pushed), np.asarray(stack)
        for lane in range(z):
            rows = np.ones(d, bool)
            if m[lane]:
                rows[int(ptr[lane])] = False
            np.testing.assert_array_equal(o[rows, lane], s[rows, lane])


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,s,h,hk,dh", [
            (2, 64, 4, 2, 16),
            (1, 128, 8, 8, 32),
            (2, 32, 4, 1, 64),
            (1, 256, 2, 2, 128),
        ],
    )
    def test_causal_sweep(self, b, s, h, hk, dh):
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, hk, dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, s, hk, dh)), jnp.float32)
        out = fa_ops.flash_attention(q, k, v, causal=True,
                                     block_q=32, block_k=32)
        exp = fa_ref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5
        )

    def test_noncausal(self):
        rng = np.random.default_rng(2)
        q = jnp.asarray(rng.normal(size=(1, 64, 4, 16)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 64, 4, 16)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 64, 4, 16)), jnp.float32)
        out = fa_ops.flash_attention(q, k, v, causal=False,
                                     block_q=32, block_k=32)
        exp = fa_ref.attention(q, k, v, causal=False)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5
        )

    def test_bf16_inputs(self):
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.normal(size=(1, 64, 4, 32)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
        out = fa_ops.flash_attention(q, k, v, block_q=32, block_k=32)
        exp = fa_ref.attention(q, k, v)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(exp, np.float32),
            rtol=3e-2, atol=3e-2,
        )

    def test_block_shape_independence(self):
        """Different VMEM tilings must give identical results."""
        rng = np.random.default_rng(4)
        q = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
        o1 = fa_ops.flash_attention(q, k, v, block_q=32, block_k=64)
        o2 = fa_ops.flash_attention(q, k, v, block_q=128, block_k=16)
        np.testing.assert_allclose(
            np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-6
        )


class TestFlashDecode:
    @pytest.mark.parametrize(
        "b,w,h,hk,dh", [
            (2, 128, 4, 2, 16),
            (4, 256, 8, 1, 32),
            (1, 512, 4, 4, 64),
        ],
    )
    def test_decode_sweep(self, b, w, h, hk, dh):
        rng = np.random.default_rng(5)
        q = jnp.asarray(rng.normal(size=(b, h, dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, w, hk, dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, w, hk, dh)), jnp.float32)
        count = jnp.asarray(rng.integers(1, w + 1, b), jnp.int32)
        out = fd_ops.decode_attention(q, k, v, count, block_k=64)
        exp = fd_ref.decode_attention(q, k, v, count)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5
        )

    def test_single_valid_entry(self):
        """count=1: attention collapses onto the first cache row."""
        rng = np.random.default_rng(6)
        b, w, hk, dh = 2, 64, 2, 16
        q = jnp.asarray(rng.normal(size=(b, 4, dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, w, hk, dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, w, hk, dh)), jnp.float32)
        count = jnp.ones((b,), jnp.int32)
        out = fd_ops.decode_attention(q, k, v, count, block_k=32)
        expect = jnp.repeat(v[:, 0], 2, axis=1).reshape(b, 4, dh)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-6
        )

    def test_matches_model_ring_cache_semantics(self):
        """The kernel's (count)-masked attention equals the model layer's
        ring-buffer decode validity rule while the cache is filling."""
        from repro.models import layers as L
        from repro import configs

        cfg = configs.get_smoke_config("qwen3-0.6b")
        # no rope: compare the raw masked-softmax core only
        rng = np.random.default_rng(7)
        b, w = 2, 32
        hk, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        h = cfg.num_heads
        q = jnp.asarray(rng.normal(size=(b, h, dh)), jnp.float32)
        kc = jnp.asarray(rng.normal(size=(b, w, hk, dh)), jnp.float32)
        vc = jnp.asarray(rng.normal(size=(b, w, hk, dh)), jnp.float32)
        pos = jnp.asarray([5, 20], jnp.int32)
        count = jnp.minimum(pos + 1, w)
        out = fd_ops.decode_attention(q, kc, vc, count, block_k=16)
        exp = fd_ref.decode_attention(q, kc, vc, count)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-5, atol=1e-6)
