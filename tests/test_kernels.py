"""Pallas kernel validation (interpret mode on CPU): shape/dtype sweeps
asserting allclose against the pure-jnp oracles, plus hypothesis
property tests for the stack kernels (the paper's hot spot)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property tests skip; deterministic ones still run
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="needs hypothesis (pip install -r "
                "requirements-dev.txt)"
            )(fn)
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _StrategyStub:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.flash_decode import ops as fd_ops
from repro.kernels.flash_decode import ref as fd_ref
from repro.kernels.stack_ops import ops as sk_ops
from repro.kernels.stack_ops import ref as sk_ref


class TestStackOps:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32, jnp.bfloat16])
    @pytest.mark.parametrize("feat", [(), (7,), (3, 5)])
    def test_push_peek_sweep(self, dtype, feat):
        rng = np.random.default_rng(0)
        d, z = 6, 9
        stack = jnp.asarray(
            rng.normal(size=(d, z) + feat) * 10, dtype
        )
        val = jnp.asarray(rng.normal(size=(z,) + feat) * 10, dtype)
        ptr = jnp.asarray(rng.integers(0, d, z), jnp.int32)
        mask = jnp.asarray(rng.integers(0, 2, z).astype(bool))
        np.testing.assert_array_equal(
            np.asarray(sk_ops.masked_push(stack, ptr, val, mask)),
            np.asarray(sk_ref.masked_push(stack, ptr, val, mask)),
        )
        np.testing.assert_array_equal(
            np.asarray(sk_ops.masked_peek(stack, ptr)),
            np.asarray(sk_ref.masked_peek(stack, ptr)),
        )

    def test_out_of_range_ptr_dropped(self):
        stack = jnp.zeros((4, 3, 2), jnp.float32)
        val = jnp.ones((3, 2), jnp.float32)
        ptr = jnp.asarray([0, 7, -1], jnp.int32)  # 7, -1 out of range
        mask = jnp.asarray([True, True, True])
        out = sk_ops.masked_push(stack, ptr, val, mask)
        refo = sk_ref.masked_push(stack, ptr, val, mask)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(refo))
        assert float(out[:, 1:].sum()) == 0.0  # nothing written for lanes 1,2

    @settings(max_examples=25, deadline=None)
    @given(
        d=st.integers(1, 8),
        z=st.integers(1, 12),
        f=st.integers(1, 9),
        seed=st.integers(0, 2**16),
    )
    def test_property_push_then_peek_roundtrip(self, d, z, f, seed):
        """For active lanes, peek(push(stack, ptr, v), ptr) == v; inactive
        lanes and untouched depths are unchanged — the VM's invariant."""
        rng = np.random.default_rng(seed)
        stack = jnp.asarray(rng.normal(size=(d, z, f)), jnp.float32)
        val = jnp.asarray(rng.normal(size=(z, f)), jnp.float32)
        ptr = jnp.asarray(rng.integers(0, d, z), jnp.int32)
        mask = jnp.asarray(rng.integers(0, 2, z).astype(bool))
        pushed = sk_ops.masked_push(stack, ptr, val, mask)
        peeked = sk_ops.masked_peek(pushed, ptr)
        m = np.asarray(mask)
        np.testing.assert_allclose(
            np.asarray(peeked)[m], np.asarray(val)[m], rtol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(peeked)[~m],
            np.asarray(sk_ref.masked_peek(stack, ptr))[~m], rtol=1e-6,
        )
        # untouched depths identical
        o, s = np.asarray(pushed), np.asarray(stack)
        for lane in range(z):
            rows = np.ones(d, bool)
            if m[lane]:
                rows[int(ptr[lane])] = False
            np.testing.assert_array_equal(o[rows, lane], s[rows, lane])


class TestShardedStackOps:
    """Kernel-vs-ref parity for the shard-local stack fast path (ISSUE 8):
    ``stack_ops.shard_local(mesh)`` wraps the Pallas kernels in a
    ``shard_map`` over the lane axis, so each device runs the kernel on
    its lane slice with zero cross-device traffic.  Results must be
    bit-identical to the unsharded pure-jnp reference on the full array,
    including the depth-overflow edge the VM leans on."""

    def _mesh(self):
        from jax.sharding import Mesh

        devs = jax.devices()
        if len(devs) < 2:
            pytest.skip("sharded stack-op parity needs >= 2 devices")
        return Mesh(np.array(devs), ("lanes",))

    def _place(self, mesh, stack, ptr, val, mask):
        from repro.launch.sharding import lane_shardings

        lane, stk, _ = lane_shardings(mesh)
        return (
            jax.device_put(stack, stk),
            jax.device_put(ptr, lane),
            jax.device_put(val, lane),
            jax.device_put(mask, lane),
        )

    @pytest.mark.parametrize("feat", [(), (3,), (2, 5)])
    def test_sharded_push_peek_matches_ref(self, feat):
        mesh = self._mesh()
        rng = np.random.default_rng(21)
        d, z = 6, 2 * len(mesh.devices.ravel())
        stack = jnp.asarray(rng.normal(size=(d, z) + feat) * 10, jnp.float32)
        val = jnp.asarray(rng.normal(size=(z,) + feat) * 10, jnp.float32)
        ptr = jnp.asarray(rng.integers(0, d, z), jnp.int32)
        mask = jnp.asarray(rng.integers(0, 2, z).astype(bool))
        push, peek = sk_ops.shard_local(mesh)
        s_stack, s_ptr, s_val, s_mask = self._place(mesh, stack, ptr, val,
                                                    mask)
        pushed = push(s_stack, s_ptr, s_val, s_mask)
        np.testing.assert_array_equal(
            np.asarray(pushed),
            np.asarray(sk_ref.masked_push(stack, ptr, val, mask)),
        )
        np.testing.assert_array_equal(
            np.asarray(peek(s_stack, s_ptr)),
            np.asarray(sk_ref.masked_peek(stack, ptr)),
        )
        # the stack layout survives the round trip: still lane-sharded on
        # axis 1, so the VM can chain pushes without a reshard
        assert pushed.sharding.is_equivalent_to(s_stack.sharding, pushed.ndim)

    def test_sharded_overflow_ptr_dropped(self):
        """The depth-overflow edge: out-of-range pointers (the lane just
        blew ``max_depth``, or is parked at ptr -1) must write nothing,
        exactly like the reference — per device slice."""
        mesh = self._mesh()
        ndev = len(mesh.devices.ravel())
        d, z = 4, 2 * ndev
        stack = jnp.zeros((d, z, 2), jnp.float32)
        val = jnp.ones((z, 2), jnp.float32)
        # every device slice holds one in-range and one OOB lane
        ptr = jnp.asarray([0, d + 3] * ndev, jnp.int32)
        mask = jnp.ones((z,), bool)
        push, _ = sk_ops.shard_local(mesh)
        s_stack, s_ptr, s_val, s_mask = self._place(mesh, stack, ptr, val,
                                                    mask)
        out = np.asarray(push(s_stack, s_ptr, s_val, s_mask))
        np.testing.assert_array_equal(
            out, np.asarray(sk_ref.masked_push(stack, ptr, val, mask))
        )
        assert out[:, 1::2].sum() == 0.0  # OOB lanes wrote nothing
        assert (out[0, 0::2] == 1.0).all()

    def test_shard_local_is_cached_per_mesh(self):
        """One shard_map trace per mesh: the VM calls this in every block
        body, so repeated lookups must be the identical callables."""
        mesh = self._mesh()
        assert sk_ops.shard_local(mesh) is sk_ops.shard_local(mesh)


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,s,h,hk,dh", [
            (2, 64, 4, 2, 16),
            (1, 128, 8, 8, 32),
            (2, 32, 4, 1, 64),
            (1, 256, 2, 2, 128),
        ],
    )
    def test_causal_sweep(self, b, s, h, hk, dh):
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, hk, dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, s, hk, dh)), jnp.float32)
        out = fa_ops.flash_attention(q, k, v, causal=True,
                                     block_q=32, block_k=32)
        exp = fa_ref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5
        )

    def test_noncausal(self):
        rng = np.random.default_rng(2)
        q = jnp.asarray(rng.normal(size=(1, 64, 4, 16)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 64, 4, 16)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 64, 4, 16)), jnp.float32)
        out = fa_ops.flash_attention(q, k, v, causal=False,
                                     block_q=32, block_k=32)
        exp = fa_ref.attention(q, k, v, causal=False)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5
        )

    def test_bf16_inputs(self):
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.normal(size=(1, 64, 4, 32)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
        out = fa_ops.flash_attention(q, k, v, block_q=32, block_k=32)
        exp = fa_ref.attention(q, k, v)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(exp, np.float32),
            rtol=3e-2, atol=3e-2,
        )

    def test_block_shape_independence(self):
        """Different VMEM tilings must give identical results."""
        rng = np.random.default_rng(4)
        q = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
        o1 = fa_ops.flash_attention(q, k, v, block_q=32, block_k=64)
        o2 = fa_ops.flash_attention(q, k, v, block_q=128, block_k=16)
        np.testing.assert_allclose(
            np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-6
        )


class TestFlashDecode:
    @pytest.mark.parametrize(
        "b,w,h,hk,dh", [
            (2, 128, 4, 2, 16),
            (4, 256, 8, 1, 32),
            (1, 512, 4, 4, 64),
        ],
    )
    def test_decode_sweep(self, b, w, h, hk, dh):
        rng = np.random.default_rng(5)
        q = jnp.asarray(rng.normal(size=(b, h, dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, w, hk, dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, w, hk, dh)), jnp.float32)
        count = jnp.asarray(rng.integers(1, w + 1, b), jnp.int32)
        out = fd_ops.decode_attention(q, k, v, count, block_k=64)
        exp = fd_ref.decode_attention(q, k, v, count)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exp), rtol=2e-5, atol=2e-5
        )

    def test_single_valid_entry(self):
        """count=1: attention collapses onto the first cache row."""
        rng = np.random.default_rng(6)
        b, w, hk, dh = 2, 64, 2, 16
        q = jnp.asarray(rng.normal(size=(b, 4, dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, w, hk, dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, w, hk, dh)), jnp.float32)
        count = jnp.ones((b,), jnp.int32)
        out = fd_ops.decode_attention(q, k, v, count, block_k=32)
        expect = jnp.repeat(v[:, 0], 2, axis=1).reshape(b, 4, dh)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-6
        )

    def test_matches_model_ring_cache_semantics(self):
        """The kernel's (count)-masked attention equals the model layer's
        ring-buffer decode validity rule while the cache is filling."""
        from repro.models import layers as L
        from repro import configs

        cfg = configs.get_smoke_config("qwen3-0.6b")
        # no rope: compare the raw masked-softmax core only
        rng = np.random.default_rng(7)
        b, w = 2, 32
        hk, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        h = cfg.num_heads
        q = jnp.asarray(rng.normal(size=(b, h, dh)), jnp.float32)
        kc = jnp.asarray(rng.normal(size=(b, w, hk, dh)), jnp.float32)
        vc = jnp.asarray(rng.normal(size=(b, w, hk, dh)), jnp.float32)
        pos = jnp.asarray([5, 20], jnp.int32)
        count = jnp.minimum(pos + 1, w)
        out = fd_ops.decode_attention(q, kc, vc, count, block_k=16)
        exp = fd_ref.decode_attention(q, kc, vc, count)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-5, atol=1e-6)
