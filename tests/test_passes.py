"""Tests for the pass pipeline, DCE, and static stack-depth bounding.

Covers core/passes.py (Pass protocol, PassPipeline between-pass
verification + debug pinpointing, DeadCodeElimination) and the
interprocedural depth analysis surfaced through
``batching.autobatch(max_depth=None)`` / ``fn.diagnostics()``.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    analysis,
    batching,
    frontend,
    fusion,
    ir,
    lowering,
    passes,
    pc_vm,
)
from repro.core.frontend import F32, I32

from tests.test_core import FIB, build_fib, build_mutual, build_pow_loop


def build_nested():
    """main -> mid -> leaf: non-recursive but two calls deep."""
    pb = frontend.ProgramBuilder()
    leaf = pb.function("leaf", ["n"], ["out"], {"n": I32}, {"out": I32})
    leaf.assign("out", lambda n: n + 1, ["n"])
    leaf.return_()
    pb.add(leaf)
    mid = pb.function("mid", ["n"], ["out"], {"n": I32}, {"out": I32})
    mid.call("leaf", ["n"], out="t")
    mid.assign("out", lambda t: t + 1, ["t"])
    mid.return_()
    pb.add(mid)
    fb = pb.function("top", ["n"], ["out"], {"n": I32}, {"out": I32})
    fb.call("mid", ["n"], out="out")
    fb.return_()
    pb.add(fb)
    return ir.Program(functions=pb.functions, main="top")


def build_with_dead_code():
    """A loop program with a dead value that crosses blocks (so it holds a
    masked VM state buffer, not a block-local temp) and a dead *tagged*
    primitive (which DCE must keep for tag_stats)."""
    pb = frontend.ProgramBuilder()
    fb = pb.function(
        "f", ["x", "k"], ["out"], {"x": F32, "k": I32}, {"out": F32}
    )
    fb.const(1.0, jnp.float32, out="out")
    fb.copy("k", out="i")
    # junk is written before the loop and read inside it: live across a
    # block boundary, hence a state var — but its only consumer is itself
    # dead, so the DCE fixpoint removes both ops and the junk buffer.
    fb.prim(lambda x: x * 17.0, ["x"], out="junk", name="dead_junk")
    with fb.while_(lambda i: i > 0, ["i"]):
        fb.prim(lambda j: j + 1.0, ["junk"], out="junk2",
                name="dead_junk2")
        fb.prim(lambda x: x + 3.0, ["x"], out="probe", name="dead_probe",
                tag="probe")
        fb.assign("out", lambda o, x: o * x, ["out", "x"])
        fb.assign("i", lambda i: i - 1, ["i"])
    fb.return_()
    pb.add(fb)
    return pb.build()


def prim_names(low: ir.LoweredProgram) -> list[str]:
    return [
        op.name
        for blk in low.blocks
        for op in blk.ops
        if isinstance(op, ir.LPrim)
    ]


def break_a_target(low: ir.LoweredProgram) -> ir.LoweredProgram:
    blocks = [
        ir.LBlock(ops=list(b.ops), term=b.term, label=b.label)
        for b in low.blocks
    ]
    blocks[0].term = ir.LJump(999)
    return ir.dataclass_replace(low, blocks=blocks)


class _BreakTargetPass:
    name = "break-target"

    def run(self, lowered):
        return break_a_target(lowered)


class _CrashPass:
    name = "boom-pass"

    def run(self, lowered):
        raise RuntimeError("boom")


class TestPassPipeline:
    def test_builtin_passes_satisfy_protocol(self):
        for p in (*passes.lowering_passes(), *passes.fusion_passes(),
                  passes.DeadCodeElimination()):
            assert isinstance(p, passes.Pass)
            assert isinstance(p.name, str) and p.name

    def test_fusion_pipeline_matches_fuse(self):
        low = lowering.lower(build_fib())
        via_fuse = fusion.fuse(low)
        via_pipe = passes.PassPipeline(passes.fusion_passes()).run(low)
        assert via_pipe.pretty() == via_fuse.pretty()
        assert via_pipe.stack_vars == via_fuse.stack_vars
        assert via_pipe.temp_vars == via_fuse.temp_vars
        assert via_pipe.fused_from == via_fuse.fused_from

    def test_pipeline_does_not_mutate_input(self):
        low = lowering.lower(build_fib())
        before = low.pretty()
        passes.PassPipeline(
            [*passes.fusion_passes(), passes.DeadCodeElimination()]
        ).run(low)
        assert low.pretty() == before

    def test_verifier_names_offending_pass(self):
        low = lowering.lower(build_fib())
        pipe = passes.PassPipeline(
            [passes.JumpChainFusion(), _BreakTargetPass()], verify=True
        )
        with pytest.raises(
            passes.PassError,
            match="pass 'break-target' produced an invalid program: "
            ".*out of range",
        ):
            pipe.run(low)

    def test_debug_mode_dumps_offending_program(self):
        low = lowering.lower(build_fib())
        pipe = passes.PassPipeline(
            [_BreakTargetPass()], verify=True, debug=True
        )
        with pytest.raises(passes.PassError) as exc:
            pipe.run(low)
        assert "--- offending program ---" in str(exc.value)
        assert "jump 999" in str(exc.value)  # the broken terminator

    def test_crashing_pass_is_named(self):
        low = lowering.lower(build_fib())
        pipe = passes.PassPipeline([_CrashPass()])
        with pytest.raises(
            passes.PassError, match="pass 'boom-pass' failed: boom"
        ):
            pipe.run(low)

    def test_invalid_input_rejected_before_any_pass(self):
        bad = break_a_target(lowering.lower(build_fib()))
        pipe = passes.PassPipeline([passes.JumpChainFusion()], verify=True)
        with pytest.raises(
            passes.PassError,
            match=r"input program \(before any pass ran\) produced an "
            "invalid program",
        ):
            pipe.run(bad)

    def test_verify_off_by_default(self):
        # Without verify=, the pipeline is pure transformation — a broken
        # program flows through an empty pipeline untouched.
        bad = break_a_target(lowering.lower(build_fib()))
        assert passes.PassPipeline([]).run(bad) is bad


class TestDeadCodeElimination:
    def test_removes_dead_untagged_keeps_dead_tagged(self):
        low = lowering.lower(build_with_dead_code())
        assert "dead_junk" in prim_names(low)
        assert "dead_junk2" in prim_names(low)
        after = passes.DeadCodeElimination().run(low)
        # dead_junk only dies once its (dead) consumer is gone: fixpoint.
        assert "dead_junk" not in prim_names(after)
        assert "dead_junk2" not in prim_names(after)
        # Tagged primitives feed the tag_stats instrumentation contract:
        assert "dead_probe" in prim_names(after)

    def test_shrinks_vm_state(self):
        low = lowering.lower(build_with_dead_code())
        after = passes.DeadCodeElimination().run(low)
        assert "f/junk" in low.var_specs
        assert "f/junk" not in after.var_specs
        state = lambda p: {v for v in p.var_specs if v not in p.temp_vars}
        assert state(after) < state(low)

    def test_noop_on_dense_program(self):
        # fib's lowering has no dead compute (every prim feeds the result).
        low = fusion.fuse(lowering.lower(build_fib()))
        after = passes.DeadCodeElimination().run(low)
        assert prim_names(after) == prim_names(low)

    def test_outputs_bit_exact_with_and_without_dce(self):
        x = np.array([1.5, 2.0, 0.5, 3.0], np.float32)
        k = np.array([3, 0, 4, 2], np.int32)
        outs = {}
        for dce in (False, True):
            fn = batching.autobatch(
                build_with_dead_code(), backend="pc", verify=True, dce=dce
            )
            outs[dce] = np.asarray(fn(x, k)["out"])
        np.testing.assert_array_equal(outs[False], outs[True])

    def test_autobatch_defaults_to_dce(self):
        fn = batching.autobatch(build_with_dead_code(), backend="pc")
        assert fn.dce is True
        assert "dead_junk" not in prim_names(fn.lowered)


class TestStackDepthBound:
    def test_loop_program_needs_depth_one(self):
        rep = analysis.stack_depth_bound(lowering.lower(build_pow_loop()))
        assert rep.recursive_cycle is None
        assert rep.required_max_depth == 1  # no calls: pc never pushed

    def test_nested_calls_bound(self):
        rep = analysis.stack_depth_bound(lowering.lower(build_nested()))
        assert rep.recursive_cycle is None
        assert rep.pc_depth == 2  # top -> mid -> leaf
        assert rep.required_max_depth == 3
        assert rep.required_max_depth <= 32

    def test_recursive_cycle_named(self):
        rep = analysis.stack_depth_bound(lowering.lower(build_fib()))
        assert rep.required_max_depth is None
        assert rep.recursive_cycle == ("fib",)

    def test_mutual_recursion_cycle_named(self):
        rep = analysis.stack_depth_bound(lowering.lower(build_mutual()))
        assert rep.recursive_cycle is not None
        assert set(rep.recursive_cycle) == {"is_even", "is_odd"}

    def test_fusion_preserves_bound(self):
        low = lowering.lower(build_nested())
        assert (
            analysis.stack_depth_bound(fusion.fuse(low)).required_max_depth
            == analysis.stack_depth_bound(low).required_max_depth
        )


class TestResolvedMaxDepth:
    def test_inferred_bound_is_sufficient(self):
        # max_depth=None runs the statically inferred bound end-to-end.
        fn = batching.autobatch(build_nested(), backend="pc", verify=True)
        assert fn.max_depth is None
        assert fn.resolved_max_depth == 3
        n = np.array([1, 5, 9], np.int32)
        np.testing.assert_array_equal(np.asarray(fn(n)["out"]), n + 2)

    def test_loop_program_runs_at_depth_one(self):
        fn = batching.autobatch(build_pow_loop(), backend="pc")
        assert fn.resolved_max_depth == 1
        x = np.array([1.5, 2.0], np.float32)
        k = np.array([3, 4], np.int32)
        np.testing.assert_allclose(
            np.asarray(fn(x, k)["out"]), x.astype(np.float64) ** k,
            rtol=1e-6,
        )

    def test_recursive_falls_back_to_default(self):
        fn = batching.autobatch(build_fib(), backend="pc")
        assert fn.resolved_max_depth == batching.DEFAULT_MAX_DEPTH == 32
        n = np.array([0, 5, 9, 12], np.int32)
        np.testing.assert_array_equal(np.asarray(fn(n)["out"]), FIB[n])

    def test_explicit_max_depth_wins(self):
        fn = batching.autobatch(build_fib(), backend="pc", max_depth=20)
        assert fn.resolved_max_depth == 20

    def test_overflow_hint_names_inferred_bound(self):
        fn = batching.autobatch(build_nested(), backend="pc", max_depth=1)
        with pytest.raises(
            pc_vm.StackOverflow,
            match="statically inferred bound for this program is "
            "max_depth=3",
        ):
            fn(np.array([4], np.int32))

    def test_overflow_hint_names_recursive_cycle(self):
        fn = batching.autobatch(build_fib(), backend="pc", max_depth=3)
        with pytest.raises(
            pc_vm.StackOverflow,
            match=r"recursive \(fib -> fib\).*pass a larger max_depth",
        ):
            fn(np.array([12], np.int32))


class TestDiagnostics:
    def test_recursive_program_report(self):
        fn = batching.autobatch(build_fib(), backend="pc", verify=True)
        d = fn.diagnostics()
        assert d.verified and d.verification_error is None
        assert d.fused and d.num_source_blocks >= d.num_blocks
        assert d.recursive_cycle == ("fib",)
        txt = d.pretty()
        assert "verifier:      ok" in txt
        assert "unbounded (recursive cycle fib -> fib)" in txt

    def test_static_bound_report(self):
        fn = batching.autobatch(build_nested(), backend="pc")
        d = fn.diagnostics()
        assert d.required_max_depth == 3
        assert "stack bound:   max_depth=3" in d.pretty()

    def test_dead_state_reported(self):
        fn = batching.autobatch(
            build_with_dead_code(), backend="pc", dce=False
        )
        d = fn.diagnostics()
        assert d.dead_ops >= 1
        assert "f/junk" in d.dead_state_vars

    def test_requires_pc_backend(self):
        fn = batching.autobatch(build_fib(), backend="local")
        with pytest.raises(ValueError, match="requires the 'pc' backend"):
            fn.diagnostics()

    def test_diagnose_reports_verification_failure(self):
        bad = break_a_target(lowering.lower(build_fib()))
        d = passes.diagnose(bad)
        assert not d.verified
        assert "out of range" in d.verification_error
        assert "verifier:      FAILED" in d.pretty()
