"""Documentation cannot rot: every fenced Python block in README.md and
docs/*.md is extracted and executed here, and every relative markdown link
must point at a file that exists.

Blocks within one file share a namespace and run top-to-bottom, so later
snippets may use names defined by earlier ones (imports, decorated
functions).  Mark genuinely non-runnable listings as ```text / ```bash —
only ```python blocks are executed.
"""
from __future__ import annotations

import linecache
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

DOC_FILES = sorted(
    [REPO / "README.md", *(REPO / "docs").glob("*.md")],
    key=lambda p: str(p),
)

_FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)


def extract_python_blocks(path: Path) -> list[tuple[int, str]]:
    """-> [(1-based start line of the block body, source)] in file order."""
    text = path.read_text()
    blocks = []
    for m in _FENCE.finditer(text):
        lineno = text[: m.start(1)].count("\n") + 1
        blocks.append((lineno, m.group(1)))
    return blocks


@pytest.mark.parametrize(
    "path", DOC_FILES, ids=[str(p.relative_to(REPO)) for p in DOC_FILES]
)
def test_doc_snippets_run(path):
    assert path.exists(), f"{path} disappeared"
    blocks = extract_python_blocks(path)
    if not blocks:
        pytest.skip(f"{path.name} has no python blocks")
    ns: dict = {"__name__": f"docsnippet_{path.stem}"}
    for lineno, src in blocks:
        fname = f"<doc {path.name}:{lineno}>"
        # Register the snippet in linecache so inspect.getsource works on
        # functions it defines (the @autobatch AST frontend reads source).
        linecache.cache[fname] = (
            len(src), None, src.splitlines(keepends=True), fname
        )
        code = compile(src, fname, "exec")
        try:
            exec(code, ns)  # noqa: S102 - executing our own docs is the test
        except Exception as e:  # pragma: no cover - failure reporting
            pytest.fail(
                f"{path.name} snippet at line {lineno} failed: {e!r}"
            )


def test_doc_snippets_found_at_all():
    """The extraction regex keeps matching the docs (guards the guard)."""
    total = sum(len(extract_python_blocks(p)) for p in DOC_FILES)
    assert total >= 5, f"only {total} python blocks found across {DOC_FILES}"


def _check_links_module():
    """tools/ is not a package; load the CI link checker by path so the
    tier-1 test and the docs CI job share one implementation."""
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "check_links", REPO / "tools" / "check_links.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.mark.parametrize(
    "path", DOC_FILES, ids=[str(p.relative_to(REPO)) for p in DOC_FILES]
)
def test_relative_links_resolve(path):
    """The CI link-check contract (tools/check_links.py), in tier-1."""
    errors = _check_links_module().check_file(path)
    assert not errors, f"{path.name}: {errors}"
