"""Superblock fusion + pluggable VM scheduler (ISSUE 2).

Covers: the fusion pass's structure (NUTS glue blocks collapse, provenance
map), bit-exactness of every schedule x fuse combination, tag_stats
invariance under fusion, runtime stack-overflow detection, and the lowering
terminator validation error.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import api, batching, frontend, fusion, ir, lowering, pc_vm
from repro.core.frontend import I32
from repro.mcmc import nuts, targets

from tests.test_core import FIB, build_fib


def tiny_nuts():
    t = targets.isotropic_gaussian(2)
    s = nuts.NutsSettings(max_tree_depth=3, num_steps=2, steps_per_leaf=2)
    return t, s


def build_deep_recursion():
    """f(n) = n for n >= 0 via unit-step recursion (depth = n frames)."""
    pb = frontend.ProgramBuilder()
    fb = pb.function("depth", ["n"], ["out"], {"n": I32}, {"out": I32})
    c = fb.prim(lambda n: n <= 0, ["n"])
    with fb.if_(c):
        fb.const(0, jnp.int32, out="out")
        fb.return_()
    t = fb.prim(lambda n: n - 1, ["n"])
    fb.call("depth", [t], out="r")
    fb.assign("out", lambda r: r + 1, ["r"])
    fb.return_()
    pb.add(fb)
    return pb.build()


class TestFusionStructure:
    def test_nuts_glue_blocks_collapse(self):
        """The acceptance criterion: fused NUTS has strictly fewer blocks
        (the loop-header hops and if-join glue collapse into superblocks)."""
        t, s = tiny_nuts()
        low = lowering.lower(nuts.build_nuts_program(t, s))
        fused = fusion.fuse(low)
        assert len(fused.blocks) < len(low.blocks)
        # Control-relevant structure survives: same functions, same vars.
        assert set(fused.func_entries) == set(low.func_entries)
        assert fused.main_params == low.main_params
        assert fused.main_outputs == low.main_outputs

    def test_provenance_covers_every_original_block(self):
        t, s = tiny_nuts()
        low = lowering.lower(nuts.build_nuts_program(t, s))
        fused = fusion.fuse(low)
        assert set(fused.fused_from) == set(range(len(fused.blocks)))
        covered = {src for srcs in fused.fused_from.values() for src in srcs}
        # Every original block's ops live on in some superblock (absorbed
        # join blocks are duplicated into their jump predecessors).
        assert covered == set(range(len(low.blocks)))

    def test_fusion_reruns_block_local_opts(self):
        """Cross-block temps newly confined to one superblock leave VM
        state (paper opt. ii re-applied to the fused program)."""
        t, s = tiny_nuts()
        low = lowering.lower(nuts.build_nuts_program(t, s))
        fused = fusion.fuse(low)
        assert fused.temp_vars > low.temp_vars

    def test_fusion_is_idempotent(self):
        t, s = tiny_nuts()
        low = lowering.lower(nuts.build_nuts_program(t, s))
        once = fusion.fuse(low)
        twice = fusion.fuse(once)
        assert len(twice.blocks) == len(once.blocks)
        # Provenance composes back to *original* indices.
        assert {
            s for srcs in twice.fused_from.values() for s in srcs
        } == set(range(len(low.blocks)))

    def test_vm_steps_decrease_and_outputs_bitwise_equal(self):
        """Fusion cuts VM dispatch steps; outputs stay bit-exact (the fused
        program runs the same masked per-member op sequence)."""
        t, s = tiny_nuts()
        args = nuts.initial_state(t, 4, eps=0.3, seed=2)
        plain = nuts.make_nuts_kernel(t, s, max_steps=100_000, fuse=False)
        fused = nuts.make_nuts_kernel(t, s, max_steps=100_000, fuse=True)
        out_p = plain(*args)
        out_f = fused(*args)
        for k in out_p:
            np.testing.assert_array_equal(
                np.asarray(out_p[k]), np.asarray(out_f[k])
            )
        assert fused.scheduler_stats.num_blocks < plain.scheduler_stats.num_blocks
        assert fused.scheduler_stats.steps < plain.scheduler_stats.steps
        assert fused.scheduler_stats.fused
        assert not plain.scheduler_stats.fused


class TestSchedules:
    @pytest.mark.parametrize("schedule", ["earliest", "popular", "sweep"])
    @pytest.mark.parametrize("fuse", [False, True])
    def test_fib_exact(self, schedule, fuse):
        n = np.array([0, 1, 5, 9, 12, 3, 7, 2], np.int32)
        bf = batching.autobatch(
            build_fib(), max_depth=20, schedule=schedule, fuse=fuse
        )
        out = bf(n)
        np.testing.assert_array_equal(np.asarray(out["out"]), FIB[n])

    def test_sweep_uses_fewer_loop_iterations(self):
        n = np.array([9, 3, 12, 7], np.int32)
        early = batching.autobatch(build_fib(), max_depth=20,
                                   schedule="earliest")
        sweep = batching.autobatch(build_fib(), max_depth=20,
                                   schedule="sweep")
        early(n)
        sweep(n)
        assert sweep.scheduler_stats.steps < early.scheduler_stats.steps

    def test_invalid_schedule_rejected(self):
        with pytest.raises(ValueError, match="schedule"):
            batching.autobatch(build_fib(), schedule="random")
        with pytest.raises(ValueError, match="schedule"):
            pc_vm.ProgramCounterVM(
                lowering.lower(build_fib()),
                pc_vm.VMConfig(batch_size=2, schedule="bogus"),
            )

    def test_schedule_and_fuse_in_cache_key(self):
        n = np.array([3, 5], np.int32)
        bf = batching.autobatch(build_fib(), max_depth=20)
        bf(n)
        key = bf._aval_key({"n": n}, 2)
        assert "earliest" in key and True in key


class TestTagStatsUnderFusion:
    def _tagged_fib(self):
        pb = frontend.ProgramBuilder()
        fb = pb.function("fib", ["n"], ["out"], {"n": I32}, {"out": I32})
        c = fb.prim(lambda n: n < 2, ["n"], name="lt2")
        with fb.if_(c):
            fb.prim(lambda n: n, ["n"], out="out", name="leaf", tag="leaf")
            fb.return_()
        t1 = fb.prim(lambda n: n - 1, ["n"])
        fb.call("fib", [t1], out="a")
        t2 = fb.prim(lambda n: n - 2, ["n"])
        fb.call("fib", [t2], out="b")
        fb.assign("out", lambda a, b: a + b, ["a", "b"])
        fb.return_()
        pb.add(fb)
        return pb.build()

    def test_lockstep_counts_invariant(self):
        """Identical inputs => members move in lockstep => both execs and
        active counts are invariant under fusion."""
        n = np.full(8, 9, np.int32)
        prog = self._tagged_fib()
        stats = {}
        for fuse in (False, True):
            bf = batching.autobatch(prog, max_depth=20, fuse=fuse)
            bf(n)
            stats[fuse] = bf.tag_stats["leaf"]
        assert stats[False] == stats[True]

    def test_member_active_counts_invariant(self):
        """Per-member primitive executions are schedule/fusion independent,
        so the 'active' half of tag_stats is always exactly preserved."""
        rng = np.random.default_rng(3)
        n = rng.integers(2, 12, 16).astype(np.int32)
        prog = self._tagged_fib()
        actives = set()
        for fuse in (False, True):
            for schedule in ("earliest", "popular", "sweep"):
                bf = batching.autobatch(prog, max_depth=24, fuse=fuse,
                                        schedule=schedule)
                bf(n)
                execs, active = bf.tag_stats["leaf"]
                assert execs > 0
                actives.add(active)
        assert len(actives) == 1


class TestDepthOverflowDetection:
    def test_batching_executor_raises_with_guidance(self):
        prog = build_deep_recursion()
        bf = batching.autobatch(prog, max_depth=8, max_steps=5_000)
        n = np.array([2, 3, 30], np.int32)  # lane 2 needs ~30 frames
        with pytest.raises(pc_vm.StackOverflow, match="max_depth"):
            bf(n)
        flags = np.asarray(bf.last_result.depth_exceeded)
        np.testing.assert_array_equal(flags, [False, False, True])

    def test_no_false_positives(self):
        prog = build_deep_recursion()
        bf = batching.autobatch(prog, max_depth=16, max_steps=5_000)
        n = np.array([2, 3, 10], np.int32)
        out = bf(n)
        np.testing.assert_array_equal(np.asarray(out["out"]), n)
        assert not np.asarray(bf.last_result.depth_exceeded).any()

    def test_legacy_api_records_flag_without_raising(self):
        """The deprecated dict API keeps the seed's contained-overflow
        semantics (shallow lanes exact) but now exposes the flag."""
        prog = build_deep_recursion()
        bp = api.autobatch(prog, 3, backend="pc", max_depth=8,
                           max_steps=5_000)
        out = bp({"n": np.array([2, 3, 30], np.int32)})
        assert int(np.asarray(out["out"])[0]) == 2
        assert int(np.asarray(out["out"])[1]) == 3
        flags = np.asarray(bp.last_result.depth_exceeded)
        np.testing.assert_array_equal(flags, [False, False, True])


class TestLoweringValidation:
    def test_unterminated_block_is_value_error_with_label(self):
        pb = frontend.ProgramBuilder()
        fb = pb.function("f", ["x"], ["out"], {"x": I32}, {"out": I32})
        fb.copy("x", out="out")
        fb.return_()
        pb.add(fb)
        prog = pb.build()
        # Corrupt the terminator with an object Program.validate() cannot
        # classify; lowering must reject it with the offending block label.
        prog.functions["f"].blocks[0].term = "bogus"
        with pytest.raises(ValueError, match=r"unterminated block f\.0"):
            lowering.lower(prog)


class TestFusionNoOpPrograms:
    def test_branch_only_program_unchanged(self):
        """A CFG with no unconditional jump chains fuses to itself."""
        low = lowering.lower(build_fib())
        fused = fusion.fuse(low)
        assert len(fused.blocks) == len(low.blocks)
        assert fused.stack_vars == low.stack_vars
        n = np.array([4, 11, 0], np.int32)
        vm = pc_vm.ProgramCounterVM(
            fused, pc_vm.VMConfig(batch_size=3, max_depth=20)
        )
        res = vm.run({ir.qualify("fib", "n"): n})
        np.testing.assert_array_equal(
            np.asarray(res.outputs[ir.qualify("fib", "out")]), FIB[n]
        )


class TestFusionEdgeCases:
    """Satellite edge cases: jump cycles, orphaned functions, re-fusion."""

    @staticmethod
    def _jump_only(terms: list[ir.LTerminator]) -> ir.LoweredProgram:
        """A varless program whose blocks carry only the given terminators."""
        return ir.LoweredProgram(
            blocks=[
                ir.LBlock(ops=[], term=t, label=f"b{i}")
                for i, t in enumerate(terms)
            ],
            entry=0,
            main_params=(),
            main_outputs=(),
            var_specs={},
            stack_vars=frozenset(),
            temp_vars=frozenset(),
            func_entries={"main": 0},
        )

    def test_cyclic_jump_chain_terminates(self):
        # 0 -> 1 -> 2 -> 1: an unconditional-jump cycle must not send the
        # chain builder into an infinite walk, and the result must verify.
        low = self._jump_only([ir.LJump(1), ir.LJump(2), ir.LJump(1)])
        fused = fusion.fuse(low, verify=True)
        srcs = {s for chain in fused.fused_from.values() for s in chain}
        assert srcs == {0, 1, 2}  # nothing dropped, nothing invented
        # Every block still terminates in a lowered terminator whose
        # target exists (the cycle is preserved, just re-indexed).
        assert all(b.term is not None for b in fused.blocks)

    def test_self_loop_jump(self):
        # 0 -> 1 -> 1: the tightest cycle.
        low = self._jump_only([ir.LJump(1), ir.LJump(1)])
        fused = fusion.fuse(low, verify=True)
        srcs = {s for chain in fused.fused_from.values() for s in chain}
        assert srcs == {0, 1}

    def test_uncalled_function_body_survives_fusion(self):
        # A registered function main never calls is dead weight, but its
        # entry is pinned: fusion must keep it (and the program must still
        # verify) rather than fusing through or dropping a root.
        pb = frontend.ProgramBuilder()
        orphan = pb.function(
            "orphan", ["n"], ["out"], {"n": I32}, {"out": I32}
        )
        orphan.assign("out", lambda n: n * 2, ["n"])
        orphan.return_()
        pb.add(orphan)
        fb = pb.function("main", ["n"], ["out"], {"n": I32}, {"out": I32})
        fb.assign("out", lambda n: n + 1, ["n"])
        fb.return_()
        pb.add(fb)
        prog = ir.Program(functions=pb.functions, main="main")
        fused = fusion.fuse(lowering.lower(prog, verify=True), verify=True)
        assert "orphan" in fused.func_entries
        orphan_entry = fused.func_entries["orphan"]
        assert fused.blocks[orphan_entry].term is not None
        n = np.array([3, 10], np.int32)
        vm = pc_vm.ProgramCounterVM(
            fused, pc_vm.VMConfig(batch_size=2, max_depth=4)
        )
        res = vm.run({"main/n": n})
        np.testing.assert_array_equal(
            np.asarray(res.outputs["main/out"]), n + 1
        )

    def test_double_fusion_provenance_composes(self):
        t, s = tiny_nuts()
        low = lowering.lower(nuts.build_nuts_program(t, s))
        once = fusion.fuse(low, verify=True)
        twice = fusion.fuse(once, verify=True)
        n_orig = len(low.blocks)
        # Re-fusing a fused program keeps provenance in *original* (pre-
        # fusion) indices: compose, don't nest.
        for chain in twice.fused_from.values():
            assert all(0 <= s_ < n_orig for s_ in chain)
        covered = {s_ for c in twice.fused_from.values() for s_ in c}
        covered_once = {s_ for c in once.fused_from.values() for s_ in c}
        assert covered == covered_once
