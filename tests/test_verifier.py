"""Mutation tests for the lowered-IR verifier (core/verifier.py).

One test per invariant class: take a *valid* lowered program, corrupt it
in exactly one way, and assert the verifier rejects it with a message
naming the offending block/variable.  Plus the positive direction: the
unmutated example programs (and the NUTS program, the paper's
experiment) pass the full verifier after every pass of the pipeline.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import frontend, fusion, ir, lowering, passes, verifier
from repro.core.frontend import I32

from tests.test_core import build_fib, build_mutual, build_pow_loop


def copy_lowered(low: ir.LoweredProgram) -> ir.LoweredProgram:
    """A structurally independent copy safe to mutate in place."""
    return ir.dataclass_replace(
        low,
        blocks=[
            ir.LBlock(ops=list(b.ops), term=b.term, label=b.label)
            for b in low.blocks
        ],
        var_specs=dict(low.var_specs),
        func_entries=dict(low.func_entries),
        fused_from=None if low.fused_from is None else dict(low.fused_from),
    )


@pytest.fixture
def fib_low():
    return lowering.lower(build_fib())


class TestStructure:
    def test_valid_program_passes(self, fib_low):
        verifier.verify(fib_low)  # does not raise

    def test_out_of_range_target(self, fib_low):
        bad = copy_lowered(fib_low)
        bad.blocks[1].term = ir.LJump(999)
        with pytest.raises(
            verifier.VerificationError,
            match=r"block 1 .*terminator target 999 is out of range",
        ):
            verifier.verify(bad)

    def test_entry_must_be_function_entry(self, fib_low):
        non_entry = next(
            i
            for i in range(len(fib_low.blocks))
            if i not in set(fib_low.func_entries.values())
        )
        bad = ir.dataclass_replace(copy_lowered(fib_low), entry=non_entry)
        with pytest.raises(
            verifier.VerificationError, match="is not a function entry"
        ):
            verifier.verify(bad)

    def test_pushjump_must_target_function_entry(self, fib_low):
        bad = copy_lowered(fib_low)
        entries = set(bad.func_entries.values())
        i, t = next(
            (i, b.term)
            for i, b in enumerate(bad.blocks)
            if isinstance(b.term, ir.LPushJump)
        )
        non_entry = next(
            j for j in range(len(bad.blocks)) if j not in entries
        )
        bad.blocks[i].term = ir.LPushJump(target=non_entry, ret=t.ret)
        with pytest.raises(
            verifier.VerificationError,
            match=rf"pushjump target {non_entry} is not a function entry",
        ):
            verifier.verify(bad)

    def test_empty_program_rejected(self, fib_low):
        bad = ir.dataclass_replace(copy_lowered(fib_low), blocks=[])
        with pytest.raises(
            verifier.VerificationError, match="program has no blocks"
        ):
            verifier.verify(bad)


class TestReachability:
    def test_unreachable_ret_site_rejected(self, fib_low):
        # Returning straight out of the entry block orphans the function
        # body — including the pinned call-return sites.
        bad = copy_lowered(fib_low)
        bad.blocks[bad.entry].term = ir.LReturn()
        with pytest.raises(
            verifier.VerificationError,
            match="unreachable from the control roots",
        ):
            verifier.verify(bad)


class TestStackBalance:
    def test_extra_push_unbalanced(self, fib_low):
        bad = copy_lowered(fib_low)
        v = sorted(bad.stack_vars)[0]
        # Duplicate an existing push somewhere on the path to a return:
        i, op = next(
            (i, op)
            for i, b in enumerate(bad.blocks)
            for op in b.ops
            if isinstance(op, ir.LPush) and op.var == v
        )
        bad.blocks[i].ops.append(op)
        with pytest.raises(
            verifier.VerificationError, match="stack balance:"
        ):
            verifier.verify(bad)

    def test_pop_below_frame_floor(self, fib_low):
        bad = copy_lowered(fib_low)
        v = sorted(bad.stack_vars)[0]
        bad.blocks[bad.entry].ops.insert(0, ir.LPop(v))
        with pytest.raises(
            verifier.VerificationError,
            match=rf"stack balance: .*{v}.*below the frame's stack floor",
        ):
            verifier.verify(bad)


class TestVarClasses:
    def test_stack_vars_must_match_ops(self, fib_low):
        bad = ir.dataclass_replace(
            copy_lowered(fib_low),
            stack_vars=fib_low.stack_vars | {"fib/bogus"},
        )
        with pytest.raises(
            verifier.VerificationError,
            match=r"stack_vars is not exactly the pushed/popped set: "
            r"missing \[\], extra \['fib/bogus'\]",
        ):
            verifier.verify(bad)

    def test_temp_cannot_be_main_io(self, fib_low):
        io = next(  # pick an I/O var that is not also a stack var
            v
            for v in (*fib_low.main_params, *fib_low.main_outputs)
            if v not in fib_low.stack_vars
        )
        bad = ir.dataclass_replace(
            copy_lowered(fib_low), temp_vars=fib_low.temp_vars | {io}
        )
        with pytest.raises(
            verifier.VerificationError,
            match="temp_vars include main params/outputs",
        ):
            verifier.verify(bad)

    def test_temp_read_before_write(self, fib_low):
        bad = copy_lowered(fib_low)
        t = sorted(bad.temp_vars)[0]
        i = next(
            i
            for i, b in enumerate(bad.blocks)
            if any(t in ir.prim_writes(op) for op in b.ops)
        )
        bad.blocks[i].ops.insert(
            0, ir.LPrim(outs=(t,), fn=lambda x: x, ins=(t,), name="bad")
        )
        with pytest.raises(
            verifier.VerificationError,
            match=rf"temp var '{t}' is read before any write",
        ):
            verifier.verify(bad)


class TestSpecs:
    def test_prim_output_spec_mismatch(self, fib_low):
        bad = copy_lowered(fib_low)
        # fib/out is written by primitives but never pushed, so the first
        # check to trip is the eval_shape one.
        bad.var_specs["fib/out"] = jax.ShapeDtypeStruct((3,), jnp.float32)
        with pytest.raises(
            verifier.VerificationError,
            match=r"writes 'fib/out' as .* but var_specs declares",
        ):
            verifier.verify(bad)

    def test_missing_var_spec(self, fib_low):
        bad = copy_lowered(fib_low)
        v = sorted(bad.temp_vars)[0]  # mentioned, but not main I/O
        del bad.var_specs[v]
        with pytest.raises(
            verifier.VerificationError,
            match=rf"variable '{v}' has no var_specs entry",
        ):
            verifier.verify(bad)

    def test_push_spec_mix(self):
        # Handcrafted minimal program: the only spec defect is the push
        # whose source buffer is typed differently from its stack.
        low = ir.LoweredProgram(
            blocks=[
                ir.LBlock(
                    ops=[ir.LPush("main/v", "main/w"), ir.LPop("main/v")],
                    term=ir.LReturn(),
                    label="main",
                )
            ],
            entry=0,
            main_params=("main/w",),
            main_outputs=("main/w",),
            var_specs={
                "main/v": jax.ShapeDtypeStruct((), jnp.int32),
                "main/w": jax.ShapeDtypeStruct((2,), jnp.float32),
            },
            stack_vars=frozenset({"main/v"}),
            temp_vars=frozenset(),
            func_entries={"main": 0},
        )
        with pytest.raises(
            verifier.VerificationError,
            match=r"push main/v <- main/w mixes specs",
        ):
            verifier.verify(low)

    def test_check_specs_false_skips_type_checking(self, fib_low):
        bad = copy_lowered(fib_low)
        bad.var_specs["fib/out"] = jax.ShapeDtypeStruct((3,), jnp.float32)
        verifier.verify(bad, check_specs=False)  # does not raise


class TestProvenance:
    @pytest.fixture
    def fused(self, fib_low):
        return fusion.fuse(fib_low)

    def test_fused_program_passes(self, fused):
        verifier.verify(fused)

    def test_missing_key(self, fused):
        bad = copy_lowered(fused)
        del bad.fused_from[0]
        with pytest.raises(
            verifier.VerificationError,
            match=r"fused_from keys are not exactly 0\.\.",
        ):
            verifier.verify(bad)

    def test_empty_sources(self, fused):
        bad = copy_lowered(fused)
        bad.fused_from[1] = ()
        with pytest.raises(
            verifier.VerificationError,
            match=r"fused_from\[1\] is empty",
        ):
            verifier.verify(bad)

    def test_duplicate_chain_head(self, fused):
        bad = copy_lowered(fused)
        bad.fused_from[1] = bad.fused_from[0]
        with pytest.raises(
            verifier.VerificationError,
            match="both claim original block .* as their chain head",
        ):
            verifier.verify(bad)

    def test_repeated_source(self, fused):
        bad = copy_lowered(fused)
        srcs = bad.fused_from[0]
        bad.fused_from[0] = srcs + (srcs[0],)
        with pytest.raises(
            verifier.VerificationError, match="repeats a source block"
        ):
            verifier.verify(bad)


def _packed_low() -> ir.LoweredProgram:
    """Minimal valid layout-packed program: one block unpacks two members
    from a packed array, combines them, and packs the group back."""
    i32 = jax.ShapeDtypeStruct((), jnp.int32)
    return ir.LoweredProgram(
        blocks=[
            ir.LBlock(
                ops=[
                    ir.LPrim(
                        outs=("main/a", "main/b"),
                        fn=lambda p: (p[0], p[1]),
                        ins=("%pgo/pack0",),
                        name="unpack",
                    ),
                    ir.LPrim(
                        outs=("main/w",),
                        fn=lambda a, b: a + b,
                        ins=("main/a", "main/b"),
                        name="add",
                    ),
                    ir.LPrim(
                        outs=("%pgo/pack0",),
                        fn=lambda a, b: jnp.stack((a, b)),
                        ins=("main/a", "main/b"),
                        name="pack",
                    ),
                ],
                term=ir.LReturn(),
                label="main",
            )
        ],
        entry=0,
        main_params=("main/w",),
        main_outputs=("main/w",),
        var_specs={
            "main/a": i32,
            "main/b": i32,
            "main/w": i32,
            "%pgo/pack0": jax.ShapeDtypeStruct((2,), jnp.int32),
        },
        stack_vars=frozenset(),
        temp_vars=frozenset({"main/a", "main/b"}),
        func_entries={"main": 0},
        state_layout=ir.StateLayout(
            groups={"%pgo/pack0": ("main/a", "main/b")}
        ),
    )


class TestLayoutPacking:
    def test_valid_packed_program_passes(self):
        verifier.verify(_packed_low())

    def test_group_of_one_rejected(self):
        bad = ir.dataclass_replace(
            _packed_low(),
            state_layout=ir.StateLayout(
                groups={"%pgo/pack0": ("main/a",)}
            ),
        )
        with pytest.raises(
            verifier.VerificationError, match=r"packs 1 member\(s\)"
        ):
            verifier.verify(bad, check_specs=False)

    def test_packed_var_needs_spec(self):
        low = _packed_low()
        specs = dict(low.var_specs)
        del specs["%pgo/pack0"]
        bad = ir.dataclass_replace(low, var_specs=specs)
        with pytest.raises(
            verifier.VerificationError,
            match=r"packed variable '%pgo/pack0' has no var_specs",
        ):
            verifier.verify(bad, check_specs=False)

    def test_member_in_two_groups_rejected(self):
        low = _packed_low()
        bad = ir.dataclass_replace(
            low,
            var_specs={
                **low.var_specs,
                "%pgo/pack1": low.var_specs["%pgo/pack0"],
            },
            state_layout=ir.StateLayout(
                groups={
                    "%pgo/pack0": ("main/a", "main/b"),
                    "%pgo/pack1": ("main/a", "main/b"),
                }
            ),
        )
        with pytest.raises(
            verifier.VerificationError,
            match=r"member 'main/a' belongs to both",
        ):
            verifier.verify(bad, check_specs=False)

    def test_member_must_be_temp(self):
        bad = ir.dataclass_replace(
            _packed_low(), temp_vars=frozenset({"main/a"})
        )
        with pytest.raises(
            verifier.VerificationError,
            match=r"member 'main/b' must be a block-local temp",
        ):
            verifier.verify(bad, check_specs=False)

    def test_member_spec_mix_rejected(self):
        low = _packed_low()
        bad = ir.dataclass_replace(
            low,
            var_specs={
                **low.var_specs,
                "main/b": jax.ShapeDtypeStruct((), jnp.float32),
            },
        )
        with pytest.raises(
            verifier.VerificationError, match="mixes member specs"
        ):
            verifier.verify(bad, check_specs=False)

    def test_packed_spec_shape_rejected(self):
        low = _packed_low()
        bad = ir.dataclass_replace(
            low,
            var_specs={
                **low.var_specs,
                "%pgo/pack0": jax.ShapeDtypeStruct((3,), jnp.int32),
            },
        )
        with pytest.raises(
            verifier.VerificationError,
            match=r"\(k,\) \+ member shape",
        ):
            verifier.verify(bad, check_specs=False)


class TestReordering:
    def test_non_permutation_rejected(self, fib_low):
        n = len(fib_low.blocks)
        bad = ir.dataclass_replace(
            copy_lowered(fib_low), block_order=(0,) * n
        )
        with pytest.raises(
            verifier.VerificationError, match="not a permutation"
        ):
            verifier.verify(bad)

    def test_block_weights_length_checked(self, fib_low):
        bad = ir.dataclass_replace(
            copy_lowered(fib_low), block_weights=(1, 2)
        )
        with pytest.raises(
            verifier.VerificationError,
            match=r"block_weights has 2 entries",
        ):
            verifier.verify(bad)

    def test_valid_permutation_passes(self, fib_low):
        n = len(fib_low.blocks)
        good = ir.dataclass_replace(
            copy_lowered(fib_low),
            block_order=tuple(range(n)),
            block_weights=(7,) * n,
        )
        verifier.verify(good)


class TestUnmutatedProgramsVerifyClean:
    """The positive direction: real programs pass after *every* pass."""

    @pytest.mark.parametrize(
        "build", [build_fib, build_pow_loop, build_mutual]
    )
    def test_examples_full_pipeline(self, build):
        low = lowering.lower(build(), verify=True)
        pipe = list(passes.fusion_passes()) + [passes.DeadCodeElimination()]
        passes.PassPipeline(pipe, verify=True, debug=True).run(low)

    def test_nuts_full_pipeline(self):
        from repro.mcmc import nuts, targets

        t = targets.isotropic_gaussian(2)
        s = nuts.NutsSettings(
            max_tree_depth=3, num_steps=2, steps_per_leaf=2
        )
        prog = nuts.build_nuts_program(t, s)
        low = lowering.lower(prog, verify=True)
        pipe = list(passes.fusion_passes()) + [passes.DeadCodeElimination()]
        fused = passes.PassPipeline(pipe, verify=True, debug=True).run(low)
        verifier.verify(fused)

    def test_error_is_value_error(self):
        # Callers catching ValueError (the lowering's historical error
        # type) also catch verifier rejections.
        assert issubclass(verifier.VerificationError, ValueError)

    def test_builder_loop_program(self):
        pb = frontend.ProgramBuilder()
        fb = pb.function("count", ["n"], ["out"], {"n": I32}, {"out": I32})
        fb.const(0, jnp.int32, out="out")
        with fb.while_(lambda n, out: out < n, ["n", "out"]):
            fb.assign("out", lambda o: o + 1, ["out"])
        fb.return_()
        pb.add(fb)
        low = lowering.lower(pb.build(), verify=True)
        verifier.verify(fusion.fuse(low, verify=True))
