"""End-to-end tests for the profile-guided optimization pipeline.

The ISSUE 10 tentpole contract: tracing a run, distilling the dispatch
stream into a :class:`BlockProfile` and re-lowering through
``passes.pgo_passes`` (trace-driven superblock formation, hot-state
layout packing, frequency block reordering) must keep outputs bit-exact
while *strictly* reducing both the dispatch count and the number of
masked whole-state updates.  The divergent-parity program below is the
canonical superblock workload: a helper called from **both** arms of a
hot branch (the multi-predecessor join that structural
``JumpChainFusion`` must skip and only the profile-guided
tail-duplicating inliner may fuse) plus a single-call-site helper (the
frame-merge opportunity).
"""
import numpy as np
import pytest

from repro.core import batching, frontend, ir, lowering
from repro.core.frontend import I32
from repro.obs import block_profile
from repro.obs.blockprof import PROFILE_VERSION, BlockProfile


def build_parity():
    """A loop whose body diverges on parity, both arms calling ``h``.

    ``h`` has two call sites (only tail-duplicating inlining can absorb
    it); ``g`` has one (the frame-merge case).  Lanes with different
    seeds interleave even/odd iterations, so both arms stay hot.
    """
    pb = frontend.ProgramBuilder(main="par")
    hb = pb.function("h", ["x"], ["y"], {"x": I32}, {"y": I32})
    hb.assign("y", lambda x: x * 3 + 1, ["x"])
    hb.return_()
    pb.add(hb)
    gb = pb.function("g", ["a"], ["b"], {"a": I32}, {"b": I32})
    gb.assign("b", lambda a: a - 5, ["a"])
    gb.return_()
    pb.add(gb)
    fb = pb.function(
        "par", ["n", "x"], ["out"], {"n": I32, "x": I32}, {"out": I32}
    )
    fb.copy("x", out="acc")
    fb.copy("n", out="i")
    with fb.while_(lambda i: i > 0, ["i"]):
        c = fb.prim(lambda acc: acc % 2 == 0, ["acc"], name="even")
        with fb.if_(c):
            fb.call("h", ["acc"], out="acc")
        with fb.orelse():
            fb.call("h", ["acc"], out="t")
            fb.assign("acc", lambda t: t + 1, ["t"])
        fb.call("g", ["acc"], out="acc")
        fb.assign("i", lambda i: i - 1, ["i"])
    fb.copy("acc", out="out")
    fb.return_()
    pb.add(fb)
    return pb.build()


def _parity_inputs(lanes=8):
    rng = np.random.default_rng(5)
    n = rng.integers(3, 9, size=lanes).astype(np.int32)
    x = rng.integers(-40, 41, size=lanes).astype(np.int32)
    return n, x


def _traced_parity(**opts):
    fn = batching.autobatch(
        build_parity(), backend="pc", max_depth=8, max_steps=100_000,
        fuse=True, trace=True, verify=True, **opts,
    )
    n, x = _parity_inputs()
    out = np.asarray(fn(n, x)["out"])
    return fn, (n, x), out


class TestSuperblocks:
    def test_parity_pgo_strictly_reduces_dispatches(self):
        fn, args, base = _traced_parity()
        base_stats = fn.scheduler_stats
        prof = block_profile(fn.last_trace)
        opt = fn.optimize(prof)
        np.testing.assert_array_equal(np.asarray(opt(*args)["out"]), base)
        stats = opt.scheduler_stats
        assert stats.steps < base_stats.steps, (
            f"hot-path superblocks must cut dispatches: "
            f"{base_stats.steps} -> {stats.steps}"
        )
        assert stats.masked_updates < base_stats.masked_updates
        # Both helper frames dissolved into their callers: the single-site
        # ``g`` by the frame merge, the two-site ``h`` by tail-duplicating
        # inlining (which the structural fuser must never do on its own).
        assert stats.num_blocks < base_stats.num_blocks
        assert "h" not in opt.lowered.func_entries
        assert "g" not in opt.lowered.func_entries
        structural = fn.lowered
        assert "h" in structural.func_entries  # fuse alone keeps the frame

    def test_nuts_pgo_bitexact_and_reduced(self):
        from repro.mcmc import nuts, targets

        target = targets.isotropic_gaussian(2)
        settings = nuts.NutsSettings(
            max_tree_depth=3, num_steps=2, steps_per_leaf=2
        )
        kern = nuts.make_nuts_kernel(
            target, settings, backend="pc", max_steps=200_000,
            fuse=True, verify=True,
        )
        traced = kern.with_options(trace=True)
        args = nuts.initial_state(target, 8, eps=0.1, seed=0)
        base = traced(*args)
        base_stats = traced.scheduler_stats
        opt = kern.optimize(block_profile(traced.last_trace))
        out = opt(*args)
        for k in base:
            np.testing.assert_array_equal(
                np.asarray(out[k]), np.asarray(base[k]),
                err_msg=f"NUTS output {k!r} drifted under PGO",
            )
        stats = opt.scheduler_stats
        assert stats.steps < base_stats.steps
        assert stats.masked_updates < base_stats.masked_updates
        layout = opt.lowered.state_layout
        assert layout is not None and len(layout.groups) >= 1, (
            "NUTS has many same-spec scalars; layout packing must fire"
        )


class TestLayoutPacking:
    def test_packed_members_leave_vm_state(self):
        fn, args, base = _traced_parity()
        opt = fn.optimize(block_profile(fn.last_trace))
        low = opt.lowered
        layout = low.state_layout
        assert layout is not None
        for packed, members in layout.groups.items():
            assert len(members) >= 2
            k = low.var_specs[packed].shape[0]
            assert k == len(members)
            for m in members:
                # The member's cross-block value lives in the packed slot;
                # the per-member buffer is gone from VM state.
                assert m in low.temp_vars
                assert layout.slot_of(m) == (packed, members.index(m))
        np.testing.assert_array_equal(np.asarray(opt(*args)["out"]), base)

    def test_segmented_stepper_reads_packed_outputs(self):
        fn, (n, x), base = _traced_parity()
        prof = block_profile(fn.last_trace)
        opt = fn.optimize(prof)
        opt(n, x)
        single_steps = int(opt.last_result.steps)
        st = opt.stepper(n, x)
        state = st.init()
        budget = 0
        while not st.done(state):
            state = st.step(state, 3)
            budget += 1
            assert budget < 10_000
        np.testing.assert_array_equal(
            np.asarray(st.result(state)["out"]), base,
            err_msg="segmented PGO run != single-shot baseline",
        )
        assert st.steps(state) == single_steps


class TestProfileRoundTrip:
    def test_save_load_round_trip(self, tmp_path):
        fn, _, _ = _traced_parity()
        prof = block_profile(fn.last_trace)
        path = tmp_path / "profile.json"
        prof.save(str(path))
        back = BlockProfile.load(str(path))
        assert back.digest() == prof.digest()
        np.testing.assert_array_equal(back.dispatches, prof.dispatches)
        np.testing.assert_array_equal(back.total_active, prof.total_active)
        np.testing.assert_array_equal(back.transitions, prof.transitions)
        assert back.schedule == prof.schedule
        assert back.batch_size == prof.batch_size

    def test_v1_profile_still_loads(self):
        fn, _, _ = _traced_parity()
        prof = block_profile(fn.last_trace)
        data = prof.to_json()
        data["version"] = 1
        for row in data["blocks"]:
            del row["total_active"]  # v1 lacked the exact integer
        back = BlockProfile.from_json(data)
        # v1 reconstructs totals from the rounded per-dispatch means.
        np.testing.assert_array_equal(back.dispatches, prof.dispatches)
        np.testing.assert_allclose(
            back.total_active, prof.total_active, atol=1,
        )

    def test_unsupported_version_rejected(self):
        fn, _, _ = _traced_parity()
        data = block_profile(fn.last_trace).to_json()
        data["version"] = PROFILE_VERSION + 1
        with pytest.raises(ValueError, match="unsupported block profile"):
            BlockProfile.from_json(data)
        with pytest.raises(ValueError, match="no 'version' field"):
            BlockProfile.from_json({"num_blocks": 3})


class TestPlumbing:
    def test_optimize_equals_with_options_pgo(self):
        fn, args, base = _traced_parity()
        prof = block_profile(fn.last_trace)
        via_opt = fn.optimize(prof)
        via_wo = fn.with_options(pgo=prof)
        assert via_opt._pgo_digest() == via_wo._pgo_digest() \
            == prof.digest()
        np.testing.assert_array_equal(
            np.asarray(via_opt(*args)["out"]),
            np.asarray(via_wo(*args)["out"]),
        )

    def test_pgo_accepts_a_saved_profile_path(self, tmp_path):
        fn, args, base = _traced_parity()
        prof = block_profile(fn.last_trace)
        path = tmp_path / "p.json"
        prof.save(str(path))
        opt = batching.autobatch(
            build_parity(), backend="pc", max_depth=8, max_steps=100_000,
            fuse=True, verify=True, pgo=str(path),
        )
        assert opt._pgo_digest() == prof.digest()
        np.testing.assert_array_equal(np.asarray(opt(*args)["out"]), base)

    def test_lowered_shared_only_for_equal_digests(self):
        fn, _, _ = _traced_parity()
        prof = block_profile(fn.last_trace)
        opt = fn.optimize(prof)
        low = opt.lowered
        assert opt.with_options(max_steps=50_000).lowered is low
        assert fn.with_options(max_steps=50_000).lowered is fn.lowered
        assert opt.lowered is not fn.lowered

    def test_bogus_pgo_value_rejected(self):
        with pytest.raises(TypeError, match="pgo"):
            batching.autobatch(
                build_parity(), backend="pc", pgo=object(),
            )


class TestPretty:
    def test_pretty_renders_permutation_and_layout(self):
        low = lowering.lower(build_parity())
        n = len(low.blocks)
        perm = tuple(reversed(range(n)))
        shown = ir.dataclass_replace(
            low,
            block_order=perm,
            state_layout=ir.StateLayout(
                groups={"%pgo/pack0": ("par/acc", "par/i")}
            ),
        )
        text = shown.pretty()
        assert f"reordered: [{','.join(str(o) for o in perm)}]" in text
        assert "layout %pgo/pack0: [par/acc, par/i]" in text

    def test_real_pgo_lowering_renders(self):
        fn, _, _ = _traced_parity()
        opt = fn.optimize(block_profile(fn.last_trace))
        text = opt.lowered.pretty()
        assert "layout %pgo/pack" in text


class TestCacheKey:
    def test_profile_digest_distinguishes_executors(self):
        """Two different profiles must not collide in the executor cache:
        the digest is part of the aval key."""
        fn, args, _ = _traced_parity()
        prof = block_profile(fn.last_trace)
        assert fn._pgo_digest() is None
        opt = fn.optimize(prof)
        assert opt._pgo_digest() == prof.digest()
        # A structurally different profile yields a different digest.
        data = prof.to_json()
        data["blocks"][0]["dispatches"] += 1
        other = BlockProfile.from_json(data)
        assert other.digest() != prof.digest()
