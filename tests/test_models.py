"""Per-architecture smoke tests (reduced configs) + consistency checks.

Every assigned arch instantiates a reduced same-family config and runs a
forward/train step on CPU asserting output shapes and no NaNs; decoder
archs additionally check that sequential decode reproduces the full
forward pass (validating KV caches and chunked<->recurrent equivalence).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import SHAPES, ShapeSpec, applicable_shapes
from repro.models import get_model

ARCHS = configs.list_archs()
SMOKE_TRAIN = ShapeSpec("smoke_train", 32, 2, "train")


@pytest.fixture(scope="module")
def model_and_params():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = configs.get_smoke_config(name)
            m = get_model(cfg)
            cache[name] = (m, m.init(jax.random.PRNGKey(0)))
        return cache[name]

    return get


class TestConfigs:
    def test_registry_has_all_assigned(self):
        assert len(ARCHS) == 10

    @pytest.mark.parametrize("name", ARCHS)
    def test_full_config_fields(self, name):
        cfg = configs.get_config(name)
        assert cfg.num_layers > 0 and cfg.d_model > 0
        assert cfg.num_heads % cfg.num_kv_heads == 0
        assert cfg.param_count() > 0
        assert cfg.active_param_count() <= cfg.param_count()

    def test_param_counts_match_public_sizes(self):
        """Analytic param counts are in the right ballpark of the names."""
        approx = {
            "qwen3-0.6b": (0.4e9, 0.9e9),
            "qwen3-14b": (12e9, 17e9),
            "qwen1.5-32b": (28e9, 38e9),
            "smollm-135m": (0.1e9, 0.2e9),
            "deepseek-moe-16b": (13e9, 20e9),
            "qwen3-moe-235b-a22b": (200e9, 260e9),
            "zamba2-7b": (5e9, 9e9),
            "hubert-xlarge": (0.7e9, 1.3e9),
            "qwen2-vl-2b": (1.2e9, 2.4e9),
            "xlstm-350m": (0.2e9, 0.6e9),
        }
        for name, (lo, hi) in approx.items():
            n = configs.get_config(name).param_count()
            assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo},{hi}]"

    def test_moe_active_params(self):
        cfg = configs.get_config("qwen3-moe-235b-a22b")
        act = cfg.active_param_count()
        assert 15e9 <= act <= 30e9  # "A22B"

    @pytest.mark.parametrize("name", ARCHS)
    def test_shape_applicability(self, name):
        cfg = configs.get_config(name)
        shapes = {s.name for s in applicable_shapes(cfg)}
        if cfg.is_encoder:
            assert "decode_32k" not in shapes and "long_500k" not in shapes
        elif cfg.subquadratic:
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
        assert "train_4k" in shapes and "prefill_32k" in shapes


class TestSmokeForward:
    @pytest.mark.parametrize("name", ARCHS)
    def test_train_step_shapes_and_finite(self, model_and_params, name):
        m, params = model_and_params(name)
        batch = m.make_batch(jax.random.PRNGKey(1), SMOKE_TRAIN)
        logits, _ = jax.jit(lambda p, b: m.forward(p, b))(params, batch)
        assert logits.shape[-1] == m.cfg.vocab_size
        assert logits.shape[0] == SMOKE_TRAIN.global_batch
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        loss, metrics = jax.jit(lambda p, b: m.loss(p, b))(params, batch)
        assert bool(jnp.isfinite(loss))
        # one gradient step is finite too
        g = jax.jit(jax.grad(lambda p, b: m.loss(p, b)[0]))(params, batch)
        flat = jax.tree.leaves(g)
        assert all(bool(jnp.all(jnp.isfinite(x))) for x in flat)

    @pytest.mark.parametrize("name", ARCHS)
    def test_remat_matches(self, model_and_params, name):
        m, params = model_and_params(name)
        batch = m.make_batch(jax.random.PRNGKey(2), SMOKE_TRAIN)
        l0, _ = jax.jit(lambda p, b: m.loss(p, b, remat="none"))(params, batch)
        l1, _ = jax.jit(lambda p, b: m.loss(p, b, remat="full"))(params, batch)
        np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


class TestDecodeConsistency:
    @pytest.mark.parametrize(
        "name", [a for a in ARCHS if configs.get_config(a).supports_decode]
    )
    def test_decode_matches_forward(self, name):
        cfg = configs.get_smoke_config(name)
        if cfg.family == "moe":
            # avoid train-path capacity dropping (standard semantics diff)
            cfg = dataclasses.replace(cfg, capacity_factor=8.0)
        m = get_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        b, s = 2, 32
        if cfg.family == "vlm":
            pytest.skip("vlm decode starts from a multimodal prefill")
        tokens = jax.random.randint(
            jax.random.PRNGKey(5), (b, s), 0, cfg.vocab_size
        )
        full, _ = jax.jit(lambda p, bt: m.forward(p, bt))(
            params, {"tokens": tokens}
        )
        cache = m.init_cache(b, s)
        step = jax.jit(m.decode_step)
        outs = []
        for t in range(s):
            lg, cache = step(
                params, cache, tokens[:, t], jnp.full((b,), t, jnp.int32)
            )
            outs.append(lg)
        dec = jnp.stack(outs, 1)
        np.testing.assert_allclose(
            np.asarray(dec), np.asarray(full, np.float32),
            rtol=2e-3, atol=2e-3,
        )

    def test_int8_kv_cache_decode(self):
        """Quantized KV cache: halved bytes, near-identical decode."""
        cfg = dataclasses.replace(
            configs.get_smoke_config("qwen3-0.6b"), kv_cache_dtype="int8"
        )
        m = get_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        b, s = 2, 32
        tokens = jax.random.randint(
            jax.random.PRNGKey(5), (b, s), 0, cfg.vocab_size
        )
        full, _ = jax.jit(lambda p, bt: m.forward(p, bt))(
            params, {"tokens": tokens}
        )
        cache = m.init_cache(b, s)
        assert cache["kv"]["k_q"].dtype == jnp.int8
        step = jax.jit(m.decode_step)
        outs = []
        for t in range(s):
            lg, cache = step(
                params, cache, tokens[:, t], jnp.full((b,), t, jnp.int32)
            )
            outs.append(lg)
        dec = jnp.stack(outs, 1)
        rel = float(jnp.max(jnp.abs(dec - full.astype(jnp.float32)))) / float(
            jnp.max(jnp.abs(full))
        )
        assert rel < 0.05
        agree = float(
            (jnp.argmax(dec, -1) == jnp.argmax(full.astype(jnp.float32), -1))
            .mean()
        )
        assert agree > 0.9

    def test_sliding_window_decode(self):
        """Ring-buffer cache with window < context stays finite & causal."""
        cfg = configs.get_smoke_config("zamba2-7b")
        m = get_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        b, w = 2, 8
        cache = m.init_cache(b, w)
        step = jax.jit(m.decode_step)
        for t in range(20):  # run past the window
            tok = jnp.full((b,), t % cfg.vocab_size, jnp.int32)
            lg, cache = step(params, cache, tok, jnp.full((b,), t, jnp.int32))
        assert bool(jnp.all(jnp.isfinite(lg)))


class TestMoEDispatch:
    def test_moe_matches_dense_loop(self):
        """Sorted-dispatch MoE == explicit per-token expert loop oracle."""
        from repro.models import moe as MOE

        cfg = dataclasses.replace(
            configs.get_smoke_config("qwen3-moe-235b-a22b"),
            capacity_factor=8.0,
        )
        p = MOE.init_moe(jax.random.PRNGKey(3), cfg)
        x = jax.random.normal(
            jax.random.PRNGKey(2), (2, 8, cfg.d_model), jnp.float32
        )
        y, aux = MOE.moe_ffn(p, x, cfg)
        # oracle: dense computation of every expert for every token
        xf = x.reshape(-1, cfg.d_model)
        top_p, top_e, _ = MOE.router_probs(p, xf, cfg)
        g = jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["wg"]))
        u = jnp.einsum("td,edf->tef", xf, p["wu"])
        all_out = jnp.einsum("tef,efd->ted", g * u, p["wd"])
        ref = jnp.zeros_like(xf)
        for slot in range(cfg.top_k):
            w = top_p[:, slot][:, None]
            ref = ref + w * jnp.take_along_axis(
                all_out, top_e[:, slot][:, None, None], axis=1
            )[:, 0]
        np.testing.assert_allclose(
            np.asarray(y.reshape(-1, cfg.d_model)), np.asarray(ref),
            rtol=1e-4, atol=1e-4,
        )
        assert float(aux["moe_dropped_frac"]) == 0.0

    def test_capacity_drop_reported(self):
        from repro.models import moe as MOE

        cfg = dataclasses.replace(
            configs.get_smoke_config("deepseek-moe-16b"),
            capacity_factor=0.1,
        )
        p = MOE.init_moe(jax.random.PRNGKey(3), cfg)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model))
        _, aux = MOE.moe_ffn(p, x, cfg)
        assert float(aux["moe_dropped_frac"]) > 0.0
