"""Mixed-precision paths: bf16 weight streams (cast_for_compute), int8
KV quantization error bounds, and training stability in bf16 compute."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import configs
from repro.configs.base import ShapeSpec
from repro.models import get_model
from repro.models.layers import _dequantize_kv, _quantize_kv
from repro.train import data as data_lib
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts

SHAPE = ShapeSpec("t", 32, 4, "train")


class TestCastForCompute:
    def test_matrices_cast_vectors_kept(self):
        cfg = dataclasses.replace(
            configs.get_smoke_config("qwen3-0.6b"), compute_dtype="bfloat16"
        )
        m = get_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        cast = m.cast_for_compute(params)
        assert cast["layers"]["attn"]["wq"].dtype == jnp.bfloat16
        assert cast["embed"]["embedding"].dtype == jnp.bfloat16
        # norms / qk-norm scales stay f32
        assert cast["layers"]["ln1"]["scale"].dtype == jnp.float32
        assert cast["layers"]["attn"]["q_norm"].dtype == jnp.float32

    def test_noop_when_compute_is_param_dtype(self):
        cfg = configs.get_smoke_config("qwen3-0.6b")  # f32 compute
        m = get_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        cast = m.cast_for_compute(params)
        assert cast["layers"]["attn"]["wq"].dtype == jnp.float32

    def test_bf16_training_loss_decreases(self):
        """End-to-end train step in bf16 compute with f32 masters."""
        cfg = dataclasses.replace(
            configs.get_smoke_config("smollm-135m"), compute_dtype="bfloat16"
        )
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tcfg = ts.TrainConfig(
            opt=opt_lib.OptimizerConfig(
                peak_lr=1e-2, warmup_steps=5, total_steps=60
            )
        )
        step = jax.jit(ts.make_train_step(model, tcfg))
        state = opt_lib.init_opt_state(params, tcfg.opt)
        stream = data_lib.SyntheticStream(model, SHAPE)
        first = last = None
        for i in range(60):
            params, state, metrics = step(params, state, stream.batch(i))
            if first is None:
                first = float(metrics["loss"])
            last = float(metrics["loss"])
        # masters stay f32 through the whole run
        assert params["layers"]["attn"]["wq"].dtype == jnp.float32
        assert last < first - 0.5, (first, last)


class TestInt8KV:
    @settings(max_examples=30, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        scale=st.floats(1e-3, 1e3),
        dh=st.sampled_from([16, 64, 128]),
    )
    def test_quantize_roundtrip_error_bound(self, seed, scale, dh):
        """Symmetric int8: |x - deq(q(x))| <= amax/254 per row."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(4, dh)) * scale, jnp.float32)
        q, s = _quantize_kv(x)
        back = _dequantize_kv(q, s, jnp.float32)
        amax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
        bound = amax / 254.0 + amax * 0.005 + 1e-6  # half-step + bf16 scale
        assert np.all(np.abs(np.asarray(back - x)) <= bound)

    def test_quantize_handles_zero_rows(self):
        x = jnp.zeros((2, 8), jnp.float32)
        q, s = _quantize_kv(x)
        back = _dequantize_kv(q, s, jnp.float32)
        assert np.all(np.asarray(back) == 0.0)
