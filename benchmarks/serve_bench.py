"""Serving-engine benchmark: the VM-scheduled generation engine vs the
naive sequential per-request loop, on a reduced-config LM.

Two modes:

* ``--arrivals closed`` (default): the seed's closed-loop sweep — every
  lane's request queue is fixed before the single compiled program
  launches; reports tokens/sec vs the sequential oracle.
* ``--arrivals poisson``: open-loop continuous batching — requests arrive
  by a Poisson process at ``--rate`` req/s and are admitted into free
  lanes between VM segments (retire-and-refill); reports p50/p99
  arrival-to-finish latency and lane occupancy, next to a batch-mode
  (all-at-once) run of the same request set for the closed-loop contrast.

``--json PATH`` writes machine-readable records (strict JSON — NaN is
serialized as ``null``).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.models import get_model
from repro.serve.engine import EngineConfig, GenerationEngine, Request

from .common import Table, write_json


def _load_model():
    """Build the bench LM once per sweep (params are sweep-invariant)."""
    cfg = configs.get_smoke_config("smollm-135m")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(cfg, model, params, lanes: int, *, max_new: int,
            prompt_len: int, requests_per_lane: int, mesh,
            segment_steps: int = 64):
    ecfg = EngineConfig(
        lanes=lanes, max_context=prompt_len + max_new + 2,
        max_prompt_len=prompt_len, max_new_tokens=max_new,
        requests_per_lane=requests_per_lane, eos_id=0, backend="pc",
        mesh=mesh, segment_steps=segment_steps,
    )
    return GenerationEngine(model, params, ecfg)


def serve_sweep(lane_counts: list[int], *, max_new: int = 16,
                prompt_len: int = 8, requests_per_lane: int = 2,
                mesh=None) -> tuple[Table, list[dict]]:
    tab = Table(
        "Serve engine — generated tokens/sec (VM engine vs sequential"
        + (f", lanes sharded over {mesh} devices" if mesh else "") + ")",
        ["lanes", "mesh", "vm_tok_s", "seq_tok_s", "speedup", "utilization"],
    )
    nan = float("nan")
    rng = np.random.default_rng(0)
    records: list[dict] = []
    cfg, model, params = _load_model()
    for lanes in lane_counts:
        if mesh and lanes % mesh:
            # Lanes must divide across the mesh: keep the row (as nans)
            # so the gap is visible, matching fig5/fig6.
            tab.add(lanes, mesh, nan, nan, nan, nan)
            records.append({"mode": "closed", "lanes": lanes,
                            "mesh": mesh, "tok_s": None,
                            "skipped": "lanes do not divide across mesh"})
            continue
        eng = _engine(cfg, model, params, lanes, max_new=max_new,
                      prompt_len=prompt_len,
                      requests_per_lane=requests_per_lane, mesh=mesh)
        prompts = rng.integers(
            1, cfg.vocab_size, (lanes, requests_per_lane, prompt_len)
        ).astype(np.int32)
        plens = rng.integers(
            2, prompt_len + 1, (lanes, requests_per_lane)
        ).astype(np.int32)
        res = eng.generate(prompts, plens)  # warm-up (compile)
        t0 = time.perf_counter()
        res = eng.generate(prompts, plens)
        t_vm = time.perf_counter() - t0
        n_tok = int(res["lengths"].sum())
        t0 = time.perf_counter()
        ref = eng.reference_generate(prompts, plens)
        t_seq = time.perf_counter() - t0
        tab.add(lanes, mesh or 1, n_tok / t_vm, n_tok / t_seq, t_seq / t_vm,
                round(res["utilization"] or 0.0, 3))
        records.append({
            "mode": "closed", "lanes": lanes, "mesh": mesh or 1,
            "tok_s": n_tok / t_vm, "seq_tok_s": n_tok / t_seq,
            "utilization": res["utilization"],
        })
    return tab, records


def poisson_requests(num: int, rate: float, prompt_len: int,
                     vocab: int, seed: int = 0) -> list[Request]:
    """An open-loop arrival stream: exponential gaps at ``rate`` req/s."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=num))
    return [
        Request(
            rid=i,
            prompt=rng.integers(
                1, vocab, int(rng.integers(1, prompt_len + 1))
            ).astype(np.int32),
            arrival=float(t),
        )
        for i, t in enumerate(arrivals)
    ]


def open_loop_sweep(lane_counts: list[int], *, rate: float,
                    num_requests: int, segment_steps: int,
                    max_new: int = 16, prompt_len: int = 8,
                    mesh=None) -> tuple[Table, list[dict]]:
    """Open-loop (Poisson) vs batch (all-at-once) continuous serving."""
    tab = Table(
        f"Serve engine, open loop — Poisson arrivals at {rate} req/s vs "
        "all-at-once batch (retire-and-refill in both)",
        ["lanes", "mode", "tok_s", "p50_s", "p99_s", "occupancy",
         "segments"],
    )
    records: list[dict] = []
    cfg, model, params = _load_model()
    for lanes in lane_counts:
        if mesh and lanes % mesh:
            tab.add(lanes, "poisson", *([float("nan")] * 5))
            records.append({"mode": "poisson", "lanes": lanes,
                            "mesh": mesh, "tok_s": None,
                            "skipped": "lanes do not divide across mesh"})
            continue
        eng = _engine(cfg, model, params, lanes, max_new=max_new,
                      prompt_len=prompt_len, requests_per_lane=1,
                      mesh=mesh, segment_steps=segment_steps)
        reqs = poisson_requests(num_requests, rate, prompt_len,
                                cfg.vocab_size)
        # Warm-up: compile the stepper path on a tiny closed run.
        eng.serve([Request(rid=0, prompt=np.array([1], np.int32))])
        for mode in ("poisson", "batch"):
            batch = [Request(r.rid, r.prompt, 0.0) for r in reqs] \
                if mode == "batch" else reqs
            comps, stats = eng.serve(batch, segment_steps=segment_steps)
            lat = np.array([c.latency for c in comps])
            p50, p99 = (float(np.percentile(lat, q)) for q in (50, 99))
            tok_s = stats.generated_tokens / stats.wall_time
            tab.add(lanes, mode, tok_s, p50, p99,
                    round(stats.occupancy, 3), stats.segments)
            records.append({
                "mode": mode, "lanes": lanes, "mesh": mesh or 1,
                "rate": rate if mode == "poisson" else None,
                "num_requests": num_requests,
                "segment_steps": segment_steps, "tok_s": tok_s,
                "p50_latency_s": p50, "p99_latency_s": p99,
                "occupancy": stats.occupancy, "segments": stats.segments,
                "vm_steps": stats.vm_steps,
            })
    return tab, records


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--lanes", default="2,8")
    ap.add_argument("--mesh", default="none",
                    help="shard lanes over this many devices ('none' = "
                         "unsharded; lanes must divide across the mesh)")
    ap.add_argument("--arrivals", default="closed",
                    choices=("closed", "poisson"),
                    help="closed = pre-assigned queues (seed baseline); "
                         "poisson = open-loop continuous batching")
    ap.add_argument("--rate", type=float, default=8.0,
                    help="poisson arrival rate, requests/sec")
    ap.add_argument("--num-requests", type=int, default=32,
                    help="poisson mode: total requests in the stream")
    ap.add_argument("--segment-steps", type=int, default=64,
                    help="VM dispatches per segment between host "
                         "admission/retire checks")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable records (strict JSON)")
    args = ap.parse_args(argv)
    lanes = [int(x) for x in args.lanes.split(",")]
    mesh = None if args.mesh.lower() in ("none", "0") else int(args.mesh)
    if args.arrivals == "poisson":
        tab, records = open_loop_sweep(
            lanes, rate=args.rate, num_requests=args.num_requests,
            segment_steps=args.segment_steps, mesh=mesh,
        )
    else:
        tab, records = serve_sweep(lanes, mesh=mesh)
    print(tab.render())
    if args.json:
        write_json(args.json, {
            "benchmark": "serve_bench",
            "config": {"arrivals": args.arrivals, "lanes": lanes,
                       "mesh": mesh, "rate": args.rate,
                       "num_requests": args.num_requests,
                       "segment_steps": args.segment_steps},
            "records": records,
        })
        print(f"[wrote {args.json}: {len(records)} records]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
