"""Serving-engine benchmark: tokens/sec of the VM-scheduled generation
engine (the paper's runtime as a continuous-batching scheduler) vs the
naive sequential per-request loop, on a reduced-config LM."""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.models import get_model
from repro.serve.engine import EngineConfig, GenerationEngine

from .common import Table


def serve_sweep(lane_counts: list[int], *, max_new: int = 16,
                prompt_len: int = 8, requests_per_lane: int = 2,
                mesh=None) -> Table:
    cfg = configs.get_smoke_config("smollm-135m")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tab = Table(
        "Serve engine — generated tokens/sec (VM engine vs sequential"
        + (f", lanes sharded over {mesh} devices" if mesh else "") + ")",
        ["lanes", "mesh", "vm_tok_s", "seq_tok_s", "speedup", "utilization"],
    )
    nan = float("nan")
    rng = np.random.default_rng(0)
    for lanes in lane_counts:
        if mesh and lanes % mesh:
            # Lanes must divide across the mesh: keep the row (as nans)
            # so the gap is visible, matching fig5/fig6.
            tab.add(lanes, mesh, nan, nan, nan, nan)
            continue
        ecfg = EngineConfig(
            lanes=lanes, max_context=prompt_len + max_new + 2,
            max_prompt_len=prompt_len, max_new_tokens=max_new,
            requests_per_lane=requests_per_lane, eos_id=0, backend="pc",
            mesh=mesh,
        )
        eng = GenerationEngine(model, params, ecfg)
        prompts = rng.integers(
            1, cfg.vocab_size, (lanes, requests_per_lane, prompt_len)
        ).astype(np.int32)
        plens = rng.integers(
            2, prompt_len + 1, (lanes, requests_per_lane)
        ).astype(np.int32)
        res = eng.generate(prompts, plens)  # warm-up (compile)
        t0 = time.perf_counter()
        res = eng.generate(prompts, plens)
        t_vm = time.perf_counter() - t0
        n_tok = int(res["lengths"].sum())
        t0 = time.perf_counter()
        ref = eng.reference_generate(prompts, plens)
        t_seq = time.perf_counter() - t0
        tab.add(lanes, mesh or 1, n_tok / t_vm, n_tok / t_seq, t_seq / t_vm,
                round(res["utilization"] or 0.0, 3))
    return tab


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--lanes", default="2,8")
    ap.add_argument("--mesh", default="none",
                    help="shard lanes over this many devices ('none' = "
                         "unsharded; lanes must divide across the mesh)")
    args = ap.parse_args(argv)
    lanes = [int(x) for x in args.lanes.split(",")]
    mesh = None if args.mesh.lower() in ("none", "0") else int(args.mesh)
    print(serve_sweep(lanes, mesh=mesh).render())
    return 0


if __name__ == "__main__":
    sys.exit(main())
