"""Serving-engine benchmark: tokens/sec of the VM-scheduled generation
engine (the paper's runtime as a continuous-batching scheduler) vs the
naive sequential per-request loop, on a reduced-config LM."""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.models import get_model
from repro.serve.engine import EngineConfig, GenerationEngine

from .common import Table


def serve_sweep(lane_counts: list[int], *, max_new: int = 16,
                prompt_len: int = 8, requests_per_lane: int = 2) -> Table:
    cfg = configs.get_smoke_config("smollm-135m")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tab = Table(
        "Serve engine — generated tokens/sec (VM engine vs sequential)",
        ["lanes", "vm_tok_s", "seq_tok_s", "speedup", "utilization"],
    )
    rng = np.random.default_rng(0)
    for lanes in lane_counts:
        ecfg = EngineConfig(
            lanes=lanes, max_context=prompt_len + max_new + 2,
            max_prompt_len=prompt_len, max_new_tokens=max_new,
            requests_per_lane=requests_per_lane, eos_id=0, backend="pc",
        )
        eng = GenerationEngine(model, params, ecfg)
        prompts = rng.integers(
            1, cfg.vocab_size, (lanes, requests_per_lane, prompt_len)
        ).astype(np.int32)
        plens = rng.integers(
            2, prompt_len + 1, (lanes, requests_per_lane)
        ).astype(np.int32)
        res = eng.generate(prompts, plens)  # warm-up (compile)
        t0 = time.perf_counter()
        res = eng.generate(prompts, plens)
        t_vm = time.perf_counter() - t0
        n_tok = int(res["lengths"].sum())
        t0 = time.perf_counter()
        ref = eng.reference_generate(prompts, plens)
        t_seq = time.perf_counter() - t0
        tab.add(lanes, n_tok / t_vm, n_tok / t_seq, t_seq / t_vm,
                round(res["utilization"] or 0.0, 3))
    return tab


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--lanes", default="2,8")
    args = ap.parse_args(argv)
    lanes = [int(x) for x in args.lanes.split(",")]
    print(serve_sweep(lanes).render())
    return 0


if __name__ == "__main__":
    sys.exit(main())
